"""Quickstart: FedPhD in ~40 lines.

Trains a reduced DDPM U-Net across 6 non-IID clients, 2 edge servers and
a cloud, with SH-aware aggregation/selection and structured pruning at
round R_s, then samples images and scores them with proxy-FID.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs import SMOKE_UNET
from repro.configs.base import FLConfig
from repro.core.hfl import FedPhD
from repro.data import SMOKE_DATA, ClientData, make_dataset, shards_per_client
from repro.fl.client import Client
from repro.metrics import fid_proxy


def main():
    # 1. non-IID federated data: each client holds ONE class
    images, labels = make_dataset(SMOKE_DATA, seed=0)
    parts = shards_per_client(labels, num_clients=6, classes_per_client=1)
    clients = [Client(i, ClientData(images[p], labels[p], batch_size=32,
                                    seed=i), SMOKE_DATA.num_classes)
               for i, p in enumerate(parts)]

    # 2. FedPhD: edge aggregation every round, cloud every 2, prune at r>=2
    fl = FLConfig(num_clients=6, num_edges=2, local_epochs=1,
                  edge_agg_every=1, cloud_agg_every=2, rounds=6,
                  sparse_rounds=2, prune_ratio=0.44, sh_a=1000.0)
    trainer = FedPhD(SMOKE_UNET, fl, clients, rng_seed=0)
    history, _ = trainer.run()

    for h in history:
        print(f"round {h.round}: loss={h.loss:.4f} "
              f"params={h.params_m:.2f}M comm={h.comm_gb*1e3:.2f}MB "
              f"edge_SH={[round(s, 3) for s in h.edge_sh]}"
              + ("  <- pruned!" if h.pruned else ""))

    # 3. sample + proxy-FID
    from repro.diffusion import sample_images
    fake = sample_images(trainer.params, trainer.cfg, n=96, steps=10)
    print(f"proxy-FID vs real data: {fid_proxy(images[:256], fake):.2f}")


if __name__ == "__main__":
    main()
