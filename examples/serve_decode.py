"""Serve a model with batched requests: prefill + KV-cache decode.

Runs the reduced variant of any assigned architecture (--arch) on CPU;
the same serve_step is what the decode_32k / long_500k dry-run lowers on
the production mesh.

  PYTHONPATH=src python examples/serve_decode.py --arch rwkv6-7b --tokens 16
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import list_archs, smoke_variant
from repro.launch.steps import build_serve_step
from repro.models import model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b", choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    args = ap.parse_args()

    cfg = smoke_variant(args.arch)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng, cfg)
    serve_step = jax.jit(build_serve_step(cfg))

    # batched requests: start from random prompt tokens
    cache = model.init_cache(params, cfg, args.batch, args.cache_len)
    toks = jax.random.randint(rng, (args.batch, 1), 0, cfg.vocab_size,
                              jnp.int32)
    seqs = [toks]
    t0 = time.perf_counter()
    for _ in range(args.tokens):
        toks, cache = serve_step(params, cache, toks)
        seqs.append(toks)
    jax.block_until_ready(toks)
    dt = time.perf_counter() - t0
    out = jnp.concatenate(seqs, axis=1)
    print(f"arch={cfg.name}  batch={args.batch}  "
          f"{args.tokens} tokens in {dt*1e3:.1f} ms "
          f"({args.batch*args.tokens/dt:.1f} tok/s)")
    for b in range(args.batch):
        print(f"  req{b}: {out[b].tolist()}")


if __name__ == "__main__":
    main()
