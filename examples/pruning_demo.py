"""Structured pruning walkthrough on an assigned architecture.

Shows the FedPhD pruning pipeline outside the FL loop: dependency groups
-> L2 group-norm scores -> masks (sparse phase, with the Pallas
block-masked matmul) -> physical compaction -> smaller config.

  PYTHONPATH=src python examples/pruning_demo.py --arch qwen3-moe-235b-a22b
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import list_archs, smoke_variant
from repro.configs.base import InputShape
from repro.core import pruning as P
from repro.kernels.block_masked_matmul.ops import masked_matmul
from repro.models import model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-20b", choices=list_archs())
    ap.add_argument("--ratio", type=float, default=0.44)
    args = ap.parse_args()

    cfg = smoke_variant(args.arch)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng, cfg)
    n0 = sum(x.size for x in jax.tree.leaves(params))

    groups = P.build_groups(cfg, params)
    print(f"{cfg.name}: {len(groups)} dependency groups")
    for g in groups[:6]:
        print(f"  {g.name}: {g.size} {g.unit}s x {len(g.members)} members"
              f"{' (scan-stacked x' + str(g.stacked) + ')' if g.stacked else ''}")

    scores = P.l2_scores(params, groups)
    masks = P.make_masks(scores, groups, args.ratio)
    lambdas = P.depth_lambdas(groups, 1e-4)
    print(f"Omega(G,k) sparse-training regularizer: "
          f"{float(P.omega(params, groups, lambdas)):.4f}")

    batch = model.make_inputs(rng, cfg, InputShape("t", 64, 2, "train"))
    masked = P.apply_masks(params, groups, masks)
    l_masked = float(model.loss_fn(masked, cfg, batch, rng))
    pruned, cfg2, report = P.compact(params, cfg, groups, masks)
    l_compact = float(model.loss_fn(pruned, cfg2, batch, rng))
    n1 = sum(x.size for x in jax.tree.leaves(pruned))

    print(f"masked loss {l_masked:.4f} == compacted loss {l_compact:.4f} "
          f"(drift {abs(l_masked-l_compact):.2e})")
    print(f"params: {n0/1e6:.2f}M -> {n1/1e6:.2f}M ({1-n1/n0:.0%} cut)")
    if cfg2.moe:
        print(f"experts: {cfg.moe.num_experts} -> {cfg2.moe.num_experts}")

    # sparse-phase kernel: block-masked matmul skips pruned tiles
    x = jax.random.normal(rng, (128, 256))
    w = jax.random.normal(jax.random.fold_in(rng, 1), (256, 256))
    cm = jnp.repeat((jax.random.uniform(rng, (2,)) > 0.5).astype(jnp.float32),
                    128)
    y = masked_matmul(x, w, cm, jnp.ones(256))
    print(f"block-masked matmul: {int(jnp.sum(cm))}/256 cols active, "
          f"out nonzero cols = "
          f"{int(jnp.sum(jnp.any(jnp.abs(y) > 0, axis=0)))}")


if __name__ == "__main__":
    main()
