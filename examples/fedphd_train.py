"""End-to-end driver: FedPhD vs FedAvg on CIFAR-10-like data (paper §V).

Default is the reduced config (CPU-friendly: a few hundred local steps
total).  ``--paper-scale`` switches to the full 35.7M U-Net + 20 clients
+ r_g=5 — the paper's exact setup (needs accelerators for useful wall
clock, but runs the identical code path).

  PYTHONPATH=src python examples/fedphd_train.py --rounds 10
"""
import argparse

import numpy as np

from repro.configs import CIFAR10_UNET, SMOKE_UNET
from repro.configs.base import FLConfig
from repro.core.hfl import FedPhD
from repro.data import (CIFAR10_LIKE, SMOKE_DATA, ClientData, make_dataset,
                        shards_per_client)
from repro.fl.baselines import run_flat_fl
from repro.fl.client import Client
from repro.metrics import fid_proxy, inception_score_proxy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--paper-scale", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", default="auto",
                    choices=("auto", "vectorized", "sequential"),
                    help="round engine: one jitted vmap/scan program per "
                         "round (vectorized) vs per-client loop; applies "
                         "to FedPhD and the FedAvg baseline alike")
    ap.add_argument("--persistent-opt", action="store_true",
                    help="carry per-client Adam moments across rounds "
                         "(off = paper semantics: fresh Adam per round)")
    args = ap.parse_args()

    if args.paper_scale:
        cfg, spec = CIFAR10_UNET, CIFAR10_LIKE
        fl = FLConfig(num_clients=20, num_edges=2, local_epochs=1,
                      edge_agg_every=1, cloud_agg_every=5,
                      rounds=args.rounds, sparse_rounds=50,
                      prune_ratio=0.44, sh_a=15000.0)
        classes_per_client = 2                      # paper: CIFAR-10 setup
    else:
        cfg, spec = SMOKE_UNET, SMOKE_DATA
        fl = FLConfig(num_clients=8, num_edges=2, local_epochs=1,
                      edge_agg_every=1, cloud_agg_every=2,
                      rounds=args.rounds, sparse_rounds=3,
                      prune_ratio=0.44, sh_a=1000.0)
        classes_per_client = 1

    images, labels = make_dataset(spec, seed=args.seed)
    parts = shards_per_client(labels, fl.num_clients, classes_per_client,
                              seed=args.seed)
    clients = [Client(i, ClientData(images[p], labels[p], batch_size=32,
                                    seed=i), spec.num_classes)
               for i, p in enumerate(parts)]
    real = images[:512]

    def score(params, model_cfg, tag):
        from benchmarks.common import sample_images
        fake = sample_images(params, model_cfg, n=128, steps=10,
                             seed=args.seed)
        fid = fid_proxy(real, fake)
        is_ = inception_score_proxy(fake)
        print(f"{tag:>10s}: proxy-FID={fid:7.2f}  proxy-IS={is_:.3f}")
        return fid

    print(f"== FedPhD ({fl.num_clients} clients, {fl.num_edges} edges, "
          f"r_e={fl.edge_agg_every}, r_g={fl.cloud_agg_every}) ==")
    trainer = FedPhD(cfg, fl, clients, rng_seed=args.seed,
                     engine=args.engine,
                     persistent_opt=args.persistent_opt)
    hist, _ = trainer.run()
    total_comm = sum(h.comm_gb for h in hist)
    print(f"final loss {hist[-1].loss:.4f}; params "
          f"{hist[-1].params_m:.2f}M; total comm {total_comm:.3f} GB")
    fid_phd = score(trainer.params, trainer.cfg, "FedPhD")

    print("== FedAvg baseline ==")
    res = run_flat_fl("fedavg", cfg, fl, clients, rounds=fl.rounds,
                      rng_seed=args.seed, engine=args.engine,
                      persistent_opt=args.persistent_opt)
    total_comm_avg = sum(h["comm_gb"] for h in res.history)
    print(f"final loss {res.history[-1]['loss']:.4f}; "
          f"total comm {total_comm_avg:.3f} GB")
    fid_avg = score(res.params, cfg, "FedAvg")

    print(f"\ncomm reduction: {1 - total_comm/max(total_comm_avg,1e-9):.1%}; "
          f"FID delta (FedAvg - FedPhD): {fid_avg - fid_phd:+.2f}")


if __name__ == "__main__":
    main()
