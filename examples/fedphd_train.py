"""End-to-end driver: FedPhD vs FedAvg on CIFAR-10-like data (paper §V),
on the unified experiment API — two points of one spec grid.

Default is the reduced config (CPU-friendly: a few hundred local steps
total).  ``--paper-scale`` switches to the full 35.7M U-Net + 20 clients
+ r_g=5 — the paper's exact setup (needs accelerators for useful wall
clock, but runs the identical code path).

  PYTHONPATH=src python examples/fedphd_train.py --rounds 10

With ``--out DIR`` the FedPhD run checkpoints after finishing and
``--resume`` continues a previously killed run — the CLI equivalent is
``python -m repro.experiment.runner``.
"""
import argparse
import dataclasses

from repro.diffusion import sample_images
from repro.experiment import ExperimentSpec, run_spec
from repro.experiment.runner import PRESETS
from repro.metrics import fid_proxy, inception_score_proxy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--paper-scale", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", default="auto",
                    choices=("auto", "vectorized", "sequential"),
                    help="round engine: one jitted vmap/scan program per "
                         "round (vectorized) vs per-client loop; applies "
                         "to FedPhD and the FedAvg baseline alike")
    ap.add_argument("--persistent-opt", action="store_true",
                    help="carry per-client Adam moments across rounds "
                         "(off = paper semantics: fresh Adam per round)")
    ap.add_argument("--out", default=None,
                    help="checkpoint the FedPhD run to <out>/ckpt.npz")
    ap.add_argument("--resume", action="store_true",
                    help="resume the FedPhD run from <out>/ckpt.npz")
    args = ap.parse_args()
    if args.resume and not args.out:
        ap.error("--resume needs --out (the checkpoint location)")

    base = PRESETS["paper" if args.paper_scale else "smoke"]
    base = base.replace(seed=args.seed, engine=args.engine,
                        persistent_opt=args.persistent_opt,
                        fl=dataclasses.replace(base.fl, rounds=args.rounds))
    fl = base.fl

    def run(spec: ExperimentSpec, ckpt=None, resume=False) -> "Experiment":
        # resume loads the checkpointed spec; --rounds still extends it
        return run_spec(None if resume else spec, rounds=args.rounds,
                        ckpt=ckpt, resume=resume)

    def report(exp) -> tuple:
        fake = sample_images(exp.params, exp.cfg, n=128, steps=10,
                             seed=args.seed)
        real = exp.images[:512]
        fid = fid_proxy(real, fake)
        is_ = inception_score_proxy(fake)
        last = exp.history[-1]
        total = sum(r.comm_gb for r in exp.history)
        print(f"{exp.spec.method:>10s}: final loss {last.loss:.4f}; params "
              f"{last.params_m:.2f}M; total comm {total:.3f} GB; "
              f"proxy-FID={fid:7.2f}  proxy-IS={is_:.3f}")
        return fid, total

    print(f"== FedPhD ({fl.num_clients} clients, {fl.num_edges} edges, "
          f"r_e={fl.edge_agg_every}, r_g={fl.cloud_agg_every}) ==")
    ckpt = f"{args.out}/ckpt.npz" if args.out else None
    exp_phd = run(base.replace(method="fedphd", name="fedphd"),
                  ckpt=ckpt, resume=args.resume)
    fid_phd, comm_phd = report(exp_phd)

    print("== FedAvg baseline ==")
    # derive the baseline from the (possibly checkpointed) FedPhD spec
    # so a resume with different local flags can't skew the comparison
    exp_avg = run(exp_phd.spec.replace(method="fedavg", name="fedavg"))
    fid_avg, comm_avg = report(exp_avg)

    print(f"\ncomm reduction: {1 - comm_phd/max(comm_avg, 1e-9):.1%}; "
          f"FID delta (FedAvg - FedPhD): {fid_avg - fid_phd:+.2f}")


if __name__ == "__main__":
    main()
