"""FL integration tests: FedPhD HFL loop (Alg. 1) + flat baselines,
at smoke scale on CPU."""
import numpy as np
import pytest

from repro.configs import SMOKE_UNET
from repro.configs.base import FLConfig
from repro.core.hfl import FedPhD
from repro.data import SMOKE_DATA, ClientData, make_dataset, shards_per_client
from repro.fl.baselines import FlatTrainer, run_centralized
from repro.fl.client import Client


def run_flat(method, cfg, fl, clients, rounds):
    """run_flat_fl is deprecated — construct FlatTrainer directly.
    RoundRecord keeps dict-style access, so assertions read the same."""
    tr = FlatTrainer(method, cfg, fl, clients, rng_seed=0)
    tr.run(rounds)
    return tr


@pytest.fixture(scope="module")
def clients():
    images, labels = make_dataset(SMOKE_DATA, seed=0)
    parts = shards_per_client(labels, num_clients=6, classes_per_client=1,
                              seed=0)
    return [Client(i, ClientData(images[p], labels[p], batch_size=32, seed=i),
                   SMOKE_DATA.num_classes) for i, p in enumerate(parts)]


@pytest.fixture(scope="module")
def fl_cfg():
    return FLConfig(num_clients=6, num_edges=2, local_epochs=1,
                    edge_agg_every=1, cloud_agg_every=2, rounds=4,
                    sparse_rounds=2, prune_ratio=0.44, sh_a=1000.0)


def test_fedphd_full_loop(clients, fl_cfg):
    trainer = FedPhD(SMOKE_UNET, fl_cfg, clients, rng_seed=0)
    hist, _ = trainer.run(4)
    assert len(hist) == 4
    assert all(np.isfinite(h.loss) for h in hist)
    # pruning fired at the first cloud round >= R_s
    assert any(h.pruned for h in hist)
    pr = next(i for i, h in enumerate(hist) if h.pruned)
    assert hist[pr].params_m < hist[0].params_m * 0.7
    # comm cost per round drops after pruning (smaller model)
    assert trainer.pruned


def test_fedphd_oneshot(clients, fl_cfg):
    import dataclasses
    cfg = dataclasses.replace(fl_cfg, prune_mode="oneshot_random", rounds=2)
    trainer = FedPhD(SMOKE_UNET, cfg, clients, rng_seed=0)
    assert trainer.pruned                      # pruned at init
    hist, _ = trainer.run(2)
    assert all(np.isfinite(h.loss) for h in hist)


def test_fedphd_sh_tracking(clients, fl_cfg):
    trainer = FedPhD(SMOKE_UNET, fl_cfg, clients, rng_seed=0, prune=False)
    hist, _ = trainer.run(2)
    for h in hist:
        for mu in h.edge_sh:
            assert 2 - np.sqrt(2) - 1e-9 <= mu <= 2 + 1e-9


@pytest.mark.parametrize("method", ["fedavg", "fedprox", "feddiffuse",
                                    "scaffold"])
def test_flat_baselines(method, clients, fl_cfg):
    res = run_flat(method, SMOKE_UNET, fl_cfg, clients, rounds=2)
    assert len(res.history) == 2
    assert all(np.isfinite(h["loss"]) for h in res.history)


def test_feddiffuse_cheaper_than_fedavg(clients, fl_cfg):
    r1 = run_flat("fedavg", SMOKE_UNET, fl_cfg, clients, rounds=1)
    r2 = run_flat("feddiffuse", SMOKE_UNET, fl_cfg, clients, rounds=1)
    assert r2.history[0]["comm_gb"] < r1.history[0]["comm_gb"]


def test_centralized_loss_decreases():
    images, _ = make_dataset(SMOKE_DATA, seed=1)
    _, losses = run_centralized(SMOKE_UNET, images, steps=12, batch_size=32)
    assert np.mean(losses[-4:]) < np.mean(losses[:4])
