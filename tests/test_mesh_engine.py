"""Mesh-sharded round engine (``ExperimentSpec.mesh``) and the
TPU-topology aggregation path, on 8 fake CPU devices.

Everything that needs a multi-device view runs in a subprocess under
``repro.launch.env`` (XLA reads ``--xla_force_host_platform_device_count``
once, at backend init — the main test process must keep its single
device; see conftest).  In-process tests cover only the single-device
guard rails.
"""
import subprocess
import sys

import pytest

from repro.launch import env as launch_env


def _run(script: str, *, devices=None) -> subprocess.CompletedProcess:
    # JAX_PLATFORMS=cpu inside child_env is load-bearing: on images
    # bundling libtpu, backend discovery otherwise polls the GCP
    # metadata server with 30-retry backoff
    return subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=600,
                          env=launch_env.child_env(devices))


# --------------------------------------------------------------------------
# Sharded-vs-unsharded equivalence, driven through env.apply() in-child
# (no XLA_FLAGS arrive from outside: the apply() call is load-bearing).
# --------------------------------------------------------------------------

_EQUIV_SCRIPT = r"""
from repro.launch import env
env.apply(8)                      # before the first jax backend init

import jax, numpy as np
assert len(jax.devices()) == 8, jax.devices()

from repro.configs import SMOKE_UNET, register_config
from repro.configs.base import FLConfig
from repro.experiment import (DataSpec, ExperimentSpec, make_clients,
                              register_dataset, run_spec)
from repro.experiment.data import DatasetSpec
from repro.fl.baselines import FlatTrainer
from repro.launch.mesh import make_spec_mesh

TINY = SMOKE_UNET.replace(name='ddpm-unet-tiny-mesh', image_size=8,
                          base_channels=8, channel_mults=(1,),
                          num_res_blocks=1, attn_resolutions=())
register_config('ddpm-unet-tiny-mesh', TINY, overwrite=True)
register_dataset('tiny-mesh', DatasetSpec('tiny-mesh', num_classes=4,
                                          image_size=8, samples_per_class=32),
                 overwrite=True)
BASE = ExperimentSpec(
    name='mesh-smoke', method='fedphd', model='ddpm-unet-tiny-mesh',
    fl=FLConfig(num_clients=8, num_edges=2, local_epochs=1,
                edge_agg_every=1, cloud_agg_every=2, rounds=2,
                sparse_rounds=2, sh_a=1000.0, participation=1.0),
    data=DataSpec(dataset='tiny-mesh', batch_size=8),
    engine='vectorized', prune=False)

# --- FedPhD: spec.mesh round-trips JSON and matches unsharded exactly
sharded_spec = ExperimentSpec.from_json(
    BASE.replace(mesh={'data': 8, 'model': 1}).to_json())
assert sharded_spec.mesh == {'data': 8, 'model': 1}
plain = run_spec(BASE, rounds=2)
shard = run_spec(sharded_spec, rounds=2)
for a, b in zip(plain.history, shard.history):
    assert abs(a.loss - b.loss) < 1e-5, (a.round, a.loss, b.loss)
    assert a.comm_gb == b.comm_gb, (a.round, a.comm_gb, b.comm_gb)
    assert a.selected == b.selected
for x, y in zip(jax.tree.leaves(plain.trainer.params),
                jax.tree.leaves(shard.trainer.params)):
    np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-5)

# --- the client axis is REALLY on the mesh: the engine's per-client
# loss output comes back sharded over 'data'
mesh = make_spec_mesh({'data': 8, 'model': 1})
clients, _, _ = make_clients(BASE.replace(method='fedavg'))
tr = FlatTrainer('fedavg', TINY, BASE.fl, clients, rng_seed=0,
                 engine='vectorized', mesh=mesh)
pend = tr._start_round(1)
losses = pend['losses']
assert losses.shape == (8,)
spec_str = str(getattr(losses.sharding, 'spec', losses.sharding))
assert 'data' in spec_str, f'losses not sharded over data: {spec_str}'
tr._finish_round(pend)
print('MESH_EQUIV_OK', [round(r.loss, 5) for r in shard.history])
"""


def test_spec_mesh_sharded_equivalence():
    res = _run(_EQUIV_SCRIPT)
    assert "MESH_EQUIV_OK" in res.stdout, res.stdout + res.stderr


# --------------------------------------------------------------------------
# hierarchical_aggregate driven from real engine output vs the (E, C)
# einsum reference; shard_clients warn-once semantics.
# --------------------------------------------------------------------------

_AGG_SCRIPT = r"""
import warnings
import jax, jax.numpy as jnp, numpy as np
assert len(jax.devices()) == 8

from repro.configs import SMOKE_UNET, register_config
from repro.configs.base import FLConfig
from repro.experiment import (DataSpec, ExperimentSpec, make_clients,
                              register_dataset)
from repro.experiment.data import DatasetSpec
from repro.fl.baselines import FlatTrainer
from repro.launch.federated import hierarchical_aggregate, shard_clients

TINY = SMOKE_UNET.replace(name='ddpm-unet-tiny-agg', image_size=8,
                          base_channels=8, channel_mults=(1,),
                          num_res_blocks=1, attn_resolutions=())
register_config('ddpm-unet-tiny-agg', TINY, overwrite=True)
register_dataset('tiny-agg', DatasetSpec('tiny-agg', num_classes=4,
                                         image_size=8, samples_per_class=32),
                 overwrite=True)
spec = ExperimentSpec(
    name='agg', method='moon', model='ddpm-unet-tiny-agg',
    fl=FLConfig(num_clients=8, num_edges=2, local_epochs=1,
                edge_agg_every=1, cloud_agg_every=2, rounds=1,
                sparse_rounds=1, participation=1.0),
    data=DataSpec(dataset='tiny-agg', batch_size=8), prune=False)

# one vectorized MOON round leaves the 8 trained client models stacked
# in _prev_stack — genuine engine output, not synthetic data
clients, _, _ = make_clients(spec)
tr = FlatTrainer('moon', TINY, spec.fl, clients, rng_seed=0,
                 engine='vectorized')
rec = tr.run_round(1)
stacked = tr._prev_stack                     # (8, ...) per-client params
n = np.asarray([c.n_samples for c in clients], np.float32)
mu = np.asarray([l for l in np.full(8, rec.loss, np.float32)
                 * np.linspace(0.5, 1.5, 8)], np.float32)  # distinct scores
A, B = 1000.0, 0.0

mesh = jax.make_mesh((2, 4), ('pod', 'data'))
agg = jax.jit(lambda p: hierarchical_aggregate(
    p, jnp.asarray(n), jnp.asarray(mu), mesh=mesh, a=A, b=B,
    cloud_round=True))(stacked)

# (E, C) einsum reference: per-edge SH weights, then SH across edges
w = np.maximum(n + A * mu + B, 0.0).reshape(2, 4)
mu_ec = mu.reshape(2, 4)
w_edge = w / w.sum(1, keepdims=True)                        # (E, C)
n_e = w.sum(1)
mu_e = (mu_ec * w).sum(1) / w.sum(1)
w_c = np.maximum(n_e + A * mu_e + B, 0.0)
w_cloud = w_c / w_c.sum()                                   # (E,)
W = (w_cloud[:, None] * w_edge).reshape(8)                  # (E*C,)
for name, (got, leaf) in zip(
        [str(i) for i in range(len(jax.tree.leaves(agg)))],
        zip(jax.tree.leaves(agg), jax.tree.leaves(stacked))):
    leaf = np.asarray(leaf, np.float64)
    ref = np.tensordot(W, leaf, axes=(0, 0))
    # every client replica of the aggregate must equal the reference
    for c in range(8):
        np.testing.assert_allclose(np.asarray(got)[c], ref, atol=1e-5)

# --- shard_clients: non-dividing leading dim warns ONCE, scalars quiet
mesh8 = jax.make_mesh((8,), ('data',))
tree = {'bad': jnp.zeros((6, 3)), 'ok': jnp.zeros((8, 2)),
        'scalar': jnp.float32(1.0)}
with warnings.catch_warnings(record=True) as rec1:
    warnings.simplefilter('always')
    out = shard_clients(tree, mesh8, 'data')
msgs = [w for w in rec1 if 'UNSHARDED' in str(w.message)]
assert len(msgs) == 1, [str(w.message) for w in rec1]
assert 'data' in str(out['ok'].sharding.spec)
with warnings.catch_warnings(record=True) as rec2:
    warnings.simplefilter('always')
    shard_clients(tree, mesh8, 'data')
assert not [w for w in rec2 if 'UNSHARDED' in str(w.message)]
print('AGG_OK')
"""


def test_hierarchical_aggregate_from_engine_output():
    res = _run(_AGG_SCRIPT, devices=8)
    assert "AGG_OK" in res.stdout, res.stdout + res.stderr


# --------------------------------------------------------------------------
# Single-device guard rails (in-process: must NOT force a device count).
# --------------------------------------------------------------------------

def test_make_host_mesh_guards_indivisible():
    import jax

    from repro.launch.mesh import make_host_mesh
    n = len(jax.devices())
    with pytest.raises(ValueError, match="does not divide"):
        make_host_mesh(n + 1)
    with pytest.raises(ValueError, match="does not divide"):
        make_host_mesh(0)
    mesh = make_host_mesh(1)
    assert mesh.shape["model"] == 1 and mesh.shape["data"] == n


def test_make_spec_mesh_validation():
    from repro.launch.mesh import make_spec_mesh
    assert make_spec_mesh(None) is None
    assert make_spec_mesh({}) is None
    with pytest.raises(ValueError, match="sizes must be >= 1"):
        make_spec_mesh({"data": 0})
    with pytest.raises(ValueError, match="repro.launch.env.apply"):
        make_spec_mesh({"data": 1024})
    mesh = make_spec_mesh({"data": 1})
    assert mesh.axis_names == ("data",)


def test_launch_env_overlay():
    env = launch_env.host_env(8, tcmalloc=False, platform="cpu")
    assert "--xla_force_host_platform_device_count=8" in env["XLA_FLAGS"]
    assert env["JAX_PLATFORMS"] == "cpu"
    # a prior device-count flag is superseded, other flags survive
    merged = launch_env.merge_xla_flags(
        launch_env.xla_host_devices_flag(4),
        "--xla_cpu_foo=1 --xla_force_host_platform_device_count=512")
    assert merged.count("device_count") == 1
    assert "--xla_force_host_platform_device_count=4" in merged
    assert "--xla_cpu_foo=1" in merged
    child = launch_env.child_env(2)
    assert child["JAX_PLATFORMS"] == "cpu" and "PYTHONPATH" in child
