"""Diffusion substrate tests: schedules, forward process, DDIM sampler."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.diffusion import (ddim_sample, ddim_timesteps, ddpm_loss,
                             linear_schedule, cosine_schedule, q_sample)


def test_linear_schedule_shapes():
    s = linear_schedule(1000)
    assert s.betas.shape == (1000,)
    assert float(s.alpha_bars[-1]) < 0.01
    assert float(s.alpha_bars[0]) > 0.99
    assert np.all(np.diff(np.asarray(s.alpha_bars)) < 0)


def test_cosine_schedule_monotone():
    s = cosine_schedule(100)
    assert np.all(np.asarray(s.betas) >= 0)
    assert np.all(np.diff(np.asarray(s.alpha_bars)) < 0)


def test_q_sample_snr():
    """At t=0 the sample is nearly clean; at t=T-1 nearly pure noise."""
    s = linear_schedule(1000)
    rng = jax.random.PRNGKey(0)
    x0 = jnp.ones((4, 8, 8, 3))
    eps = jax.random.normal(rng, x0.shape)
    early = q_sample(s, x0, jnp.zeros(4, jnp.int32), eps)
    late = q_sample(s, x0, jnp.full(4, 999, jnp.int32), eps)
    assert float(jnp.mean(jnp.abs(early - x0))) < 0.1
    assert float(jnp.corrcoef(late.ravel(), eps.ravel())[0, 1]) > 0.95


def test_ddpm_loss_zero_for_perfect_predictor():
    s = linear_schedule(100)
    rng = jax.random.PRNGKey(0)
    x0 = jax.random.normal(rng, (2, 8, 8, 3))
    stash = {}
    def oracle(x_t, t):
        # invert q_sample given known x0
        abar = s.alpha_bars[t].reshape(-1, 1, 1, 1)
        return (x_t - jnp.sqrt(abar) * x0) / jnp.sqrt(1 - abar)
    loss = ddpm_loss(oracle, s, x0, rng)
    assert float(loss) < 1e-8


def test_ddim_timesteps():
    ts = ddim_timesteps(1000, 100)
    assert ts.shape == (100,)
    assert int(ts[0]) == 990 and int(ts[-1]) == 0


def test_ddim_sample_runs():
    s = linear_schedule(100)
    eps_fn = lambda x, t: jnp.zeros_like(x)
    out = ddim_sample(eps_fn, s, jax.random.PRNGKey(0), (2, 8, 8, 3),
                      num_steps=10)
    assert out.shape == (2, 8, 8, 3)
    assert not bool(jnp.any(jnp.isnan(out)))
