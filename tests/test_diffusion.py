"""Diffusion substrate tests: schedules, forward process, DDIM sampler."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.diffusion import (ddim_sample, ddim_step, ddim_timesteps,
                             ddpm_loss, linear_schedule, cosine_schedule,
                             q_sample)


def test_linear_schedule_shapes():
    s = linear_schedule(1000)
    assert s.betas.shape == (1000,)
    assert float(s.alpha_bars[-1]) < 0.01
    assert float(s.alpha_bars[0]) > 0.99
    assert np.all(np.diff(np.asarray(s.alpha_bars)) < 0)


def test_cosine_schedule_monotone():
    s = cosine_schedule(100)
    assert np.all(np.asarray(s.betas) >= 0)
    assert np.all(np.diff(np.asarray(s.alpha_bars)) < 0)


def test_q_sample_snr():
    """At t=0 the sample is nearly clean; at t=T-1 nearly pure noise."""
    s = linear_schedule(1000)
    rng = jax.random.PRNGKey(0)
    x0 = jnp.ones((4, 8, 8, 3))
    eps = jax.random.normal(rng, x0.shape)
    early = q_sample(s, x0, jnp.zeros(4, jnp.int32), eps)
    late = q_sample(s, x0, jnp.full(4, 999, jnp.int32), eps)
    assert float(jnp.mean(jnp.abs(early - x0))) < 0.1
    assert float(jnp.corrcoef(late.ravel(), eps.ravel())[0, 1]) > 0.95


def test_ddpm_loss_zero_for_perfect_predictor():
    s = linear_schedule(100)
    rng = jax.random.PRNGKey(0)
    x0 = jax.random.normal(rng, (2, 8, 8, 3))
    stash = {}
    def oracle(x_t, t):
        # invert q_sample given known x0
        abar = s.alpha_bars[t].reshape(-1, 1, 1, 1)
        return (x_t - jnp.sqrt(abar) * x0) / jnp.sqrt(1 - abar)
    loss = ddpm_loss(oracle, s, x0, rng)
    assert float(loss) < 1e-8


def test_ddim_timesteps():
    ts = ddim_timesteps(1000, 100)
    assert ts.shape == (100,)
    assert int(ts[0]) == 990 and int(ts[-1]) == 0


def test_ddim_timesteps_divisible_unchanged():
    """The paper's 1000/100 setting keeps the classic stride sub-sequence
    bit-for-bit (990, 980, ..., 0)."""
    ts = np.asarray(ddim_timesteps(1000, 100))
    np.testing.assert_array_equal(ts, np.arange(99, -1, -1) * 10)
    np.testing.assert_array_equal(np.asarray(ddim_timesteps(100, 100)),
                                  np.arange(99, -1, -1))


@pytest.mark.parametrize("T,S", [(1000, 7), (1000, 600), (100, 33),
                                 (10, 3), (1000, 999)])
def test_ddim_timesteps_non_divisible(T, S):
    """Non-divisible counts previously truncated the trajectory top
    (1000/600 started at t=599); now the first sampled t is always the
    final training timestep and spacing is even over [0, T-1]."""
    ts = np.asarray(ddim_timesteps(T, S))
    assert ts.shape == (S,)
    assert ts[0] == T - 1 and ts[-1] == 0
    assert np.all(np.diff(ts) < 0)               # strictly descending
    gaps = -np.diff(ts)
    assert gaps.max() - gaps.min() <= 1          # even spacing


def test_ddim_timesteps_single_and_validation():
    assert np.asarray(ddim_timesteps(1000, 1)).tolist() == [999]
    with pytest.raises(ValueError):
        ddim_timesteps(100, 0)
    with pytest.raises(ValueError):
        ddim_timesteps(100, 101)


def test_ddim_sample_runs():
    s = linear_schedule(100)
    eps_fn = lambda x, t: jnp.zeros_like(x)
    out = ddim_sample(eps_fn, s, jax.random.PRNGKey(0), (2, 8, 8, 3),
                      num_steps=10)
    assert out.shape == (2, 8, 8, 3)
    assert not bool(jnp.any(jnp.isnan(out)))


def test_ddim_eta0_invariant_to_rng():
    """The deterministic sampler consumes no randomness beyond the
    prior: with x_init supplied, the input rng cannot matter."""
    s = linear_schedule(100)
    eps_fn = lambda x, t: 0.1 * x
    x_init = jax.random.normal(jax.random.PRNGKey(7), (2, 8, 8, 3))
    a = ddim_sample(eps_fn, s, jax.random.PRNGKey(0), (2, 8, 8, 3),
                    num_steps=10, x_init=x_init)
    b = ddim_sample(eps_fn, s, jax.random.PRNGKey(123), (2, 8, 8, 3),
                    num_steps=10, x_init=x_init)
    assert float(jnp.max(jnp.abs(a - b))) == 0.0


def test_ddim_step_scan_matches_sample():
    """Driving ddim_step by hand (per-sample timesteps) reproduces the
    whole-trajectory sampler."""
    s = linear_schedule(100)
    eps_fn = lambda x, t: 0.1 * x
    rng = jax.random.PRNGKey(3)
    out = ddim_sample(eps_fn, s, rng, (2, 8, 8, 3), num_steps=5)
    _, rng_init = jax.random.split(rng)
    x = jax.random.normal(rng_init, (2, 8, 8, 3), jnp.float32)
    ts = ddim_timesteps(100, 5)
    ts_prev = jnp.concatenate([ts[1:], jnp.full((1,), -1, ts.dtype)])
    for i in range(5):
        t = jnp.full((2,), ts[i], jnp.int32)
        x = ddim_step(x, t, ts_prev[i], eps_fn(x, t), s, eta=0.0)
    np.testing.assert_allclose(np.asarray(x), np.asarray(out),
                               rtol=0, atol=1e-6)


def test_ddim_eta_pos_stream_compat():
    """eta>0 keeps the pre-refactor RNG stream: one split + one z draw
    per step, drawn before the update — locked against an inline
    re-implementation of the old sampler."""
    T, S, eta, shape = 100, 6, 0.5, (2, 8, 8, 3)
    s = linear_schedule(T)
    eps_fn = lambda x, t: 0.1 * x
    out = ddim_sample(eps_fn, s, jax.random.PRNGKey(5), shape,
                      num_steps=S, eta=eta)

    rng = jax.random.PRNGKey(5)
    rng, rng_init = jax.random.split(rng)
    x = jax.random.normal(rng_init, shape, jnp.float32)
    ts = np.asarray(ddim_timesteps(T, S))
    for i in range(S):
        t = jnp.full((shape[0],), int(ts[i]), jnp.int32)
        eps = eps_fn(x, t)
        abar_t = s.alpha_bars[int(ts[i])]
        abar_prev = s.alpha_bars[int(ts[i + 1])] if i + 1 < S else 1.0
        x0 = jnp.clip((x - jnp.sqrt(1 - abar_t) * eps) / jnp.sqrt(abar_t),
                      -1.0, 1.0)
        sigma = eta * jnp.sqrt((1 - abar_prev) / (1 - abar_t)) \
            * jnp.sqrt(1 - abar_t / abar_prev)
        rng, rng_z = jax.random.split(rng)
        z = jax.random.normal(rng_z, shape, jnp.float32)
        x = jnp.sqrt(abar_prev) * x0 \
            + jnp.sqrt(jnp.maximum(1 - abar_prev - sigma ** 2, 0.0)) * eps \
            + sigma * z
    np.testing.assert_allclose(np.asarray(x), np.asarray(out),
                               rtol=0, atol=1e-5)
