"""Expert-parallel MoE (shard_map all-to-all) vs the pjit baseline.

Runs on a forced 8-device CPU mesh in a subprocess so the main test
process keeps its single-device view.
"""
import subprocess
import sys

import pytest

# JAX_PLATFORMS=cpu is load-bearing: on images that bundle libtpu,
# dropping it makes backend discovery poll the GCP metadata server with
# 30-retry backoff — the subprocess hangs for minutes before any test
# code runs.
_ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
        "JAX_PLATFORMS": "cpu"}


def _run(script: str) -> subprocess.CompletedProcess:
    return subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=600,
                          env=_ENV)

_SCRIPT = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import jax, jax.numpy as jnp
from repro.configs.base import MoEConfig
from repro.models.moe import init_moe, apply_moe
from repro.launch.expert_parallel import apply_moe_ep

mesh = jax.make_mesh((2, 4), ('data', 'model'))
moe = MoEConfig(num_experts=8, experts_per_token=2, d_expert=32,
                capacity_factor=8.0)
rng = jax.random.PRNGKey(0)
p = init_moe(rng, 64, moe, activation='silu')
x = jax.random.normal(jax.random.fold_in(rng, 1), (2, 32, 64))

base, _ = apply_moe(p, x, moe, activation='silu')
run_ep = lambda p, x: apply_moe_ep(p, x, moe, mesh=mesh, ep_axes=('model',),
                                   token_axes=('data', 'model'),
                                   activation='silu', capacity_mult=8.0)
ep, _ = jax.jit(run_ep)(p, x)
diff = float(jnp.max(jnp.abs(base - ep)))
assert diff < 1e-5, f'EP mismatch: {diff}'

g = jax.grad(lambda p: jnp.sum(run_ep(p, x)[0] ** 2))(p)
gsum = float(sum(jnp.sum(jnp.abs(l)) for l in jax.tree.leaves(g)))
assert gsum > 0, 'no gradient through EP dispatch'

# E_loc == 1 path (one expert per device)
moe1 = MoEConfig(num_experts=8, experts_per_token=2, d_expert=32,
                 capacity_factor=8.0)
ep1, _ = jax.jit(lambda p, x: apply_moe_ep(
    p, x, moe1, mesh=mesh, ep_axes=('data', 'model'),
    token_axes=('data', 'model'), activation='silu',
    capacity_mult=8.0))(p, x)
diff1 = float(jnp.max(jnp.abs(base - ep1)))
assert diff1 < 1e-5, f'E_loc=1 mismatch: {diff1}'
print('EP_OK', diff, diff1)
"""


def test_expert_parallel_matches_baseline():
    res = _run(_SCRIPT)
    assert "EP_OK" in res.stdout, res.stdout + res.stderr


_FED_SCRIPT = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import jax, jax.numpy as jnp, numpy as np
from repro.launch.federated import hierarchical_aggregate
mesh = jax.make_mesh((2, 4), ('pod', 'data'))
params = {'w': jnp.arange(8, dtype=jnp.float32).reshape(8, 1)}
n = jnp.full((8,), 10.0)
mu = jnp.full((8,), 2.0)
# equal weights -> edge tier = per-pod mean; cloud tier = global mean
out_edge = jax.jit(lambda p: hierarchical_aggregate(
    p, n, mu, mesh=mesh, cloud_round=False))(params)
vals = np.unique(np.asarray(out_edge['w']))
assert len(vals) == 2, vals            # two pods, two distinct means
out_cloud = jax.jit(lambda p: hierarchical_aggregate(
    p, n, mu, mesh=mesh, cloud_round=True))(params)
vals_c = np.unique(np.asarray(out_cloud['w']).round(5))
assert len(vals_c) == 1 and abs(vals_c[0] - 3.5) < 1e-5, vals_c
print('FED_OK')
"""


def test_hierarchical_aggregate_tpu_mapping():
    res = _run(_FED_SCRIPT)
    assert "FED_OK" in res.stdout, res.stdout + res.stderr
