"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.aggregation import fedavg_weights, sh_weights, weighted_average
from repro.core.selection import selection_probabilities
from repro.core.sh_score import (AccumulatedDistribution, label_distribution,
                                 sh_score, uniform_target)
from repro.core.pruning.masks import kept_count
from repro.core.pruning.groups import PruneGroup
from repro.fl.comm import CommModel


dists = st.lists(st.floats(0.001, 1.0), min_size=2, max_size=12).map(
    lambda xs: np.asarray(xs) / np.sum(xs))


@given(dists)
@settings(max_examples=100, deadline=None)
def test_sh_score_bounds(q):
    """mu in [2 - sqrt(2), 2] for any probability vector."""
    mu = sh_score(q)
    assert 2 - np.sqrt(2) - 1e-9 <= mu <= 2 + 1e-9


@given(dists)
@settings(max_examples=50, deadline=None)
def test_sh_uniform_dominates(q):
    assert sh_score(uniform_target(len(q))) >= sh_score(q) - 1e-12


@given(st.lists(st.integers(1, 10_000), min_size=2, max_size=8),
       st.lists(st.floats(0.6, 2.0), min_size=2, max_size=8),
       st.floats(0.0, 1e5), st.floats(0.0, 1e3))
@settings(max_examples=100, deadline=None)
def test_sh_weights_simplex(counts, mus, a, b):
    n = min(len(counts), len(mus))
    w = sh_weights(counts[:n], mus[:n], a=a, b=b)
    assert np.all(w >= -1e-12)
    assert np.isclose(w.sum(), 1.0)


@given(st.integers(0, 9), st.integers(1, 500))
@settings(max_examples=50, deadline=None)
def test_label_distribution_is_distribution(cls, n):
    labels = np.full(n, cls)
    q = label_distribution(labels, 10)
    assert np.isclose(q.sum(), 1.0)
    assert q[cls] == 1.0


@given(st.lists(st.tuples(dists, st.integers(1, 1000)), min_size=1,
                max_size=10))
@settings(max_examples=50, deadline=None)
def test_accumulated_distribution_matches_pooled(updates):
    """Eq. 19 accumulation == pooling all samples directly."""
    k = len(updates[0][0])
    updates = [(q, n) for q, n in updates if len(q) == k]
    acc = AccumulatedDistribution(k)
    total = np.zeros(k)
    n_tot = 0
    for q, n in updates:
        acc.update(q, n)
        total += q * n
        n_tot += n
    np.testing.assert_allclose(acc.q, total / n_tot, rtol=1e-9)


@given(st.integers(2, 6), st.floats(1.0, 1e5))
@settings(max_examples=50, deadline=None)
def test_selection_probabilities_simplex(n_edges, a):
    edges = []
    rng = np.random.default_rng(0)
    for _ in range(n_edges):
        e = AccumulatedDistribution(4)
        e.update(rng.dirichlet(np.ones(4)), int(rng.integers(1, 1000)))
        edges.append(e)
    p = selection_probabilities(edges, rng.dirichlet(np.ones(4)), 100,
                                a=a, b=0.0)
    assert np.isclose(p.sum(), 1.0)
    assert np.all(p >= 0)


@given(st.integers(8, 4096), st.floats(0.0, 0.95))
@settings(max_examples=100, deadline=None)
def test_kept_count_valid(size, ratio):
    g = PruneGroup(name="g", size=size, members=(), unit="channel")
    k = kept_count(g, ratio)
    assert 1 <= k <= size


@given(st.floats(1e3, 1e9), st.integers(1, 100))
@settings(max_examples=50, deadline=None)
def test_comm_cost_monotone(volume, clients):
    cm = CommModel()
    assert cm.flat_fl_round(volume, clients) > 0
    assert cm.hfl_round(volume, clients, 2, cloud_round=False) \
        < cm.hfl_round(volume, clients, 2, cloud_round=True)
    # HFL round without cloud sync is cheaper than flat FL (the paper's
    # core efficiency claim: d_e << d_c)
    assert cm.hfl_round(volume, clients, 2, cloud_round=False) \
        < cm.flat_fl_round(volume, clients)


@given(st.lists(st.floats(0.1, 10.0), min_size=2, max_size=5))
@settings(max_examples=50, deadline=None)
def test_weighted_average_convexity(weights):
    """Aggregated scalar lies in the convex hull of the inputs."""
    vals = np.linspace(-1.0, 1.0, len(weights))
    trees = [{"x": np.full((3,), v, np.float32)} for v in vals]
    out = weighted_average(trees, weights)
    x = np.asarray(out["x"])
    assert np.all(x >= vals.min() - 1e-6) and np.all(x <= vals.max() + 1e-6)


# ---------------------------------------------------------------------------
# Round-engine substrate: ctx stacking, padding masks, persistent Adam.
# ---------------------------------------------------------------------------

_shapes = st.lists(st.tuples(st.integers(1, 3), st.integers(1, 4)),
                   min_size=1, max_size=3)


@given(_shapes, st.integers(1, 5))
@settings(max_examples=25, deadline=None)
def test_ctx_stacking_roundtrips(shapes, n):
    """stack_trees/unstack_tree round-trip arbitrary pytree shapes,
    including nesting — the substrate of the engine's stacked ctx."""
    from repro.fl.engine import stack_trees, unstack_tree
    trees = [{"a": {f"k{j}": np.full(s, 10 * i + j, np.float32)
                    for j, s in enumerate(shapes)},
              "b": np.full((2,), float(i), np.float32)}
             for i in range(n)]
    back = unstack_tree(stack_trees(trees), n)
    assert len(back) == n
    for t, r in zip(trees, back):
        for x, y in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@given(st.integers(1, 6), st.integers(0, 4), st.integers(0, 2 ** 16))
@settings(max_examples=15, deadline=None)
def test_stacked_epochs_padding_never_leaks(n_real, pad, seed):
    """For random ragged client sizes, the masked scan yields params
    bitwise-identical to an unpadded run: padded steps never leak into
    params, opt state, or the loss mean."""
    from repro.fl.engine import make_train_one
    from repro.optim import adam_init
    rng = np.random.default_rng(seed)
    xs = rng.normal(size=(n_real + pad, 4)).astype(np.float32)
    xs[n_real:] = xs[n_real - 1]            # stacked_epochs-style padding
    valid = np.arange(n_real + pad) < n_real
    params = {"w": jnp.asarray(rng.normal(size=(4,)), jnp.float32)}
    loss_fn = lambda p, batch, r, ctx: jnp.mean((batch["x"] - p["w"]) ** 2)
    train_one = make_train_one(loss_fn, lr=0.1)
    opt = adam_init(params)
    key = jax.random.PRNGKey(seed)
    p_pad, o_pad, l_pad = train_one(params, opt, {"x": jnp.asarray(xs)},
                                    jnp.asarray(valid), key, {}, True)
    p_ref, o_ref, l_ref = train_one(params, opt,
                                    {"x": jnp.asarray(xs[:n_real])},
                                    jnp.ones(n_real, bool), key, {}, False)
    np.testing.assert_array_equal(np.asarray(p_pad["w"]),
                                  np.asarray(p_ref["w"]))
    np.testing.assert_array_equal(np.asarray(o_pad.mu["w"]),
                                  np.asarray(o_ref.mu["w"]))
    assert int(o_pad.step) == int(o_ref.step) == n_real
    np.testing.assert_allclose(float(l_pad), float(l_ref), rtol=1e-6)


@given(st.integers(2, 8), st.integers(1, 8), st.integers(0, 2 ** 16))
@settings(max_examples=25, deadline=None)
def test_persistent_adam_gather_scatter(n, k, seed):
    """Gather/scatter by a participation selection is (i) a no-op when
    rows are written back unchanged, (ii) invariant to permuting the
    selection, (iii) leaves non-participating clients untouched."""
    from repro.fl.engine import stacked_adam_init, tree_gather, tree_scatter
    rng = np.random.default_rng(seed)
    k = min(k, n)
    stack = stacked_adam_init({"w": np.zeros((3,), np.float32)}, n)
    fill = lambda leaf: (jnp.arange(np.prod(leaf.shape), dtype=leaf.dtype)
                         .reshape(leaf.shape))
    stack = jax.tree.map(fill, stack)
    idx = rng.choice(n, size=k, replace=False)

    rows = tree_gather(stack, idx)
    noop = tree_scatter(stack, idx, rows)
    for x, y in zip(jax.tree.leaves(stack), jax.tree.leaves(noop)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    new_rows = jax.tree.map(lambda leaf: leaf + 1, rows)
    perm = rng.permutation(k)
    out1 = tree_scatter(stack, idx, new_rows)
    out2 = tree_scatter(stack, idx[perm],
                        jax.tree.map(lambda leaf: leaf[perm], new_rows))
    others = np.setdiff1d(np.arange(n), idx)
    for x, y, base in zip(jax.tree.leaves(out1), jax.tree.leaves(out2),
                          jax.tree.leaves(stack)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        np.testing.assert_array_equal(np.asarray(x)[others],
                                      np.asarray(base)[others])
