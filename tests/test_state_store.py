"""Host-resident stacked client state (``state_store``): the store
resolver, numpy-aware gather/scatter, and trainer-level equivalence —
a host-store vectorized run must match the device-store sequential
reference exactly, including through checkpoint restore."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SMOKE_UNET, register_config
from repro.configs.base import FLConfig
from repro.experiment import (DataSpec, ExperimentSpec, register_dataset,
                              run_spec)
from repro.experiment.data import DatasetSpec
from repro.fl.engine import (resolve_store, stacked_adam_init, stacked_zeros,
                             store_tree, tree_gather, tree_scatter)

TINY_UNET = SMOKE_UNET.replace(name="ddpm-unet-tiny-store", image_size=8,
                               base_channels=8, channel_mults=(1,),
                               num_res_blocks=1, attn_resolutions=())
register_config("ddpm-unet-tiny-store", TINY_UNET, overwrite=True)
register_dataset("tiny-store",
                 DatasetSpec("tiny-store", num_classes=4, image_size=8,
                             samples_per_class=32), overwrite=True)

BASE = ExperimentSpec(
    name="store", method="fedphd", model="ddpm-unet-tiny-store",
    fl=FLConfig(num_clients=8, num_edges=2, local_epochs=1,
                edge_agg_every=1, cloud_agg_every=2, rounds=2,
                sparse_rounds=2, sh_a=1000.0, participation=0.5),
    # shards partition: non-IID (1 class per client) but UNIFORM batch
    # shapes — the strict vectorized engine refuses ragged clients, and
    # the equivalence below must exercise the vectorized host-store path
    data=DataSpec(dataset="tiny-store", partition="shards",
                  classes_per_client=1, batch_size=8),
    persistent_opt=True, prune=False)


def test_resolve_store():
    assert resolve_store("device", 100000, 1) == "device"
    assert resolve_store("host", 2, 2) == "host"
    # auto: host only for large, mostly-idle populations — the 10k @ 1%
    # participation regime must fit without N device-resident stacks
    assert resolve_store("auto", 10_000, 100) == "host"
    assert resolve_store("auto", 256, 32) == "host"
    assert resolve_store("auto", 255, 31) == "device"   # below floor
    assert resolve_store("auto", 256, 64) == "device"   # too dense
    assert resolve_store("auto", 8, 8) == "device"
    with pytest.raises(ValueError, match="unknown state store"):
        resolve_store("gpu", 8, 8)


def test_host_stack_gather_scatter_roundtrip():
    tree = {"w": jnp.ones((3, 2)), "b": jnp.zeros((4,))}
    stack = stacked_zeros(tree, 10, host=True)
    assert isinstance(stack["w"], np.ndarray)
    assert stack["w"].shape == (10, 3, 2)
    rows = tree_gather(stack, np.array([2, 7]))
    assert isinstance(rows["w"], np.ndarray) and rows["w"].shape == (2, 3, 2)
    # scatter device-computed rows back into the numpy stack in place
    new = {"w": jnp.full((2, 3, 2), 5.0), "b": jnp.full((2, 4), -1.0)}
    out = tree_scatter(stack, np.array([2, 7]), new)
    assert out["w"] is stack["w"]           # in-place, no copy of (N,...)
    np.testing.assert_array_equal(stack["w"][2], 5.0 * np.ones((3, 2)))
    np.testing.assert_array_equal(stack["b"][7], -np.ones(4))
    np.testing.assert_array_equal(stack["w"][0], np.zeros((3, 2)))
    # single-row (int index) gather drops the leading axis
    row = tree_gather(stack, 2)
    assert row["w"].shape == (3, 2)


def test_host_adam_stack_staging():
    params = {"w": jnp.ones((2, 2))}
    stack = stacked_adam_init(params, 6, host=True)
    assert isinstance(stack.mu["w"], np.ndarray)
    rows = tree_gather(stack, np.array([0, 3]))
    staged = store_tree(rows, "device")
    assert isinstance(staged.mu["w"], jnp.ndarray)    # donation-safe
    back = store_tree(staged, "host")
    assert isinstance(back.mu["w"], np.ndarray)


def test_fedphd_host_store_matches_device_reference():
    """Vectorized engine + host store vs sequential engine + device
    store, dirichlet alpha=0.5, persistent Adam: identical trajectories
    — the participating-slice staging must be numerically invisible."""
    ref = run_spec(BASE.replace(engine="sequential",
                                state_store="device"), rounds=2)
    host = run_spec(BASE.replace(engine="vectorized",
                                 state_store="host"), rounds=2)
    assert host.trainer._store == "host"
    for a, b in zip(ref.history, host.history):
        assert a.selected == b.selected
        assert a.comm_gb == b.comm_gb
        assert np.isclose(a.loss, b.loss, atol=1e-4)
    for x, y in zip(jax.tree.leaves(ref.params),
                    jax.tree.leaves(host.params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-5)
    # the opt stack really lives on host
    assert isinstance(jax.tree.leaves(host.trainer._opt_stack.mu)[0],
                      np.ndarray)


def test_scaffold_host_store_matches_device(tmp_path):
    """SCAFFOLD is the stack-heaviest flat method (control variates +
    Adam): host-store vectorized vs device-store sequential, THROUGH a
    kill-and-resume checkpoint round-trip on the host-store side."""
    spec = BASE.replace(method="scaffold", aggregation="fedavg",
                        selection="sh")
    ref = run_spec(spec.replace(engine="sequential", state_store="device"),
                   rounds=2)
    ckpt = str(tmp_path / "ckpt.npz")
    h1 = run_spec(spec.replace(engine="vectorized", state_store="host"),
                  rounds=1, ckpt=ckpt)
    assert len(h1.history) == 1
    host = run_spec(None, resume=True, ckpt=ckpt, rounds=2)
    assert host.trainer._store == "host"
    assert isinstance(
        jax.tree.leaves(host.trainer._c_local_stack)[0], np.ndarray)
    for a, b in zip(ref.history, host.history):
        assert a.selected == b.selected
        assert np.isclose(a.loss, b.loss, atol=1e-4)
    for x, y in zip(jax.tree.leaves(ref.params),
                    jax.tree.leaves(host.params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-5)


def test_spec_state_store_roundtrip():
    spec = BASE.replace(state_store="host")
    again = ExperimentSpec.from_json(spec.to_json())
    assert again.state_store == "host" and again == spec
