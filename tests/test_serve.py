"""Serving-layer tests: continuous-batching DDIM server, masked-serving
parity, checkpoint artifact loading, masked MACs accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint
from repro.configs import SMOKE_UNET
from repro.configs.base import config_to_dict
from repro.core import pruning as P
from repro.diffusion import ddim_sample
from repro.diffusion.sampling import sample_images
from repro.diffusion.schedule import linear_schedule
from repro.metrics.flops import unet_macs
from repro.models import model
from repro.models.unet import apply_unet
from repro.serve import (DiffusionServer, Request, load_serving_artifact,
                         masks_for_ratio)

CFG = SMOKE_UNET
SHAPE1 = (1, CFG.image_size, CFG.image_size, CFG.in_channels)


@pytest.fixture(scope="module")
def unet_params():
    return model.init(jax.random.PRNGKey(0), CFG)


def _standalone(params, seed, *, steps, eta=0.0, masks=None):
    """Reference: one request sampled outside the server."""
    sched = linear_schedule(CFG.diffusion_steps)
    eps_fn = lambda x, t: apply_unet(params, CFG, x, t, masks=masks)
    out = ddim_sample(eps_fn, sched, jax.random.PRNGKey(seed), SHAPE1,
                      num_steps=steps, eta=eta)
    return np.asarray(out[0])


# -- continuous batching ------------------------------------------------------

def test_server_matches_standalone_mixed_depths(unet_params):
    """4 requests through 2 slots: refilled slots serve later requests at
    different depths than their neighbours, yet every output is bitwise
    the standalone ddim_sample for that request's seed — and the tick
    never recompiles."""
    srv = DiffusionServer(unet_params, CFG, slots=2, num_steps=4)
    reqs = [Request(rid=i, seed=100 + i) for i in range(4)]
    res = srv.run(reqs)
    assert sorted(res.images) == [0, 1, 2, 3]
    assert srv.compile_count() == 1, "slot occupancy/depth must be data"
    for r in reqs:
        want = _standalone(unet_params, r.seed, steps=4)
        np.testing.assert_array_equal(res.images[r.rid], want)


def test_server_eta_pos_matches_standalone(unet_params):
    """eta>0: the per-slot z stream reproduces ddim_sample's
    split-then-draw sequence per request, slot history irrelevant."""
    srv = DiffusionServer(unet_params, CFG, slots=2, num_steps=3, eta=1.0)
    res = srv.run([Request(rid=i, seed=7 + i) for i in range(3)])
    assert srv.compile_count() == 1
    for i in range(3):
        want = _standalone(unet_params, 7 + i, steps=3, eta=1.0)
        np.testing.assert_array_equal(res.images[i], want)


def test_server_kill_then_refill_isolated(unet_params):
    """A killed request's slot must serve its successor exactly as a
    fresh server would — no leakage of the dead request's state."""
    srv = DiffusionServer(unet_params, CFG, slots=1, num_steps=4)
    srv.submit(Request(rid=0, seed=1))
    srv.step()                                   # rid 0 partway through
    assert srv.kill(0)
    assert not srv.kill(0)                       # already gone
    res = srv.run([Request(rid=1, seed=2)])
    assert list(res.images) == [1]
    np.testing.assert_array_equal(res.images[1],
                                  _standalone(unet_params, 2, steps=4))


def test_server_queue_faults_degrade_gracefully(unet_params):
    """A request source that raises between requests is recorded as a
    fault; every request it does manage to yield still gets served."""
    reqs = iter([Request(rid=0, seed=3), None, Request(rid=1, seed=4)])
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] % 2 == 0:
            raise ConnectionError("queue hiccup")
        try:
            return next(reqs)
        except StopIteration:
            raise StopIteration

    srv = DiffusionServer(unet_params, CFG, slots=2, num_steps=3)
    res = srv.run(flaky, idle_limit=5)
    assert sorted(res.images) == [0, 1]
    assert any("fault" in f for f in res.faults)
    for rid, seed in ((0, 3), (1, 4)):
        np.testing.assert_array_equal(res.images[rid],
                                      _standalone(unet_params, seed, steps=3))


def test_server_idle_limit_stops_empty_source(unet_params):
    """A source that only times out (yields None) ends the run after
    idle_limit polls with the condition recorded, not a hang."""
    srv = DiffusionServer(unet_params, CFG, slots=2, num_steps=3)
    res = srv.run(lambda: None, idle_limit=3)
    assert res.images == {}
    assert any("idle limit" in f for f in res.faults)


def test_server_fault_limit_stops_dead_source(unet_params):
    def dead():
        raise ConnectionError("down")

    srv = DiffusionServer(unet_params, CFG, slots=2, num_steps=3)
    res = srv.run(dead, fault_limit=3)
    assert res.images == {}
    assert any("fault limit" in f for f in res.faults)


# -- masked serving parity ----------------------------------------------------

def _masks_and_zeroed(params, as_numpy):
    groups = P.build_groups(CFG, params)
    masks = P.make_masks(P.l2_scores(params, groups), groups, 0.44)
    zeroed = P.apply_masks(params, groups, masks)
    if as_numpy:
        masks = {k: np.asarray(v) for k, v in masks.items()}
    return masks, zeroed


@pytest.mark.parametrize("backend,as_numpy,atol", [
    ("xla", False, 0.0),      # training-time multiply-by-zero path
    ("ref", False, 0.0),
    ("xla", True, 1e-5),      # static gather-GEMM specialization
    ("pallas", True, 1e-5),
])
def test_masked_sampling_equals_prezeroed_dense(unet_params, backend,
                                                as_numpy, atol):
    """DDIM trajectories with masks= must match sampling from
    apply_masks-pre-zeroed dense weights: exactly for device masks
    (same multiplies in the same order), atol 1e-5 for the static
    host-mask specialization (reduced GEMMs reassociate the sums)."""
    cfg = CFG.replace(backend=backend)
    masks, zeroed = _masks_and_zeroed(unet_params, as_numpy)
    steps, n = (2, 1) if backend == "pallas" else (3, 2)
    got = sample_images(unet_params, cfg, n=n, steps=steps, seed=11,
                        masks=masks)
    want = sample_images(zeroed, cfg, n=n, steps=steps, seed=11)
    np.testing.assert_allclose(got, want, rtol=0, atol=atol)


def test_server_masked_matches_prezeroed_dense(unet_params):
    """The serving hot path (static host masks) agrees with a dense
    server over pre-zeroed weights, request by request."""
    masks, zeroed = _masks_and_zeroed(unet_params, as_numpy=True)
    reqs = [Request(rid=i, seed=50 + i) for i in range(3)]
    got = DiffusionServer(unet_params, CFG, slots=2, num_steps=3,
                          masks=masks).run(reqs)
    want = DiffusionServer(zeroed, CFG, slots=2, num_steps=3).run(reqs)
    for r in reqs:
        np.testing.assert_allclose(got.images[r.rid], want.images[r.rid],
                                   rtol=0, atol=1e-5)


# -- checkpoint artifact ------------------------------------------------------

def test_load_serving_artifact_roundtrip(unet_params, tmp_path):
    """Both metadata flavours — trainer cfg dict and runner spec — load
    into a servable (params, cfg) that samples identically to the
    in-memory params."""
    p_cfg = str(tmp_path / "ckpt_cfg.npz")
    checkpoint.save(p_cfg, {"params": unet_params},
                    {"cfg": config_to_dict(CFG)})
    p_spec = str(tmp_path / "ckpt_spec.npz")
    checkpoint.save(p_spec, {"params": unet_params},
                    {"spec": {"model": "ddpm-unet-smoke"}})
    want = _standalone(unet_params, 9, steps=3)
    for path in (p_cfg, p_spec):
        params, cfg, _ = load_serving_artifact(path)
        assert cfg.arch_type == "unet"
        assert cfg.image_size == CFG.image_size
        res = DiffusionServer(params, cfg, slots=1, num_steps=3).run(
            [Request(rid=0, seed=9)])
        np.testing.assert_array_equal(res.images[0], want)


def test_load_serving_artifact_rejects_token_models(rng, tmp_path):
    from repro.configs import smoke_variant
    cfg = smoke_variant("gemma2-2b")
    params = model.init(rng, cfg)
    path = str(tmp_path / "tok.npz")
    checkpoint.save(path, {"params": params}, {"cfg": config_to_dict(cfg)})
    with pytest.raises(ValueError, match="arch_type"):
        load_serving_artifact(path)


def test_load_serving_artifact_requires_params(tmp_path):
    path = str(tmp_path / "empty.npz")
    checkpoint.save(path, {"stats": {"x": np.zeros(3)}}, {})
    with pytest.raises(ValueError, match="params"):
        load_serving_artifact(path)


def test_masks_for_ratio_static_and_sparse(unet_params):
    masks = masks_for_ratio(unet_params, CFG, 0.44)
    assert masks and all(isinstance(m, np.ndarray) for m in masks.values())
    kept = sum(int(m.sum()) for m in masks.values())
    total = sum(m.size for m in masks.values())
    assert kept < total                          # actually pruned
    with pytest.raises(ValueError):
        masks_for_ratio(unet_params, CFG, 0.44, criterion="nope")


# -- honest FLOPs -------------------------------------------------------------

def test_unet_macs_masked_accounting(unet_params):
    """Masked MACs count only kept channels: all-ones masks reproduce
    the dense figure exactly; 44% pruning lands strictly below dense and
    above the naive density-squared lower bound's floor of zero."""
    dense = unet_macs(unet_params, CFG.image_size)
    masks = masks_for_ratio(unet_params, CFG, 0.44)
    ones = {k: np.ones_like(m) for k, m in masks.items()}
    assert unet_macs(unet_params, CFG.image_size, masks=ones) == dense
    pruned = unet_macs(unet_params, CFG.image_size, masks=masks)
    assert 0 < pruned < dense
