"""k8s sweep executor against the in-memory FakeCluster: Job rendering,
the worker entrypoint, grid equivalence with the sequential executor,
preemption-resume, artifact reconciliation, and quarantine."""
import json
import os

import pytest

from repro.configs import SMOKE_UNET, register_config
from repro.configs.base import FLConfig
from repro.experiment import (DataSpec, ExperimentSpec, FakeCluster,
                              JobStatus, K8sExecutor, SweepSpec,
                              register_dataset, resolve_executor, run_sweep)
from repro.experiment.cluster import (PREEMPTED_EXIT, job_name, load_result,
                                      render_job, run_result_path,
                                      run_spec_path, worker_main)
from repro.experiment.data import DatasetSpec
from repro.experiment.sweep import (EXECUTORS, ProcessExecutor,
                                    SequentialExecutor)

TINY_UNET = SMOKE_UNET.replace(name="ddpm-unet-tiny-k8s", image_size=8,
                               base_channels=8, channel_mults=(1,),
                               num_res_blocks=1, attn_resolutions=())
register_config("ddpm-unet-tiny-k8s", TINY_UNET, overwrite=True)
register_dataset("tiny-k8s", DatasetSpec("tiny-k8s", num_classes=4,
                                         image_size=8, samples_per_class=32),
                 overwrite=True)

BASE = ExperimentSpec(
    name="k8s-base", method="fedavg", model="ddpm-unet-tiny-k8s",
    fl=FLConfig(num_clients=4, num_edges=1, local_epochs=1,
                edge_agg_every=1, cloud_agg_every=2, rounds=2,
                sparse_rounds=2, sh_a=1000.0),
    data=DataSpec(dataset="tiny-k8s", batch_size=8),
    engine="sequential", prune=False)

GRID = SweepSpec(name="k8s-grid", base=BASE,
                 axes={"seed": [0, 1], "lr": [1e-4, 2e-4]})


def fake_exec(**kw):
    """A FakeCluster-backed executor (poll_s=0: no scheduler latency)."""
    cluster = kw.pop("cluster", None) or FakeCluster()
    return K8sExecutor(cluster=cluster, poll_s=0.0, **kw), cluster


@pytest.fixture(scope="module")
def seq_manifest(tmp_path_factory):
    """The sequential-executor reference manifest for GRID."""
    out = tmp_path_factory.mktemp("seq")
    res = run_sweep(GRID, str(out))
    assert res.complete
    return res.manifest


# -- validation / resolution -------------------------------------------------

def test_executor_registry_and_validation(tmp_path):
    assert EXECUTORS == ("sequential", "process", "k8s")
    with pytest.raises(ValueError, match="executor 'slurm' not in"):
        run_sweep(GRID, str(tmp_path), executor="slurm")
    with pytest.raises(TypeError, match="Executor-like"):
        resolve_executor(object())
    assert isinstance(resolve_executor("sequential"), SequentialExecutor)
    assert isinstance(resolve_executor("process"), ProcessExecutor)
    exe = resolve_executor("k8s", max_workers=3)
    assert isinstance(exe, K8sExecutor) and exe.max_workers == 3
    injected, _ = fake_exec()
    assert resolve_executor(injected) is injected


def test_capability_rejections(tmp_path):
    exe, _ = fake_exec()
    with pytest.raises(ValueError, match="eval_fn cannot cross"):
        run_sweep(GRID, str(tmp_path), executor=exe,
                  eval_fn=lambda p, c, r: {})
    with pytest.raises(ValueError, match="timeout_s needs executor"):
        run_sweep(GRID, str(tmp_path), executor="sequential", timeout_s=5.0)


# -- Job rendering -----------------------------------------------------------

def test_job_name_sanitized():
    name = job_name("fl.participation=0.5,method=fedphd,seed=2", 1)
    assert name == name.lower() and len(name) <= 63
    assert all(c.isalnum() or c == "-" for c in name)
    assert name != job_name("fl.participation=0.5,method=fedphd,seed=2", 2)
    long_a = job_name("axis=" + "x" * 100 + "1", 1)
    long_b = job_name("axis=" + "x" * 100 + "2", 1)
    assert len(long_a) <= 63 and len(long_b) <= 63 and long_a != long_b


def test_render_job_schema():
    job = render_job(run_id="lr=0.1,seed=0", attempt=2, image="repro:test",
                     spec_path="/sweep/runs/r/spec.json",
                     ckpt_path="/sweep/runs/r/ckpt.npz",
                     result_path="/sweep/runs/r/result.json",
                     rounds=7, save_every=2, namespace="fl",
                     mount_path="/sweep", pvc="sweep-pvc",
                     env={"FEDPHD_ENGINE": "vectorized"}, devices=8)
    assert job["apiVersion"] == "batch/v1" and job["kind"] == "Job"
    assert job["metadata"]["namespace"] == "fl"
    # the raw run-id survives in an annotation (labels can't hold '=')
    assert job["metadata"]["annotations"]["repro.run-id"] == "lr=0.1,seed=0"
    spec = job["spec"]
    # retries belong to the executor, not kubelet
    assert spec["backoffLimit"] == 0
    pod = spec["template"]["spec"]
    assert pod["restartPolicy"] == "Never"
    [ctr] = pod["containers"]
    cmd = ctr["command"]
    assert cmd[:3] == ["python", "-m", "repro.experiment.cluster"]
    assert cmd[cmd.index("--rounds") + 1] == "7"
    assert cmd[cmd.index("--save-every") + 1] == "2"
    env = {e["name"]: e["value"] for e in ctr["env"]}
    assert env["FEDPHD_ENGINE"] == "vectorized"
    assert "--xla_force_host_platform_device_count=8" in env["XLA_FLAGS"]
    [vol] = pod["volumes"]
    assert vol["persistentVolumeClaim"]["claimName"] == "sweep-pvc"
    assert ctr["volumeMounts"][0]["mountPath"] == "/sweep"
    # hostPath fallback without a PVC; no mount at all without a path
    job2 = render_job(run_id="r", attempt=1, image="i", spec_path="s",
                      ckpt_path="c", result_path="o", mount_path="/data")
    assert job2["spec"]["template"]["spec"]["volumes"][0][
        "hostPath"]["path"] == "/data"
    job3 = render_job(run_id="r", attempt=1, image="i", spec_path="s",
                      ckpt_path="c", result_path="o")
    assert job3["spec"]["template"]["spec"]["volumes"] == []


# -- worker entrypoint -------------------------------------------------------

def test_worker_main_writes_result_and_resumes(tmp_path):
    out = str(tmp_path)
    rid = "worker-direct"
    os.makedirs(os.path.join(out, "runs", rid))
    spec_path = run_spec_path(out, rid)
    with open(spec_path, "w") as f:
        json.dump(BASE.to_dict(), f)
    ckpt = os.path.join(out, "runs", rid, "ckpt.npz")
    argv = ["--spec", spec_path, "--ckpt", ckpt,
            "--result", run_result_path(out, rid), "--run-id", rid]

    # preempted attempt: one round trained, no completion token
    assert worker_main(argv, _stop_after=1) == PREEMPTED_EXIT
    assert load_result(out, rid) is None
    assert os.path.exists(ckpt + ".manifest.json")

    # retry resumes from the checkpoint and completes
    assert worker_main(argv) == 0
    res = load_result(out, rid)
    assert res["run_id"] == rid and res["spec"] == BASE.to_dict()
    assert res["rounds_done"] == len(res["history"]) == 2
    assert res["history"][0]["round"] == 1 and res["wall_s"] > 0


# -- executor end-to-end -----------------------------------------------------

def test_k8s_grid_matches_sequential(tmp_path, seq_manifest):
    exe, cluster = fake_exec()
    res = run_sweep(GRID, str(tmp_path), executor=exe)
    assert res.complete
    assert set(res.manifest["runs"]) == set(seq_manifest["runs"])
    assert len(cluster.submitted) == 4
    for rid, entry in res.manifest["runs"].items():
        ref = seq_manifest["runs"][rid]
        assert [h["selected"] for h in entry["history"]] \
            == [h["selected"] for h in ref["history"]]
        assert [h["comm_gb"] for h in entry["history"]] \
            == [h["comm_gb"] for h in ref["history"]]
        for a, b in zip(entry["history"], ref["history"]):
            assert a["loss"] == pytest.approx(b["loss"], abs=1e-5)


def test_preemption_resumes_from_checkpoint(tmp_path, seq_manifest):
    rid = "lr=0.0001,seed=0"
    exe, cluster = fake_exec(cluster=FakeCluster(preempt_once={rid: 1}))
    res = run_sweep(GRID, str(tmp_path), executor=exe, max_retries=1)
    assert res.complete
    assert cluster.preempted == [rid]
    entry = res.manifest["runs"][rid]
    assert entry["attempts"] == 2
    # the resumed history is the unbroken 2-round trajectory
    ref = seq_manifest["runs"][rid]["history"]
    assert [h["round"] for h in entry["history"]] == [1, 2]
    for a, b in zip(entry["history"], ref):
        assert a["loss"] == pytest.approx(b["loss"], abs=1e-5)
        assert a["selected"] == b["selected"]


def test_preemption_without_retries_quarantines(tmp_path):
    rid = "lr=0.0001,seed=0"
    exe, _ = fake_exec(cluster=FakeCluster(preempt_once={rid: 1}))
    res = run_sweep(GRID, str(tmp_path), executor=exe)  # max_retries=0
    entry = res.manifest["runs"][rid]
    assert entry["status"] == "failed"
    assert "JobFailed(Preempted)" in entry["error"]
    done = [r for r, e in res.manifest["runs"].items()
            if e["status"] == "done"]
    assert len(done) == 3   # the rest of the grid completed


def test_reconcile_from_artifacts(tmp_path):
    out = str(tmp_path)
    exe, _ = fake_exec()
    assert run_sweep(GRID, out, executor=exe).complete
    # lose the manifest; forbid submits: completion must come purely
    # from the result.json artifacts on shared storage
    os.remove(os.path.join(out, "sweep.json"))
    exe2, cluster2 = fake_exec(cluster=FakeCluster(fail_submits=True))
    res = run_sweep(GRID, out, executor=exe2)
    assert res.complete and cluster2.submitted == []


def test_stale_result_reruns(tmp_path):
    out = str(tmp_path)
    exe, _ = fake_exec()
    assert run_sweep(GRID, out, executor=exe).complete
    # an edited sweep: same run-ids, different specs (the sweep name is
    # baked into every spec) -> on-disk artifacts are stale, all rerun
    edited = GRID.replace(name="k8s-grid-v2")
    exe2, cluster2 = fake_exec()
    res = run_sweep(edited, out, executor=exe2)
    assert res.complete and len(cluster2.submitted) == 4
    for rid, entry in res.manifest["runs"].items():
        assert entry["spec"]["name"] == f"k8s-grid-v2/{rid}"


def test_injected_failure_quarantine_and_raise(tmp_path):
    rid = "lr=0.0002,seed=1"
    exe, _ = fake_exec(cluster=FakeCluster(
        fail_reasons={rid: "ImagePullBackOff"}))
    res = run_sweep(GRID, str(tmp_path / "a"), executor=exe)
    entry = res.manifest["runs"][rid]
    assert entry["status"] == "failed"
    assert "JobFailed(ImagePullBackOff)" in entry["error"]
    exe2, _ = fake_exec(cluster=FakeCluster(
        fail_reasons={rid: "ImagePullBackOff"}))
    with pytest.raises(RuntimeError, match="failed after 1 attempt"):
        run_sweep(GRID, str(tmp_path / "b"), executor=exe2,
                  raise_on_error=True)


def test_pending_polls_then_success(tmp_path):
    exe, cluster = fake_exec(cluster=FakeCluster(pending_polls=2))
    res = run_sweep(GRID, str(tmp_path), executor=exe, max_workers=2)
    assert res.complete
    assert all(st["polls"] > 2 for st in cluster.jobs.values())


def test_k8s_cluster_requires_package():
    from repro.experiment.cluster import K8sCluster
    pytest.importorskip  # real client only errors when kubernetes absent
    try:
        import kubernetes  # noqa: F401
        pytest.skip("kubernetes package present")
    except ImportError:
        pass
    with pytest.raises(RuntimeError, match="kubernetes"):
        K8sCluster()


# -- CLI ---------------------------------------------------------------------

def test_cli_k8s_fake(tmp_path):
    from repro.experiment import runner
    sweep_json = tmp_path / "grid.json"
    sweep_json.write_text(GRID.to_json())
    out = tmp_path / "out"
    res = runner.main(["--sweep", str(sweep_json), "--out", str(out),
                       "--executor", "k8s", "--k8s-fake"])
    assert res.complete
    assert (out / "report.json").exists()


def test_cli_k8s_flag_guards(tmp_path):
    from repro.experiment import runner
    sweep_json = tmp_path / "grid.json"
    sweep_json.write_text(GRID.to_json())
    with pytest.raises(SystemExit, match="--executor k8s"):
        runner.main(["--sweep", str(sweep_json), "--out", str(tmp_path),
                     "--k8s-fake"])
    with pytest.raises(SystemExit, match="require --sweep"):
        runner.main(["--preset", "smoke", "--out", str(tmp_path),
                     "--k8s-fake"])


def test_job_status_value():
    st = JobStatus("Failed", "Preempted")
    assert (st.phase, st.reason) == ("Failed", "Preempted")
