"""Launch-layer tests: mesh construction, sharding rules, step builders,
roofline HLO parser."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant, sharding_rules
from repro.configs.base import InputShape
from repro.launch.mesh import make_host_mesh
from repro.launch.sharding import _param_spec, _fit
from repro.launch.steps import (build_opt_init, build_serve_step,
                                build_train_step)
from repro.models import model
from repro.roofline.analysis import analyze_hlo, parse_hlo


def test_host_mesh():
    mesh = make_host_mesh()
    assert set(mesh.axis_names) == {"data", "model"}


def test_param_spec_megatron_rules():
    mesh = make_host_mesh()  # 1 device: every _fit -> None (divisibility)
    rules = sharding_rules(get_config("internlm2-20b"))
    spec = _param_spec("cycles/0/attn/wq", (48, 6144, 6144), mesh, rules)
    assert len(spec) == 3


def test_train_step_runs_and_learns(rng):
    cfg = smoke_variant("gemma2-2b")
    step = jax.jit(build_train_step(cfg, lr=1e-3))
    opt_init = build_opt_init(cfg)
    params = model.init(rng, cfg)
    opt = opt_init(params)
    batch = model.make_inputs(rng, cfg, InputShape("t", 64, 2, "train"))
    losses = []
    for i in range(5):
        params, opt, loss = step(params, opt, batch, i)
        losses.append(float(loss))
    assert losses[-1] < losses[0]          # memorizes a fixed batch


def test_serve_step_greedy(rng):
    cfg = smoke_variant("moonshot-v1-16b-a3b")
    serve = jax.jit(build_serve_step(cfg))
    params = model.init(rng, cfg)
    cache = model.init_cache(params, cfg, 2, 32)
    toks = jnp.ones((2, 1), jnp.int32)
    for _ in range(4):
        toks, cache = serve(params, cache, toks)
    assert toks.shape == (2, 1)
    assert int(cache["pos"][0]) == 4


def test_reset_cache_slots(rng):
    """Blending fresh state into one slot's rows restores init state
    there and leaves the other slots' rows untouched."""
    cfg = smoke_variant("gemma2-2b")
    serve = jax.jit(build_serve_step(cfg))
    params = model.init(rng, cfg)
    fresh = model.init_cache(params, cfg, 2, 16)
    cache = fresh
    toks = jnp.ones((2, 1), jnp.int32)
    for _ in range(3):
        toks, cache = serve(params, cache, toks)
    reset0 = model.reset_cache_slots(cache, fresh,
                                     jnp.asarray([True, False]))
    assert int(reset0["pos"][0]) == 0 and int(reset0["pos"][1]) == 3
    # resetting every slot restores init_cache exactly; resetting none
    # is the identity — including non-zero init leaves (ring kv_pos=-1)
    reset_all = model.reset_cache_slots(cache, fresh,
                                        jnp.asarray([True, True]))
    reset_none = model.reset_cache_slots(cache, fresh,
                                         jnp.asarray([False, False]))
    for got, want in ((reset_all, fresh), (reset_none, cache)):
        for leaf_g, leaf_w in zip(jax.tree.leaves(got),
                                  jax.tree.leaves(want)):
            np.testing.assert_array_equal(np.asarray(leaf_g),
                                          np.asarray(leaf_w))


def test_serve_requests_refill_isolated(rng):
    """Regression: a refilled slot must not leak the previous request's
    KV rows or token — request output is a function of its id only."""
    from repro.launch.serve import serve_requests
    cfg = smoke_variant("gemma2-2b")
    params = model.init(rng, cfg)
    kw = dict(requests=4, max_tokens=4, cache_len=16, seed=0)
    refilled = serve_requests(params, cfg, slots=2, **kw)
    isolated = serve_requests(params, cfg, slots=4, **kw)
    for rid in range(4):
        assert refilled["outputs"][rid] == isolated["outputs"][rid], \
            f"request {rid} output depends on slot history"


def test_master_weights_for_bf16(rng):
    cfg = smoke_variant("internlm2-20b").replace(param_dtype="bfloat16")
    params = model.init(rng, cfg)
    opt = build_opt_init(cfg)(params)
    assert opt.master is not None
    m_leaves = jax.tree.leaves(opt.master)
    assert all(l.dtype == jnp.float32 for l in m_leaves)


_HLO = """
HloModule test
%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}
%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8] get-tuple-element(%p), index=1
  %d = f32[8,8] dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%ni, %d)
}
ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %zero = s32[] constant(0)
  %tup = (s32[], f32[8,8]) tuple(%zero, %a)
  %w = (s32[], f32[8,8]) while(%tup), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
  ROOT %out = f32[8,8] get-tuple-element(%w), index=1
}
"""


def test_roofline_parser_trip_counts():
    terms = analyze_hlo(_HLO)
    # 7 iterations x 2*8*8*8 flops
    assert terms.flops == pytest.approx(7 * 2 * 8 * 8 * 8)


def test_roofline_parser_computations():
    comps = parse_hlo(_HLO)
    assert {"cond", "body", "main"} <= set(comps)
    assert comps["main"].is_entry
