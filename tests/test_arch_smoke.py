"""Per-architecture smoke tests: reduced variant of each assigned family,
one forward/train step + one decode step on CPU; output shapes + no NaNs.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import list_archs, smoke_variant
from repro.configs.base import InputShape
from repro.models import model

TRAIN = InputShape("smoke_train", 64, 2, "train")


@pytest.mark.parametrize("arch", list_archs())
def test_train_step(arch, rng):
    cfg = smoke_variant(arch)
    params = model.init(rng, cfg)
    batch = model.make_inputs(rng, cfg, TRAIN)
    loss, grads = jax.value_and_grad(
        lambda p: model.loss_fn(p, cfg, batch, rng))(params)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss)), f"{arch}: NaN loss"
    gleaves = jax.tree.leaves(grads)
    assert gleaves, f"{arch}: empty grads"
    assert all(not bool(jnp.any(jnp.isnan(g))) for g in gleaves), \
        f"{arch}: NaN grads"


@pytest.mark.parametrize("arch", list_archs())
def test_decode_step(arch, rng):
    cfg = smoke_variant(arch)
    params = model.init(rng, cfg)
    cache = model.init_cache(params, cfg, 2, 64)
    toks = jnp.ones((2, 1), jnp.int32)
    for _ in range(3):
        logits, cache = model.decode(params, cache, cfg, toks)
        toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits))), f"{arch}: NaN decode logits"
    assert int(cache["pos"][0]) == 3


@pytest.mark.parametrize("arch", ["internlm2-20b", "rwkv6-7b",
                                  "recurrentgemma-9b"])
def test_prefill_matches_decode(arch, rng):
    """Prefill logits at position t == decode logits after feeding t tokens."""
    cfg = smoke_variant(arch)
    params = model.init(rng, cfg)
    T = 8
    toks = jax.random.randint(rng, (1, T), 0, cfg.vocab_size, jnp.int32)
    hidden, _ = __import__("repro.models.transformer",
                           fromlist=["forward"]).forward(
        params, cfg, {"tokens": toks})
    from repro.models.transformer import logits_from_hidden
    full_logits = logits_from_hidden(params, cfg, hidden)

    cache = model.init_cache(params, cfg, 1, 64)
    for t in range(T):
        step_logits, cache = model.decode(params, cache, cfg, toks[:, t:t+1])
    import numpy as np
    np.testing.assert_allclose(np.asarray(step_logits[0, 0]),
                               np.asarray(full_logits[0, -1]),
                               rtol=2e-2, atol=2e-2)


def test_ring_buffer_window_decode_matches_full(rng):
    """A sliding-window layer's ring-buffer cache must give the same
    logits as a full-size cache once enough tokens have been fed: the
    window masks out everything the ring has evicted."""
    cfg = smoke_variant("gemma2-2b")           # local/global alternating
    params = model.init(rng, cfg)
    T = 24                                     # > sliding_window (16 min? smoke window=64 -> use shorter)
    win = 8
    cfg = cfg.replace(sliding_window=win)
    toks = jax.random.randint(rng, (1, T), 0, cfg.vocab_size, jnp.int32)

    # full-size cache: ring size = min(window, seq) = window either way;
    # compare against a cache big enough to never wrap
    cache_small = model.init_cache(params, cfg, 1, win)    # local layers wrap
    cache_big = model.init_cache(params, cfg, 1, 4 * T)
    for t in range(T):
        l_small, cache_small = model.decode(params, cache_small, cfg,
                                            toks[:, t:t + 1])
        l_big, cache_big = model.decode(params, cache_big, cfg,
                                        toks[:, t:t + 1])
    import numpy as np
    # NOTE: global layers in cache_small only hold the last `win` tokens,
    # so compare a pure-local variant for exactness
    cfg_local = cfg.replace(layer_pattern=(1,))  # ATTN_LOCAL only
    params_l = model.init(rng, cfg_local)
    cs = model.init_cache(params_l, cfg_local, 1, win)
    cb = model.init_cache(params_l, cfg_local, 1, 4 * T)
    for t in range(T):
        ls, cs = model.decode(params_l, cs, cfg_local, toks[:, t:t + 1])
        lb, cb = model.decode(params_l, cb, cfg_local, toks[:, t:t + 1])
    np.testing.assert_allclose(np.asarray(ls), np.asarray(lb),
                               rtol=2e-3, atol=2e-3)
