"""PR-9 axes: mixed-precision (bf16) round engines and the quantized
delta uplink with error feedback (repro.fl.compress).

Locked tolerances (tiny config: 4 clients / 2 edges / 4 rounds with the
prune at round 3):

- bf16 vs fp32 loss trajectories agree within 0.05 absolute — the loss
  surface at init is O(1), bf16 keeps ~3 decimal digits, and the fp32
  master weights stop the gap compounding multiplicatively;
- int8 error-feedback uplink tracks the fp32 losses within the same
  0.05 while ``comm_up_gb`` drops ~4x, byte-accurately.

Runs on a registered micro U-Net (8x8, 8 channels) — compile time
dominates at any larger scale.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SMOKE_UNET, register_config
from repro.configs.base import FLConfig
from repro.data.synthetic import DatasetSpec
from repro.experiment import (DataSpec, ExperimentSpec, register_dataset,
                              run_spec)
from repro.experiment.sweep import spec_with
from repro.fl.compress import (CommSpec, downlink_bytes, ef_roundtrip,
                               ef_roundtrip_stacked, uplink_bytes)
from repro.models.ops import (PRECISIONS, cast_floats, compute_dtype,
                              resolve_precision)

LOSS_ATOL = 0.05            # locked: bf16 / int8+EF vs fp32 trajectories

TINY_UNET = SMOKE_UNET.replace(name="ddpm-unet-tiny-prec", image_size=8,
                               base_channels=8, channel_mults=(1,),
                               num_res_blocks=1, attn_resolutions=())
register_config("ddpm-unet-tiny-prec", TINY_UNET, overwrite=True)
register_dataset("tiny-prec", DatasetSpec("tiny-prec", num_classes=4,
                                          image_size=8, samples_per_class=32),
                 overwrite=True)

FL = FLConfig(num_clients=4, num_edges=2, local_epochs=1, edge_agg_every=1,
              cloud_agg_every=2, rounds=4, sparse_rounds=2, prune_ratio=0.44,
              sh_a=1000.0)


def _spec(**kw) -> ExperimentSpec:
    base = dict(name="precision-smoke", method="fedavg",
                model="ddpm-unet-tiny-prec", fl=FL,
                data=DataSpec(dataset="tiny-prec", batch_size=8), seed=0)
    base.update(kw)
    return ExperimentSpec(**base)


# completed experiments are read-only to the assertions, so identical
# specs across tests share one run (specs are frozen -> hashable)
_RUNS = {}


def _run(**kw):
    spec = _spec(**kw)
    if spec not in _RUNS:
        _RUNS[spec] = run_spec(spec)
    return _RUNS[spec]


def _maxdiff(a, b) -> float:
    return max(float(np.max(np.abs(np.asarray(x, np.float32)
                                   - np.asarray(y, np.float32))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# resolution + spec plumbing
# ---------------------------------------------------------------------------

def test_resolve_precision_contract(monkeypatch):
    monkeypatch.delenv("FEDPHD_PRECISION", raising=False)
    assert resolve_precision(None) == "fp32"
    assert resolve_precision("") == "fp32"
    assert resolve_precision("bf16") == "bf16"
    monkeypatch.setenv("FEDPHD_PRECISION", "bf16")
    assert resolve_precision(None) == "bf16"
    assert resolve_precision("fp32") == "fp32"     # explicit beats env
    with pytest.raises(ValueError):
        resolve_precision("fp16")
    assert compute_dtype("bf16") == jnp.bfloat16
    assert compute_dtype("fp32") == jnp.float32
    assert set(PRECISIONS) == {"fp32", "bf16"}


def test_cast_floats_skips_integers():
    tree = {"w": jnp.ones((2,), jnp.float32), "t": jnp.asarray(3, jnp.int32)}
    out = cast_floats(tree, jnp.bfloat16)
    assert out["w"].dtype == jnp.bfloat16
    assert out["t"].dtype == jnp.int32


def test_spec_json_roundtrip_and_sweep_axes():
    s = _spec(precision="bf16", comm=CommSpec(quant="int8"))
    rt = ExperimentSpec.from_json(s.to_json())
    assert rt == s and rt.comm.quant == "int8" and rt.precision == "bf16"
    # comm.quant is a dotted sweep axis like fault.*
    sw = spec_with(s, {"comm.quant": "fp8", "precision": "fp32"})
    assert sw.comm.quant == "fp8" and sw.precision == "fp32"
    with pytest.raises(ValueError):
        CommSpec(quant="int4")


# ---------------------------------------------------------------------------
# compress unit behavior
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("quant", ["int8", "fp8"])
def test_ef_roundtrip_error_bound_and_feedback(quant):
    rng = np.random.default_rng(0)
    delta = {"a": jnp.asarray(rng.standard_normal((8, 8)), jnp.float32),
             "b": jnp.asarray(rng.standard_normal((16,)) * 100, jnp.float32)}
    err = jax.tree.map(jnp.zeros_like, delta)
    deq, new_err = ef_roundtrip(delta, err, quant)
    for k in delta:
        d = np.asarray(delta[k])
        q = np.asarray(deq[k])
        e = np.asarray(new_err[k])
        assert np.all(np.isfinite(q)), f"{quant} produced non-finite deq"
        # int8: uniform buckets of amax/127, error <= half a bucket.
        # fp8 e4m3: 3 mantissa bits -> RELATIVE error <= 2^-4 of the
        # element's own magnitude (floating, not uniform).
        if quant == "int8":
            step = np.max(np.abs(d)) / 127.0
            assert np.max(np.abs(d - q)) <= step * 0.5 + 1e-6
            bound = step
        else:
            assert np.all(np.abs(d - q) <= np.abs(d) * 2.0 ** -4 + 1e-6)
            bound = np.max(np.abs(d)) * 2.0 ** -4
        # the residual IS the feedback: deq + err' == delta exactly
        np.testing.assert_allclose(q + e, d, atol=1e-5 * max(1.0, bound))
    deq2, _ = ef_roundtrip(delta, new_err, quant)
    assert np.all(np.isfinite(np.asarray(deq2["a"])))


def test_ef_zero_tree_is_exact():
    z = {"a": jnp.zeros((4, 4), jnp.float32)}
    deq, err = ef_roundtrip(z, jax.tree.map(jnp.zeros_like, z), "int8")
    assert float(jnp.abs(deq["a"]).max()) == 0.0
    assert float(jnp.abs(err["a"]).max()) == 0.0


def test_fp8_overflow_clips_not_nan():
    """XLA's f8e4m3fn cast does NOT saturate — out-of-range values come
    back NaN unless clipped first.  The quantizer must clip."""
    big = {"a": jnp.asarray([[5.0e4, -5.0e4, 1.0, 0.0]], jnp.float32)}
    deq, err = ef_roundtrip(big, jax.tree.map(jnp.zeros_like, big), "fp8")
    assert np.all(np.isfinite(np.asarray(deq["a"])))
    assert np.all(np.isfinite(np.asarray(err["a"])))


def test_stacked_roundtrip_matches_per_client():
    """ef_roundtrip_stacked (vectorized engine) == per-client
    ef_roundtrip (sequential path), client for client, bitwise."""
    rng = np.random.default_rng(1)
    C = 3
    delta = {"w": jnp.asarray(rng.standard_normal((C, 4, 5)), jnp.float32)}
    err = {"w": jnp.asarray(rng.standard_normal((C, 4, 5)) * 0.1,
                            jnp.float32)}
    deq_s, err_s = ef_roundtrip_stacked(delta, err, "int8")
    for c in range(C):
        deq_c, err_c = ef_roundtrip({"w": delta["w"][c]},
                                    {"w": err["w"][c]}, "int8")
        np.testing.assert_array_equal(np.asarray(deq_s["w"][c]),
                                      np.asarray(deq_c["w"]))
        np.testing.assert_array_equal(np.asarray(err_s["w"][c]),
                                      np.asarray(err_c["w"]))


def test_wire_byte_accounting_exact():
    tree = {"a": np.zeros((10, 3), np.float32), "b": np.zeros(7, np.float32)}
    assert uplink_bytes(tree, "none") == 37 * 4
    assert uplink_bytes(tree, "int8") == 37 * 1 + 2 * 4
    assert uplink_bytes(tree, "fp8") == 37 * 1 + 2 * 4
    assert downlink_bytes(tree, "fp32") == 37 * 4
    assert downlink_bytes(tree, "bf16") == 37 * 2


# ---------------------------------------------------------------------------
# trainer integration: precision
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["fedavg", "fedphd"])
def test_bf16_tracks_fp32_losses(method):
    """bf16 compute with fp32 masters stays within the locked loss
    tolerance of the fp32 run, and the params the trainer exposes stay
    fp32 (master weights, not compute casts)."""
    fp = _run(method=method, precision="fp32")
    bf = _run(method=method, precision="bf16")
    assert bf.cfg.precision == "bf16" and fp.cfg.precision == "fp32"
    for x in jax.tree.leaves(bf.params):
        assert jnp.asarray(x).dtype == jnp.float32
    for a, b in zip(fp.history, bf.history):
        assert abs(a.loss - b.loss) < LOSS_ATOL
    # downloads halve under bf16; the uplink ships fp32 master deltas
    assert bf.history[0].comm_down_gb == fp.history[0].comm_down_gb / 2
    assert bf.history[0].comm_up_gb == fp.history[0].comm_up_gb


def test_bf16_seq_vs_vec_close():
    """Both engines run the same bf16 loss closure; bf16 rounding makes
    them drift faster than fp32, so the equivalence bar is looser than
    the fp32 suites' 1e-5."""
    a = _run(precision="bf16", engine="sequential")
    b = _run(precision="bf16", engine="vectorized")
    assert _maxdiff(a.params, b.params) < 1e-2
    for x, y in zip(a.history, b.history):
        assert x.comm_gb == y.comm_gb


# ---------------------------------------------------------------------------
# trainer integration: quantized uplink
# ---------------------------------------------------------------------------

def test_int8_ef_tracks_fp32_and_cuts_uplink():
    """Locked acceptance: int8+EF stays within LOSS_ATOL of the
    fp32/none run while the uplink drops ~4x, byte-accurately."""
    ref = _run()
    q = _run(comm=CommSpec(quant="int8"))
    for a, b in zip(ref.history, q.history):
        assert abs(a.loss - b.loss) < LOSS_ATOL
    # byte-accurate uplink: N*1 + 4 per leaf vs N*4, at the same linear
    # cost-model rate -> comm_up_gb scales by exactly the byte ratio
    up_f = uplink_bytes(ref.params, "none")
    up_q = uplink_bytes(ref.params, "int8")
    assert 3.5 < up_f / up_q <= 4.0
    r, s = ref.history[0], q.history[0]
    assert s.comm_up_gb == pytest.approx(r.comm_up_gb * up_q / up_f,
                                         rel=1e-12)
    assert s.comm_down_gb == r.comm_down_gb        # downloads untouched
    assert s.comm_gb == s.comm_up_gb + s.comm_down_gb


@pytest.mark.parametrize("method", ["fedavg", "scaffold", "fedphd"])
def test_quant_seq_vs_vec(method):
    """Engine equivalence under int8+EF: bitwise comm accounting, and
    params within the quantization-bucket tolerance (buckets can flip
    near ties between the two execution orders, so the bar is one
    bucket, not the fp32 suites' 1e-5)."""
    a = _run(method=method, comm=CommSpec(quant="int8"),
             engine="sequential")
    b = _run(method=method, comm=CommSpec(quant="int8"),
             engine="vectorized")
    for x, y in zip(a.history, b.history):
        assert x.comm_gb == y.comm_gb              # bitwise
        assert x.comm_up_gb == y.comm_up_gb
        assert x.comm_down_gb == y.comm_down_gb
    assert _maxdiff(a.params, b.params) < 1e-3


def test_comm_split_fields_sum_to_total():
    """The new up/down decomposition always reconstitutes comm_gb."""
    e = _run()
    for h in e.history:
        assert h.comm_up_gb is not None and h.comm_down_gb is not None
        assert h.comm_gb == h.comm_up_gb + h.comm_down_gb


# ---------------------------------------------------------------------------
# checkpoint kill-and-resume across sparse -> prune -> plain
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["fedphd", "fedavg"])
def test_quant_bf16_kill_and_resume_bitwise(method, tmp_path):
    """Sequential engine: killing after round 2 and resuming reproduces
    the unbroken int8+bf16 run bitwise — every leaf dtype and the
    error-feedback residuals included — across FedPhD's sparse ->
    prune -> plain transition (rounds=4, sparse_rounds=2: prune fires
    at round 3, round 4 runs on the compacted model)."""
    spec = _spec(method=method, precision="bf16",
                 comm=CommSpec(quant="int8"), engine="sequential")
    full = _RUNS.get(spec) or _RUNS.setdefault(spec, run_spec(spec))

    ck = os.path.join(tmp_path, "ckpt")
    run_spec(spec, rounds=2, ckpt=ck)
    resumed = run_spec(None, ckpt=ck, resume=True, rounds=spec.fl.rounds)

    assert _maxdiff(full.params, resumed.params) == 0.0
    for x, y in zip(jax.tree.leaves(full.params),
                    jax.tree.leaves(resumed.params)):
        assert jnp.asarray(x).dtype == jnp.asarray(y).dtype
    for a, b in zip(full.history, resumed.history):
        assert a.comm_gb == b.comm_gb
        assert a.comm_up_gb == b.comm_up_gb
    if method == "fedphd":
        assert any(h.pruned for h in full.history)
    # the EF residuals themselves restore bitwise
    fe, re_ = full.trainer._err_stack, resumed.trainer._err_stack
    assert fe is not None and re_ is not None
    for x, y in zip(jax.tree.leaves(fe), jax.tree.leaves(re_)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
