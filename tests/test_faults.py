"""Fault-injection layer: seeded availability/dropout/straggler/churn
schedules, engine equivalence under faults, staleness-aware
aggregation, checkpointed fault-RNG streams, and the sweep executor's
retry/timeout quarantine.

The acceptance contract this file locks:

  (a) sequential vs vectorized under nonzero dropout + stragglers +
      churn agree (params atol 1e-5, ``comm_gb`` bitwise, identical
      realized availability), and dropped clients contribute zero
      uplink;
  (b) a disabled ``fault.*`` block reproduces today's trajectories
      bitwise (fault=None and the all-default FaultSpec are the same
      code path);
  (c) ``aggregation="staleness"`` with zero stragglers IS FedAvg;
  (d) a sweep run that raises mid-round is retried with backoff and
      then quarantined ``status="failed"`` while the rest of the grid
      completes, and the report marks the failure.

Everything trains on an 8x8 micro U-Net (registered here) except the
process-pool timeout test, which must use a built-in config — spawned
workers re-import repro and never see this module's registrations.
"""
import dataclasses
import os
import warnings
from types import SimpleNamespace

import jax.numpy as jnp
import jax
import numpy as np
import pytest

from repro.configs import SMOKE_UNET, get_config, register_config
from repro.configs.base import FLConfig
from repro.data.synthetic import DatasetSpec
from repro.experiment import (DataSpec, ExperimentSpec, FaultModel,
                              FaultSpec, SweepSpec, build_report,
                              make_clients, make_trainer, register_dataset,
                              register_method, report_markdown, run_spec,
                              run_sweep, spec_with)
from repro.fl.baselines import FlatTrainer
from repro.fl.engine import route_engine

TINY = "ddpm-unet-tiny-faults"
register_config(TINY, SMOKE_UNET.replace(name=TINY, image_size=8,
                                         base_channels=8, channel_mults=(1,),
                                         num_res_blocks=1,
                                         attn_resolutions=()),
                overwrite=True)
register_dataset("tiny-faults",
                 DatasetSpec("tiny-faults", num_classes=4, image_size=8,
                             samples_per_class=32),
                 overwrite=True)

DATA = DataSpec(dataset="tiny-faults", batch_size=8)
# local_epochs=3 so the deadline/slowdown math yields a non-degenerate
# budget spread (slow clients cap at floor(steps/2), dropped clients at
# a uniform prefix) instead of flooring everything to zero
FL = FLConfig(num_clients=6, num_edges=2, local_epochs=3, edge_agg_every=1,
              cloud_agg_every=2, rounds=2, sparse_rounds=1, prune_ratio=0.44,
              sh_a=1000.0)

# every fault class active at once: partial arrival, mid-round dropout,
# half the population 2x slow, population churn
MIXED = FaultSpec(arrival=0.7, dropout=0.3, straggler_frac=0.5, slowdown=2.0,
                  deadline=1.0, churn=0.2, seed=3)


def _spec(method, engine, fault, fl=FL, prune=None, model=TINY):
    if prune is None:
        prune = method.startswith("fedphd")
    return ExperimentSpec(name="faults", method=method, model=model,
                          fl=fl, data=DATA, engine=engine, prune=prune,
                          fault=fault)


def _run(method, engine, fault, rounds=2, **kw):
    spec = _spec(method, engine, fault, **kw)
    clients, _, _ = make_clients(spec)        # fresh per trainer: the
    tr = make_trainer(spec, get_config(spec.model), clients)   # data RNG
    tr.run(rounds)                            # streams mutate in-place
    return tr


def _maxdiff(a, b):
    return max(float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                     - y.astype(jnp.float32))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# FaultSpec: the declarative layer.
# ---------------------------------------------------------------------------

def test_fault_spec_roundtrip_and_sweep_axis():
    f = FaultSpec(arrival=0.9, dropout=0.1, churn=0.05, seed=7)
    assert FaultSpec.from_dict(f.to_dict()) == f
    base = _spec("fedavg", "sequential", FaultSpec())
    assert ExperimentSpec.from_json(base.to_json()) == base
    # fault.* is a sweepable path like fl.* / data.*
    s = spec_with(base, {"fault.dropout": 0.5, "fault.seed": 2})
    assert s.fault.dropout == 0.5 and s.fault.seed == 2
    runs = SweepSpec(name="fx", base=base,
                     axes={"fault.dropout": [0.0, 0.5]}).expand()
    assert [r.run_id for r in runs] == ["fault.dropout=0.0",
                                        "fault.dropout=0.5"]


def test_fault_spec_validation():
    with pytest.raises(ValueError, match="arrival"):
        FaultSpec(arrival=1.5)
    with pytest.raises(ValueError, match="slowdown"):
        FaultSpec(slowdown=0.5)
    with pytest.raises(ValueError, match="deadline"):
        FaultSpec(deadline=0.0)
    assert not FaultSpec().enabled
    assert not FaultSpec(straggler_frac=0.5, slowdown=1.0).enabled
    assert FaultSpec(dropout=0.1).enabled


# ---------------------------------------------------------------------------
# FaultModel: one seeded stream, engine/mode/resume-independent.
# ---------------------------------------------------------------------------

def _draw(model, rounds=3, n=8, steps=6):
    out = []
    for _ in range(rounds):
        online = model.begin_round()
        sel = np.flatnonzero(online)
        rf = model.draw_round(sel, [steps] * len(sel), staleness_mode=True)
        out.append((online.tolist(), rf.availability()))
    return out


def test_fault_model_deterministic_and_seed_sensitive():
    spec = MIXED
    a = _draw(FaultModel(spec, 8, base_seed=0))
    b = _draw(FaultModel(spec, 8, base_seed=0))
    assert a == b                              # bitwise-identical schedule
    c = _draw(FaultModel(spec.replace(seed=4), 8, base_seed=0))
    assert a != c                              # fault.seed is a real axis
    d = _draw(FaultModel(spec, 8, base_seed=1))
    assert a != d                              # experiment seed folds in


def test_fault_model_state_resumes_stream_mid_run():
    unbroken = FaultModel(MIXED, 8, base_seed=0)
    full = _draw(unbroken, rounds=4)

    first = FaultModel(MIXED, 8, base_seed=0)
    head = _draw(first, rounds=2)
    snap = first.state()                       # JSON-serializable
    resumed = FaultModel(MIXED, 8, base_seed=0)
    resumed.set_state(snap)
    tail = _draw(resumed, rounds=2)
    assert head + tail == full


# ---------------------------------------------------------------------------
# (a) engine equivalence under mixed faults.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["fedphd", "fedavg"])
def test_seq_vs_vec_under_mixed_faults(method):
    seq = _run(method, "sequential", MIXED)
    vec = _run(method, "vectorized", MIXED)
    assert _maxdiff(seq.params, vec.params) < 1e-5
    for a, b in zip(seq.history, vec.history):
        assert a.comm_gb == b.comm_gb          # bitwise
        assert a.selected == b.selected
        assert a.availability == b.availability
        assert a.availability is not None
        assert b.loss == pytest.approx(a.loss, abs=1e-5)
    # the schedule actually fired: some client missed/dropped/was capped
    av = [h.availability for h in seq.history]
    assert any(len(a["arrived"]) < len(h.selected)
               or a["dropped"] or min(a["budgets"], default=0) == 0
               or len(set(a["budgets"])) > 1
               for a, h in zip(av, seq.history))


def test_dropped_clients_zero_uplink():
    """Flat-topology comm accounting under faults: every arrived client
    downloads, only completed clients upload.  With dropout=1.0 every
    arrival crashes, so the round costs exactly HALF the fault-free
    round (downloads only) — dropped clients contribute zero uplink."""
    free = _run("fedavg", "sequential", None, rounds=1)
    drop = _run("fedavg", "sequential",
                FaultSpec(dropout=1.0, seed=5), rounds=1)
    av = drop.history[0].availability
    assert av["arrived"] and av["arrived"] == av["dropped"]
    assert drop.history[0].comm_gb == free.history[0].comm_gb / 2


# ---------------------------------------------------------------------------
# (b) disabled faults are bitwise-invisible.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["fedphd", "fedavg"])
def test_disabled_fault_spec_is_bitwise_noop(method):
    plain = _run(method, "sequential", None)
    noop = _run(method, "sequential", FaultSpec())   # all-default spec
    assert _maxdiff(plain.params, noop.params) == 0.0
    for a, b in zip(plain.history, noop.history):
        assert b.availability is None
        assert (a.loss, a.comm_gb, a.selected) \
            == (b.loss, b.comm_gb, b.selected)


# ---------------------------------------------------------------------------
# (c)+(d of the tentpole) staleness-aware aggregation.
# ---------------------------------------------------------------------------

def test_staleness_without_stragglers_is_fedavg():
    f = FaultSpec(arrival=0.8, dropout=0.3, seed=1)   # no deadline misses
    a = _run("fedavg", "sequential", f)
    b = _run("fedavg-stale", "sequential", f)
    assert _maxdiff(a.params, b.params) == 0.0
    assert [h.loss for h in a.history] == [h.loss for h in b.history]


def test_staleness_seq_vs_vec_with_late_clients():
    f = FaultSpec(straggler_frac=0.5, slowdown=2.0, deadline=0.9, seed=2)
    seq = _run("fedavg-stale", "sequential", f)
    vec = _run("fedavg-stale", "vectorized", f)
    assert _maxdiff(seq.params, vec.params) < 1e-5
    lates = [h.availability["late"] for h in seq.history]
    assert any(lates), "spec produced no late clients"
    for a, b in zip(seq.history, vec.history):
        assert a.availability == b.availability
    # and the late path changes the model vs plain truncating fedavg
    plain = _run("fedavg", "sequential", f)
    assert _maxdiff(seq.params, plain.params) > 0.0


# ---------------------------------------------------------------------------
# kill-and-resume: the fault RNG stream checkpoints.
# ---------------------------------------------------------------------------

def test_kill_resume_restores_fault_stream(tmp_path):
    spec = _spec("fedavg", "sequential", MIXED).replace(
        fl=dataclasses.replace(FL, rounds=3))
    full = run_spec(spec, ckpt=str(tmp_path / "a.npz"))

    ckpt = str(tmp_path / "b.npz")
    run_spec(spec, rounds=2, ckpt=ckpt)              # "killed" after r2
    resumed = run_spec(None, resume=True, rounds=3, ckpt=ckpt)

    assert _maxdiff(full.params, resumed.params) == 0.0
    assert [h.availability for h in full.history] \
        == [h.availability for h in resumed.history]
    assert [h.loss for h in full.history] \
        == [h.loss for h in resumed.history]
    assert all(h.availability is not None for h in full.history)


# ---------------------------------------------------------------------------
# (d) sweep executor: retry with backoff, then quarantine.
# ---------------------------------------------------------------------------

SWEEP_BASE = ExperimentSpec(
    name="fault-sweep", method="fedavg", model=TINY,
    fl=dataclasses.replace(FL, num_clients=4, num_edges=1, local_epochs=1,
                           rounds=2),
    data=DATA, engine="sequential", prune=False,
    fault=FaultSpec(dropout=0.5, seed=1))

_FLAKY = {"marker": None}


class _CrashingTrainer(FlatTrainer):
    """Raises entering round 2 — every attempt (they resume from the
    round-1 checkpoint) hits the same mid-round crash."""

    def run_round(self, r):
        if r >= 2:
            raise RuntimeError("boom: injected mid-round crash")
        return super().run_round(r)


class _FlakyTrainer(FlatTrainer):
    """Crashes entering round 2 exactly once (drops a marker file), so
    the first retry resumes the checkpoint and completes."""

    def run_round(self, r):
        m = _FLAKY["marker"]
        if r >= 2 and m and not os.path.exists(m):
            open(m, "w").close()
            raise RuntimeError("flaky: transient crash")
        return super().run_round(r)


def _wrapped_factory(cls):
    def make(spec, cfg, clients, eval_fn):
        return cls("fedavg", cfg, spec.fl, clients, lr=spec.lr,
                   rng_seed=spec.seed, engine=spec.engine,
                   eval_fn=eval_fn, eval_every=spec.eval_every,
                   fault=spec.fault)
    return make


register_method("crash-always", "flat", _wrapped_factory(_CrashingTrainer),
                overwrite=True)
register_method("crash-once", "flat", _wrapped_factory(_FlakyTrainer),
                overwrite=True)


def test_sweep_retries_then_quarantines_and_reports(tmp_path):
    sweep = SweepSpec(name="q", base=SWEEP_BASE,
                      axes={"method": ["crash-always", "fedavg"]})
    res = run_sweep(sweep, str(tmp_path / "q"), max_retries=2,
                    backoff_s=0.01)
    bad = res.manifest["runs"]["method=crash-always"]
    good = res.manifest["runs"]["method=fedavg"]
    assert bad["status"] == "failed"
    assert bad["attempts"] == 3                  # 1 try + 2 retries
    assert "RuntimeError" in bad["error"] and "boom" in bad["error"]
    assert "Traceback" in bad["error"]           # full traceback kept
    # the rest of the grid completed despite the quarantined run
    assert good["status"] == "done" and good["rounds_done"] == 2
    assert good["history"][-1]["availability"] is not None

    rep = build_report(res.manifest)
    assert rep["failed"] == 1 and rep["done"] == 1 and not rep["complete"]
    md = report_markdown(rep)
    assert "1 FAILED" in md.splitlines()[0]
    assert "| failed |" in md or "| failed " in md
    row = next(l for l in md.splitlines() if "crash-always" in l)
    assert "| 1 |" in row                        # failure column counts it

    # raise_on_error surfaces the quarantined run's exception
    with pytest.raises(RuntimeError, match="boom"):
        run_sweep(sweep.replace(name="q2"), str(tmp_path / "q2"),
                  max_retries=0, backoff_s=0.01, raise_on_error=True)


def test_sweep_transient_crash_retried_and_resumed(tmp_path):
    """A transient mid-round crash on a FAULTED run: the retry resumes
    the round-1 checkpoint (including the fault RNG stream) and the
    finished history matches an unbroken run bitwise."""
    _FLAKY["marker"] = str(tmp_path / "crashed.marker")
    try:
        sweep = SweepSpec(name="t", base=SWEEP_BASE.replace(
            method="crash-once", name="flaky"))
        res = run_sweep(sweep, str(tmp_path / "t"), max_retries=1,
                        backoff_s=0.01)
        (entry,) = res.manifest["runs"].values()
        assert entry["status"] == "done"
        assert entry["attempts"] == 2
        assert entry["rounds_done"] == 2
        assert os.path.exists(_FLAKY["marker"])
    finally:
        _FLAKY["marker"] = None
    # unbroken reference: same spec, marker disarmed -> no crash
    ref = run_spec(SWEEP_BASE.replace(method="crash-once", name="flaky"))
    assert [r["availability"] for r in entry["history"]] \
        == [h.availability for h in ref.history]
    assert [r["loss"] for r in entry["history"]] \
        == [h.loss for h in ref.history]


def test_timeout_requires_process_executor(tmp_path):
    sweep = SweepSpec(name="x", base=SWEEP_BASE)
    with pytest.raises(ValueError, match="timeout_s"):
        run_sweep(sweep, str(tmp_path / "x"), timeout_s=1.0)


def test_process_timeout_kills_and_quarantines(tmp_path):
    """A hung run on the process executor is killed at the wall-clock
    deadline and quarantined.  Built-in model/dataset only: the spawned
    worker never sees this module's registrations — and the deadline is
    far shorter than the worker's startup, a deterministic 'hang'."""
    base = ExperimentSpec(
        name="hang", method="fedavg", model="ddpm-unet-smoke",
        fl=FLConfig(num_clients=2, num_edges=1, local_epochs=1,
                    edge_agg_every=1, cloud_agg_every=2, rounds=1,
                    sparse_rounds=2, sh_a=1000.0),
        data=DataSpec(dataset="smoke", batch_size=32),
        engine="sequential", prune=False)
    sweep = SweepSpec(name="hang", base=base, axes={"seed": [0]})
    res = run_sweep(sweep, str(tmp_path / "h"), executor="process",
                    max_workers=1, timeout_s=0.5, max_retries=1,
                    backoff_s=0.01)
    (entry,) = res.manifest["runs"].values()
    assert entry["status"] == "failed"
    assert entry["attempts"] == 2
    assert "TimeoutError" in entry["error"]
    assert "timeout_s=0.5" in entry["error"]


# ---------------------------------------------------------------------------
# route_engine fallback warning keys on (method, engine).
# ---------------------------------------------------------------------------

def _ragged_clients(batch_sizes):
    return [SimpleNamespace(data=SimpleNamespace(
        batch_size=b, images=np.zeros((b, 8, 8, 1)))) for b in batch_sizes]


def test_route_engine_warning_keyed_by_method_and_engine():
    ragged = _ragged_clients([8, 4])
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("default")       # registry dedup semantics
        use, warned = route_engine("auto", False, ragged, False,
                                   "FlatTrainer", method="fedavg")
        assert not use and warned
        _, warned2 = route_engine("auto", False, ragged, False,
                                  "FlatTrainer", method="fedprox")
        assert warned2
    msgs = [str(w.message) for w in caught]
    # two different methods in one process must BOTH warn: the message
    # text keys the warnings registry, so it must embed (method, engine)
    assert len(msgs) == 2
    assert "method=fedavg" in msgs[0] and "engine=auto" in msgs[0]
    assert "method=fedprox" in msgs[1]
    assert msgs[0] != msgs[1]
