"""Data partitioners, proxy metrics, comm model, checkpointing."""
import os

import numpy as np
import pytest

from repro import checkpoint
from repro.data import (CIFAR10_LIKE, SMOKE_DATA, dirichlet, iid,
                        make_dataset, shards_per_client)
from repro.fl.comm import CommModel
from repro.metrics import fid_proxy, inception_score_proxy
from repro.metrics.flops import count_params_analytic
from repro.configs import ARCHS


def test_make_dataset_shapes():
    images, labels = make_dataset(SMOKE_DATA, seed=0)
    assert images.shape == (4 * 64, 16, 16, 3)
    assert images.min() >= -1.0 and images.max() <= 1.0
    assert set(np.unique(labels)) == {0, 1, 2, 3}


def test_shards_partition_non_iid():
    _, labels = make_dataset(SMOKE_DATA, seed=0)
    parts = shards_per_client(labels, 4, classes_per_client=1, seed=0)
    assert sum(len(p) for p in parts) <= len(labels)
    for p in parts:
        assert len(np.unique(labels[p])) <= 2   # ~1 class (+shard boundary)


def test_iid_partition_balanced():
    _, labels = make_dataset(SMOKE_DATA, seed=0)
    parts = iid(labels, 4, seed=0)
    counts = [len(np.unique(labels[p])) for p in parts]
    assert all(c == 4 for c in counts)


def test_dirichlet_partition_covers():
    _, labels = make_dataset(SMOKE_DATA, seed=0)
    parts = dirichlet(labels, 5, alpha=0.5, seed=0)
    all_idx = np.concatenate(parts)
    assert len(np.unique(all_idx)) == len(all_idx)


def test_fid_proxy_discriminates():
    """Same-distribution FID << different-distribution FID, and
    FID(x, x) ~ 0 — the property the paper's tables rely on."""
    images, labels = make_dataset(SMOKE_DATA, seed=0)
    a = images[labels < 2]
    b = images[labels >= 2]
    same = fid_proxy(a[:100], a[100:200])
    diff = fid_proxy(a[:100], b[:100])
    noise = np.random.default_rng(0).uniform(-1, 1, a[:100].shape
                                             ).astype(np.float32)
    vs_noise = fid_proxy(a[:100], noise)
    assert same < diff < vs_noise
    assert fid_proxy(a[:128], a[:128]) < 1e-6


def test_inception_score_proxy_positive():
    images, _ = make_dataset(SMOKE_DATA, seed=0)
    score = inception_score_proxy(images[:128])
    assert score >= 1.0


def test_comm_model_matches_paper_constants():
    cm = CommModel()
    V = 136.53e6 * 8 / 8   # FedAvg model bytes (136.53 MB, paper §V-C)
    # edge<->cloud cost factor is 100x the client<->edge factor
    assert cm.edge_cloud(V) / cm.client_edge(V) == pytest.approx(100.0)


def test_param_counts_match_analytic():
    """Analytic #Params (Table IV accounting) matches real init shapes."""
    import jax
    from repro.configs import smoke_variant
    from repro.models import model
    for arch in ["internlm2-20b", "gemma2-2b", "rwkv6-7b",
                 "qwen3-moe-235b-a22b", "command-r-35b"]:
        cfg = smoke_variant(arch)
        params = model.init(jax.random.PRNGKey(0), cfg)
        real = sum(x.size for x in jax.tree.leaves(params))
        analytic = count_params_analytic(cfg)
        assert abs(real - analytic) / real < 0.02, \
            f"{arch}: analytic {analytic} vs real {real}"


def test_checkpoint_roundtrip(tmp_path, rng):
    import jax
    from repro.configs import smoke_variant
    from repro.models import model
    cfg = smoke_variant("gemma2-2b")
    params = model.init(rng, cfg)
    path = os.path.join(tmp_path, "ckpt.npz")
    checkpoint.save(path, params, {"round": 7})
    loaded, meta = checkpoint.load(path)
    assert meta["round"] == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_roundtrip_post_prune(tmp_path, rng):
    """Post-prune state round-trips: the sparse->prune->plain transition
    compacts parameter shapes and resets the stacked Adam moments, and
    the checkpoint must reproduce exactly that — not the init shapes
    (the pre-prune pytree case above)."""
    import jax
    from repro.configs import SMOKE_UNET
    from repro.configs.base import config_from_dict, config_to_dict
    from repro.core import pruning as P
    from repro.fl.engine import stacked_adam_init
    from repro.models import model

    params = model.init(rng, SMOKE_UNET)
    groups = P.build_groups(SMOKE_UNET, params)
    masks = P.make_masks(P.l2_scores(params, groups), groups, 0.44)
    pruned, pruned_cfg, _ = P.compact(params, SMOKE_UNET, groups, masks)
    opt = stacked_adam_init(pruned, n=3)        # reset at the boundary

    path = os.path.join(tmp_path, "ckpt.npz")
    checkpoint.save(path, {"params": pruned, "opt": opt},
                    {"round": 9, "cfg": config_to_dict(pruned_cfg)})
    loaded, meta = checkpoint.load(path)
    assert meta["round"] == 9
    # the compacted ModelConfig (not the seed one) comes back intact
    assert config_from_dict(meta["cfg"]) == pruned_cfg
    for a, b in zip(jax.tree.leaves(pruned), jax.tree.leaves(loaded["params"])):
        assert np.asarray(a).shape == np.asarray(b).shape
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    # stacked per-client Adam rows: compacted shapes with the (N,) axis
    for a, b in zip(jax.tree.leaves(pruned), jax.tree.leaves(loaded["opt"][1])):
        assert np.asarray(b).shape == (3,) + np.asarray(a).shape
        assert not np.asarray(b).any()          # freshly reset moments


def test_full_config_param_counts_sane():
    """Full-size configs land near their nameplate sizes."""
    expected = {"deepseek-v3-671b": (600e9, 750e9),
                "qwen3-moe-235b-a22b": (200e9, 260e9),
                "command-r-35b": (30e9, 40e9),
                "internlm2-20b": (17e9, 23e9),
                "gemma2-2b": (2e9, 3.5e9),
                "rwkv6-7b": (6e9, 9e9),
                "recurrentgemma-9b": (7e9, 11e9)}
    for arch, (lo, hi) in expected.items():
        n = count_params_analytic(ARCHS[arch])
        assert lo <= n <= hi, f"{arch}: {n/1e9:.1f}B outside [{lo/1e9},{hi/1e9}]"
