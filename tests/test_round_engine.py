"""Vectorized round engine: equivalence with the sequential reference,
stacked-epoch pipeline, and mixed-dtype aggregation regression."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import SMOKE_UNET
from repro.configs.base import FLConfig
from repro.core.aggregation import weighted_average
from repro.core.hfl import FedPhD
from repro.data import SMOKE_DATA, ClientData, make_dataset, shards_per_client
from repro.fl.client import Client
from repro.fl.engine import uniform_batch_shape


def make_clients(n=4, batch_size=16):
    """Fresh clients each call: ClientData holds a stateful shuffle RNG,
    so both engines must consume it from the same starting state."""
    images, labels = make_dataset(SMOKE_DATA, seed=0)
    parts = shards_per_client(labels, num_clients=n, classes_per_client=1,
                              seed=0)
    return [Client(i, ClientData(images[p], labels[p],
                                 batch_size=batch_size, seed=i),
                   SMOKE_DATA.num_classes) for i, p in enumerate(parts)]


FL = FLConfig(num_clients=4, num_edges=2, local_epochs=1, edge_agg_every=1,
              cloud_agg_every=2, rounds=4, sparse_rounds=2, prune_ratio=0.44,
              sh_a=1000.0)


def test_engine_equivalence_through_prune():
    """2-edge/4-client: identical params (atol 1e-5) and identical
    comm_gb across the sparse -> prune -> plain transition at r = R_s."""
    seq = FedPhD(SMOKE_UNET, FL, make_clients(), rng_seed=0,
                 engine="sequential")
    h_seq, _ = seq.run(4)
    vec = FedPhD(SMOKE_UNET, FL, make_clients(), rng_seed=0,
                 engine="vectorized")
    h_vec, _ = vec.run(4)

    assert any(h.pruned for h in h_seq), "prune transition must be covered"
    for a, b in zip(h_seq, h_vec):
        assert a.comm_gb == b.comm_gb
        assert a.pruned == b.pruned
        assert np.isclose(a.loss, b.loss, atol=1e-4)
    for x, y in zip(jax.tree.leaves(seq.params), jax.tree.leaves(vec.params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-5)


def test_stacked_epochs_lockstep_with_epoch():
    images, labels = make_dataset(SMOKE_DATA, seed=3)
    a = ClientData(images[:40], labels[:40], batch_size=8, seed=7)
    b = ClientData(images[:40], labels[:40], batch_size=8, seed=7)
    ref = [bt for _ in range(2) for bt in a.epoch()]
    stacked, valid = b.stacked_epochs(2, steps=len(ref) + 3)
    assert valid.sum() == len(ref) and not valid[len(ref):].any()
    for i, bt in enumerate(ref):
        np.testing.assert_array_equal(stacked["images"][i], bt["images"])
        np.testing.assert_array_equal(stacked["labels"][i], bt["labels"])
    # padding repeats the last real batch (masked out by the engine)
    np.testing.assert_array_equal(stacked["images"][-1], ref[-1]["images"])
    with pytest.raises(ValueError):
        b.stacked_epochs(1, steps=1)


def test_uniform_batch_shape_detects_ragged():
    cls = make_clients(4, batch_size=16)
    assert uniform_batch_shape(cls) is not None
    ragged = make_clients(4, batch_size=16)
    ragged[0].data.batch_size = 8
    assert uniform_batch_shape(ragged) is None


def test_engine_vectorized_raises_on_ragged():
    cls = make_clients(4, batch_size=16)
    cls[0].data.batch_size = 8
    trainer = FedPhD(SMOKE_UNET, FL, cls, rng_seed=0, engine="vectorized")
    with pytest.raises(ValueError):
        trainer.run_round(1)


def test_engine_auto_falls_back_on_ragged():
    """Ragged clients silently route to the sequential path, with
    exactly one warning across all rounds (not one per round)."""
    import warnings
    cls = make_clients(4, batch_size=16)
    cls[0].data.batch_size = 8
    trainer = FedPhD(SMOKE_UNET, FL, cls, rng_seed=0, engine="auto")
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        rec1 = trainer.run_round(1)
        rec2 = trainer.run_round(2)
    ragged = [w for w in caught if "sequential" in str(w.message)]
    assert len(ragged) == 1
    assert np.isfinite(rec1.loss) and np.isfinite(rec2.loss)


def test_fedphd_persistent_opt_equivalence():
    """Stacked per-client Adam moments (gather/scatter by participation)
    match the sequential per-client dict threading."""
    seq = FedPhD(SMOKE_UNET, FL, make_clients(), rng_seed=0,
                 engine="sequential", persistent_opt=True, prune=False)
    seq.run(2)
    vec = FedPhD(SMOKE_UNET, FL, make_clients(), rng_seed=0,
                 engine="vectorized", persistent_opt=True, prune=False)
    vec.run(2)
    for a, b in zip(seq.history, vec.history):
        assert a.comm_gb == b.comm_gb
    for x, y in zip(jax.tree.leaves(seq.params), jax.tree.leaves(vec.params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-5)


def test_weighted_average_mixed_dtypes():
    """fp32 accumulation for low-precision leaves; integer leaves (Adam
    t) round-trip instead of truncating to zero."""
    t1 = {"w": jnp.asarray([1.0, 2.0], jnp.bfloat16),
          "t": jnp.asarray(7, jnp.int32),
          "f": np.asarray([0.5, 0.5], np.float32)}
    t2 = {"w": jnp.asarray([3.0, 6.0], jnp.bfloat16),
          "t": jnp.asarray(7, jnp.int32),
          "f": np.asarray([1.5, 2.5], np.float32)}
    out = weighted_average([t1, t2], [1.0, 1.0])
    assert out["w"].dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out["w"], np.float32), [2.0, 4.0])
    # identical step counters survive averaging exactly
    assert out["t"].dtype == jnp.int32 and int(out["t"]) == 7
    np.testing.assert_allclose(np.asarray(out["f"]), [1.0, 1.5])
    # skewed integer weights round to nearest, not truncate
    out2 = weighted_average([t1, t2], [1.0, 3.0])
    assert int(out2["t"]) == 7
