"""Unit tests for the paper's core: SH score (Eqs. 18-20), aggregation
weights (Eqs. 21-24), edge selection (Eq. 25)."""
import numpy as np
import pytest

from repro.core.aggregation import (aggregate_sh, fedavg_weights, sh_weights,
                                    weighted_average)
from repro.core.selection import (ranked_alternatives,
                                  selection_probabilities)
from repro.core.sh_score import (AccumulatedDistribution, label_distribution,
                                 sh_score, uniform_target)


def test_sh_score_uniform_is_max():
    q_u = uniform_target(10)
    assert sh_score(q_u) == pytest.approx(2.0)


def test_sh_score_onehot_is_min():
    q = np.zeros(10)
    q[0] = 1.0
    expected = 2.0 - np.sqrt((1 - 0.1) ** 2 + 9 * 0.01)
    assert sh_score(q) == pytest.approx(expected)
    # one-hot is the least homogeneous distribution
    rng = np.random.default_rng(0)
    for _ in range(50):
        p = rng.dirichlet(np.ones(10))
        assert sh_score(p) >= sh_score(q) - 1e-12


def test_label_distribution():
    labels = np.array([0, 0, 1, 2])
    q = label_distribution(labels, 4)
    np.testing.assert_allclose(q, [0.5, 0.25, 0.25, 0.0])


def test_accumulated_distribution_eq19():
    acc = AccumulatedDistribution(2)
    acc.update(np.array([1.0, 0.0]), 100)     # client A: all class 0
    acc.update(np.array([0.0, 1.0]), 100)     # client B: all class 1
    np.testing.assert_allclose(acc.q, [0.5, 0.5])
    assert acc.sh() == pytest.approx(2.0)
    n2, mu2 = acc.peek_with(np.array([1.0, 0.0]), 200)
    assert n2 == 400
    assert mu2 < 2.0                          # adding skew lowers SH
    acc.refresh()
    assert acc.n == 0


def test_sh_weights_favor_homogeneous():
    w = sh_weights([100, 100], [2.0, 1.0], a=1000.0, b=0.0)
    assert w[0] > w[1]
    assert w.sum() == pytest.approx(1.0)


def test_sh_weights_relu_degenerate_falls_back():
    w = sh_weights([10, 10], [1.0, 1.0], a=-1e9, b=0.0)
    np.testing.assert_allclose(w, fedavg_weights([10, 10]))


def test_weighted_average_pytree():
    t1 = {"a": np.ones((2, 2)), "b": [np.zeros(3)]}
    t2 = {"a": np.zeros((2, 2)), "b": [np.ones(3)]}
    out = weighted_average([t1, t2], [3, 1])
    np.testing.assert_allclose(np.asarray(out["a"]), 0.75)
    np.testing.assert_allclose(np.asarray(out["b"][0]), 0.25)


def test_selection_prefers_balancing_edge():
    """Paper Fig. 5: a client whose data fills an edge's missing class
    should prefer that edge."""
    e0 = AccumulatedDistribution(2)
    e0.update(np.array([1.0, 0.0]), 1000)     # edge 0 heavy on class 0
    e1 = AccumulatedDistribution(2)
    e1.update(np.array([0.3, 0.7]), 1000)     # edge 1 mildly skewed to 1
    q_n = np.array([0.0, 1.0])                # client holds class 1
    p = selection_probabilities([e0, e1], q_n, 500, a=15000.0, b=0.0)
    # adding the client makes edge 0 MORE homogeneous (mu 1.764) but
    # pushes edge 1 further from uniform (mu 1.576)
    assert p[0] > p[1]
    assert p.sum() == pytest.approx(1.0)


def test_selection_load_balance():
    """With equal SH effect, the less-loaded edge wins (the -n_e term)."""
    e0 = AccumulatedDistribution(2)
    e0.update(np.array([0.5, 0.5]), 5000)
    e1 = AccumulatedDistribution(2)
    e1.update(np.array([0.5, 0.5]), 500)
    q_n = np.array([0.5, 0.5])
    p = selection_probabilities([e0, e1], q_n, 100, a=15000.0, b=0.0)
    assert p[1] > p[0]


def test_ranked_alternatives():
    edges = []
    for frac in (0.9, 0.5, 0.1):
        e = AccumulatedDistribution(2)
        e.update(np.array([frac, 1 - frac]), 1000)
        edges.append(e)
    order = ranked_alternatives(edges, np.array([0.0, 1.0]), 500,
                                a=15000.0, b=0.0)
    assert order[0] == 0   # most skewed-to-0 edge benefits most
