"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches
must see the real single CPU device; only dryrun.py forces 512."""
import os

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session", autouse=True)
def fedphd_engine_matrix():
    """CI matrix knob: FEDPHD_ENGINE=sequential|vectorized|auto pins the
    default round engine for every FedPhD / run_flat_fl constructed
    without an explicit engine= (repro.fl.engine.resolve_engine reads
    the env).  Tests that pass engine= explicitly — the equivalence
    suites — are unaffected, so both paths stay covered in every matrix
    leg.  Fails fast on a typo'd value instead of silently running the
    default path twice.
    """
    from repro.fl.engine import ENGINES, resolve_engine
    env = os.environ.get("FEDPHD_ENGINE")
    if env is not None and env not in ENGINES:
        raise RuntimeError(f"FEDPHD_ENGINE={env!r}; expected one of "
                           f"{ENGINES}")
    engine, strict = resolve_engine(None)
    assert not strict and engine == (env or "auto")
    return engine


@pytest.fixture(scope="session", autouse=True)
def fedphd_backend_matrix():
    """CI matrix knob: FEDPHD_BACKEND=xla|pallas|ref pins the default
    compute backend for every trainer/config that does not set
    ``ModelConfig.backend`` explicitly (repro.models.ops.resolve_backend
    reads the env; trainers bake the resolved value into their frozen
    cfg at construction).  The backend-parity tests pass explicit
    backends, so every leg still covers all three.  Fails fast on a
    typo'd value instead of silently running xla thrice.
    """
    from repro.models.ops import BACKENDS, resolve_backend
    env = os.environ.get("FEDPHD_BACKEND")
    # "" behaves like unset (resolve_backend's `or` chain skips it)
    if env and env not in BACKENDS:
        raise RuntimeError(f"FEDPHD_BACKEND={env!r}; expected one of "
                           f"{BACKENDS}")
    backend = resolve_backend(None)
    assert backend == (env or "xla")
    return backend


@pytest.fixture(scope="session", autouse=True)
def fedphd_precision_matrix():
    """CI matrix knob: FEDPHD_PRECISION=fp32|bf16 pins the default
    compute precision for every trainer/config that does not set
    ``ModelConfig.precision`` explicitly (repro.models.ops.
    resolve_precision reads the env; trainers bake the resolved value
    into their frozen cfg at construction, exactly like the backend).
    The precision tests pass explicit values, so both stay covered in
    every leg.  Fails fast on a typo'd value instead of silently
    running fp32 twice.
    """
    from repro.models.ops import PRECISIONS, resolve_precision
    env = os.environ.get("FEDPHD_PRECISION")
    if env and env not in PRECISIONS:
        raise RuntimeError(f"FEDPHD_PRECISION={env!r}; expected one of "
                           f"{PRECISIONS}")
    precision = resolve_precision(None)
    assert precision == (env or "fp32")
    return precision
