"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches
must see the real single CPU device; only dryrun.py forces 512.

The CI matrix knobs (FEDPHD_ENGINE/BACKEND/PRECISION) all route
through repro.experiment.resolve — the one ``explicit > $FEDPHD_* >
default`` code path — so a typo'd leg fails fast here instead of
silently re-running the default path N times.
"""
import jax
import pytest

from repro.experiment.resolve import KNOBS, resolve_knob, validate_env


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def _matrix_knob(name):
    """Validate $<knob.env> and return the resolved default-path value."""
    env = validate_env(name)        # raises on a typo'd value
    resolved = resolve_knob(name)
    assert resolved == (env or KNOBS[name].default)
    return resolved


@pytest.fixture(scope="session", autouse=True)
def fedphd_engine_matrix():
    """CI matrix knob: FEDPHD_ENGINE=sequential|vectorized|auto pins the
    default round engine for every FedPhD / FlatTrainer constructed
    without an explicit engine= (repro.experiment.resolve reads the
    env).  Tests that pass engine= explicitly — the equivalence
    suites — are unaffected, so both paths stay covered in every matrix
    leg.
    """
    return _matrix_knob("engine")


@pytest.fixture(scope="session", autouse=True)
def fedphd_backend_matrix():
    """CI matrix knob: FEDPHD_BACKEND=xla|pallas|ref pins the default
    compute backend for every trainer/config that does not set
    ``ModelConfig.backend`` explicitly (trainers bake the resolved
    value into their frozen cfg at construction).  The backend-parity
    tests pass explicit backends, so every leg still covers all three.
    """
    return _matrix_knob("backend")


@pytest.fixture(scope="session", autouse=True)
def fedphd_precision_matrix():
    """CI matrix knob: FEDPHD_PRECISION=fp32|bf16 pins the default
    compute precision for every trainer/config that does not set
    ``ModelConfig.precision`` explicitly, exactly like the backend.
    The precision tests pass explicit values, so both stay covered in
    every leg.
    """
    return _matrix_knob("precision")
