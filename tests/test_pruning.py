"""Structured-pruning tests: mask ≡ compaction equivalence, depth-aware
lambdas, regularizer monotonicity, kept-count alignment."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SMOKE_UNET, smoke_variant
from repro.configs.base import InputShape
from repro.core import pruning as P
from repro.models import model

TRAIN = InputShape("t", 64, 2, "train")


@pytest.mark.parametrize("arch", ["internlm2-20b", "recurrentgemma-9b",
                                  "rwkv6-7b", "whisper-base"])
def test_mask_equals_compaction(arch, rng):
    """Zeroing pruned channels and physically slicing them must give the
    same loss (the central invariant of the two-phase TPU adaptation)."""
    cfg = smoke_variant(arch)
    params = model.init(rng, cfg)
    groups = P.build_groups(cfg, params)
    masks = P.make_masks(P.l2_scores(params, groups), groups, 0.44)
    batch = model.make_inputs(rng, cfg, TRAIN)
    l_masked = model.loss_fn(P.apply_masks(params, groups, masks), cfg,
                             batch, rng)
    np2, cfg2, _ = P.compact(params, cfg, groups, masks)
    l_compact = model.loss_fn(np2, cfg2, batch, rng)
    np.testing.assert_allclose(float(l_masked), float(l_compact), rtol=1e-5)


def test_unet_mask_equals_compaction(rng):
    cfg = SMOKE_UNET
    params = model.init(rng, cfg)
    groups = P.build_groups(cfg, params)
    masks = P.make_masks(P.l2_scores(params, groups), groups, 0.44)
    batch = model.make_inputs(rng, cfg, InputShape("t", 0, 4, "train"))
    l_masked = model.loss_fn(P.apply_masks(params, groups, masks), cfg,
                             batch, rng)
    np2, cfg2, _ = P.compact(params, cfg, groups, masks)
    l_compact = model.loss_fn(np2, cfg2, batch, rng)
    np.testing.assert_allclose(float(l_masked), float(l_compact), rtol=1e-5)


def test_compaction_reduces_params(rng):
    cfg = SMOKE_UNET
    params = model.init(rng, cfg)
    groups = P.build_groups(cfg, params)
    masks = P.make_masks(P.l2_scores(params, groups), groups, 0.44)
    np2, _, report = P.compact(params, cfg, groups, masks)
    n0 = sum(x.size for x in jax.tree.leaves(params))
    n1 = sum(x.size for x in jax.tree.leaves(np2))
    assert n1 < 0.7 * n0
    for name, (kept, size) in report.items():
        assert 0 < kept <= size


def test_depth_aware_lambda_middle_largest(rng):
    """Eq. 17: lambda_g = lambda0 / Q — middle layers get the largest
    regularization pressure."""
    cfg = SMOKE_UNET
    params = model.init(rng, cfg)
    groups = P.build_groups(cfg, params)
    lam = P.depth_lambdas(groups, 1e-3)
    max_layer = max(max(g.layer_indices) for g in groups)
    mid = max_layer / 2
    by_dist = sorted(
        ((abs(g.layer_indices[0] - mid), float(lam[g.name][0]))
         for g in groups), key=lambda t: t[0])
    assert by_dist[0][1] >= by_dist[-1][1]


def test_omega_decreases_when_weights_shrink(rng):
    cfg = smoke_variant("internlm2-20b")
    params = model.init(rng, cfg)
    groups = P.build_groups(cfg, params)
    lam = P.depth_lambdas(groups, 1e-4)
    om1 = float(P.omega(params, groups, lam))
    smaller = jax.tree.map(lambda x: x * 0.5, params)
    om2 = float(P.omega(smaller, groups, lam))
    assert om2 == pytest.approx(om1 * 0.25, rel=1e-3)
    assert om1 > 0


def _group(size, unit="channel"):
    return P.PruneGroup(name=f"g{size}{unit}", size=size, members=(),
                        unit=unit)


def test_alignment_for_128_boundary():
    """align flips 8 -> 128 exactly when the group is >=1024 wide AND
    divisible by 128 (DESIGN.md §3.1)."""
    assert P.masks.alignment_for(_group(1024)) == 128
    assert P.masks.alignment_for(_group(1152)) == 128
    assert P.masks.alignment_for(_group(1016)) == 8     # <1024, %8==0
    assert P.masks.alignment_for(_group(1040)) == 8     # >=1024, %128!=0
    assert P.masks.alignment_for(_group(1023)) == 1     # divides neither
    assert P.masks.alignment_for(_group(16)) == 8
    assert P.masks.alignment_for(_group(12)) == 1       # <16 never rounded


def test_kept_count_128_rounding_at_1024():
    # 1024 * (1-0.44) = 573.44 -> nearest 128-multiple of round(573.44)
    assert P.kept_count(_group(1024), 0.44) == 512
    assert P.kept_count(_group(1024), 0.0) == 1024      # never exceeds size
    # 1024 * 0.56 -> 573 -> but a hair under the .5 crossover rounds up
    assert P.kept_count(_group(1024), 0.40) == 640      # 614.4 -> 5*128
    assert P.kept_count(_group(1152), 0.44) == 640      # 645.1 -> 5*128


def test_kept_count_clamps_to_alignment():
    """Extreme ratios clamp to one full alignment unit, never zero."""
    assert P.kept_count(_group(16), 0.99) == 8          # round(0.16)->1->8
    assert P.kept_count(_group(1024), 0.999) == 128
    assert P.kept_count(_group(64), 1.0) == 8
    assert P.kept_count(_group(12), 1.0) == 1           # align=1: floor 1


def test_kept_count_heads_and_experts_unrounded():
    for unit in ("head", "expert"):
        assert P.masks.alignment_for(_group(32, unit)) == 1
        assert P.kept_count(_group(32, unit), 0.44) == 18   # round(17.92)
        assert P.kept_count(_group(32, unit), 0.99) == 1


def test_oneshot_random_prunes(rng):
    cfg = smoke_variant("qwen3-moe-235b-a22b")
    params = model.init(rng, cfg)
    groups = P.build_groups(cfg, params)
    scores = P.random_scores(rng, groups)
    masks = P.make_masks(scores, groups, 0.5)
    np2, cfg2, _ = P.compact(params, cfg, groups, masks)
    assert cfg2.moe.num_experts < cfg.moe.num_experts
    batch = model.make_inputs(rng, cfg2, TRAIN)
    loss = model.loss_fn(np2, cfg2, batch, rng)
    assert not bool(jnp.isnan(loss))
