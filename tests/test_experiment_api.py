"""Unified experiment API: spec JSON round-trip, the method registry,
the one-schema method grid, and kill-and-resume checkpointing
(bitwise on the sequential engine, atol 1e-5 on the vectorized one,
including the post-prune compacted state).

Runs on a registered micro U-Net (8x8, 8 channels): the grid is six
methods and MOON traces three model applications, so compile time
dominates at any larger scale.
"""
import dataclasses
import json
import os

import jax
import numpy as np
import pytest

from repro.configs import SMOKE_UNET, register_config
from repro.configs.base import FLConfig
from repro.data.synthetic import DatasetSpec
from repro.experiment import (DataSpec, Experiment, ExperimentSpec,
                              RoundRecord, Trainer, make_clients,
                              register_dataset, register_method,
                              registered_methods, run_spec)
from repro.experiment import runner as exp_runner
from repro.fl.record import RunResult

TINY_UNET = SMOKE_UNET.replace(name="ddpm-unet-tiny-exp", image_size=8,
                               base_channels=8, channel_mults=(1,),
                               num_res_blocks=1, attn_resolutions=())
register_config("ddpm-unet-tiny-exp", TINY_UNET, overwrite=True)
register_dataset("tiny-exp", DatasetSpec("tiny-exp", num_classes=4,
                                         image_size=8, samples_per_class=32),
                 overwrite=True)

GRID_METHODS = ("fedphd", "fedavg", "fedprox", "moon", "scaffold",
                "feddiffuse")

SPEC = ExperimentSpec(
    name="tiny", method="fedphd", model="ddpm-unet-tiny-exp",
    fl=FLConfig(num_clients=4, num_edges=2, local_epochs=1,
                edge_agg_every=1, cloud_agg_every=2, rounds=4,
                sparse_rounds=2, prune_ratio=0.44, sh_a=1000.0),
    data=DataSpec(dataset="tiny-exp", batch_size=8),
    engine="sequential")


def assert_trees_equal(a, b, *, bitwise=True, atol=1e-5):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        if bitwise:
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        else:
            np.testing.assert_allclose(np.asarray(x, np.float32),
                                       np.asarray(y, np.float32), atol=atol)


def run_broken(spec, *, path, split: int, total: int, clients=None):
    """Run ``split`` rounds, checkpoint, then resume to ``total`` in a
    freshly loaded experiment — the kill-and-resume trajectory."""
    run_spec(spec, rounds=split, ckpt=path, clients=clients)
    return run_spec(None, resume=True, ckpt=path, rounds=total)


# ---------------------------------------------------------------------------
# Spec + registry.
# ---------------------------------------------------------------------------

def test_spec_json_roundtrip():
    spec = SPEC.replace(engine="vectorized", persistent_opt=True,
                        eval_every=3, selection="random")
    assert ExperimentSpec.from_json(spec.to_json()) == spec
    # JSON is pure data: nested configs come back as frozen dataclasses
    loaded = ExperimentSpec.from_json(spec.to_json())
    assert isinstance(loaded.fl, FLConfig) and loaded.fl == spec.fl
    assert loaded.data == spec.data


def test_registry_resolves_all_methods():
    for m in ("fedphd", "fedphd-os", "fedavg", "fedprox", "moon",
              "scaffold", "feddiffuse"):
        assert m in registered_methods()
    with pytest.raises(KeyError):
        Experiment(SPEC.replace(method="nope"))
    with pytest.raises(ValueError):   # topology consistency assertion
        Experiment(SPEC.replace(method="fedavg", topology="hierarchical"))


def test_register_custom_method():
    calls = {}

    class StubTrainer:
        def __init__(self):
            self.history, self.params, self.cfg = [], {}, TINY_UNET

        def run_round(self, r):
            rec = RoundRecord(round=r, loss=0.0, comm_gb=0.0)
            self.history.append(rec)
            return rec

        def run(self, rounds):
            for r in range(len(self.history) + 1, rounds + 1):
                self.run_round(r)
            return RunResult(self.history, [])

        def state(self):
            return {}, {"history": []}

        def restore(self, arrays, meta):
            pass

    def factory(spec, cfg, clients, eval_fn):
        calls["spec"] = spec
        return StubTrainer()

    with pytest.raises(ValueError):   # collision guard
        register_method("fedavg", "flat", factory)
    register_method("stub-method", "flat", factory, overwrite=True)
    exp = run_spec(SPEC.replace(method="stub-method"), rounds=2)
    assert isinstance(exp.trainer, Trainer)   # runtime protocol check
    assert calls["spec"].method == "stub-method"
    assert [r.round for r in exp.history] == [1, 2]


# ---------------------------------------------------------------------------
# The grid: six methods, one schema, one eval-hook contract.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", GRID_METHODS)
def test_grid_one_schema(method):
    evaluated = []

    def eval_fn(params, cfg, r):
        evaluated.append(r)
        return float(sum(np.asarray(x, np.float32).sum()
                         for x in jax.tree.leaves(params)))

    spec = SPEC.replace(method=method, eval_every=2, prune=False)
    exp = run_spec(spec, rounds=2, eval_fn=eval_fn)
    assert len(exp.history) == 2
    for rec in exp.history:
        assert isinstance(rec, RoundRecord)
        assert np.isfinite(rec.loss) and rec.comm_gb > 0
        assert rec.params_m > 0 and rec.selected
        # dict-style access (legacy flat-history consumers)
        assert rec["loss"] == rec.loss
    # unified eval contract: the hook ran once, at round 2, and its
    # result landed in RoundRecord.eval for BOTH topologies
    assert evaluated == [2]
    assert exp.history[0].eval is None
    assert isinstance(exp.history[1].eval, float)
    # edge_sh only exists on the hierarchical topology
    assert (exp.history[0].edge_sh is not None) == (method == "fedphd")


# ---------------------------------------------------------------------------
# Kill-and-resume.
# ---------------------------------------------------------------------------

def test_resume_bitwise_sequential_through_prune(tmp_path):
    """Checkpoint at the pruning round, resume, and match an unbroken
    run bitwise: params AND history (incl. comm_gb and the post-prune
    params_m)."""
    unbroken = run_spec(SPEC)
    resumed = run_broken(SPEC, path=str(tmp_path / "ck.npz"),
                         split=2, total=4)
    assert any(r.pruned for r in unbroken.history)
    assert_trees_equal(unbroken.params, resumed.params, bitwise=True)
    assert [r.to_dict() for r in unbroken.history] \
        == [r.to_dict() for r in resumed.history]
    assert resumed.cfg == unbroken.cfg          # compacted ModelConfig


@pytest.mark.parametrize("method", ["scaffold", "moon", "feddiffuse"])
def test_resume_bitwise_flat_state(method, tmp_path):
    """Per-client ctx state (SCAFFOLD variates, MOON prev models,
    FedDiffuse local subtrees) + stacked persistent-Adam buffers survive
    the checkpoint bitwise; partial participation exercises the
    seen-mask defaulting."""
    spec = SPEC.replace(
        method=method, persistent_opt=True,
        fl=dataclasses.replace(SPEC.fl, num_edges=1, participation=0.5))
    unbroken = run_spec(spec, rounds=3)
    resumed = run_broken(spec, path=str(tmp_path / "ck.npz"),
                         split=2, total=3)
    assert_trees_equal(unbroken.params, resumed.params, bitwise=True)
    assert [r.to_dict() for r in unbroken.history] \
        == [r.to_dict() for r in resumed.history]


def test_mid_run_checkpoint_cadence(tmp_path):
    """``save_every`` writes resumable snapshots DURING the run, so a
    killed process loses at most that many rounds (the final save
    belongs to run_spec)."""
    path = str(tmp_path / "ck.npz")
    exp = Experiment(SPEC.replace(prune=False))
    exp.run(2, ckpt=path, save_every=1)
    # the on-disk state is the round-1 snapshot: a kill during round 2
    # resumes from there
    assert Experiment.load(path).next_round == 2


def test_resume_rejects_conflicting_spec(tmp_path):
    path = str(tmp_path / "ck.npz")
    run_spec(SPEC.replace(prune=False), rounds=1, ckpt=path)
    with pytest.raises(ValueError):
        run_spec(SPEC, resume=True, ckpt=path)


def test_resume_vectorized_close(tmp_path):
    """prune=False: the sparse engine is rebuilt per trainer (groups
    aren't hashable, so it skips the engine memo) and three trainers'
    worth of sparse compiles dominate the suite; the vectorized
    prune transition is already equivalence-locked in
    test_round_engine.py and resumed bitwise sequentially above."""
    spec = SPEC.replace(engine="vectorized", prune=False)
    unbroken = run_spec(spec)
    resumed = run_broken(spec, path=str(tmp_path / "ck.npz"),
                         split=2, total=4)
    assert_trees_equal(unbroken.params, resumed.params,
                       bitwise=False, atol=1e-5)
    for a, b in zip(unbroken.history, resumed.history):
        assert a.comm_gb == b.comm_gb and a.selected == b.selected


def test_post_prune_checkpoint_state(tmp_path):
    """Save AFTER the sparse->prune->plain transition: the reloaded
    trainer carries the compacted shapes, the reset (then re-trained)
    stacked Adam moments, the round counter, and the refreshed edge
    distributions."""
    spec = SPEC.replace(persistent_opt=True)
    path = str(tmp_path / "ck.npz")
    a = run_spec(spec, rounds=3, ckpt=path)     # prune fires at r=2
    assert any(r.pruned for r in a.history)
    b = Experiment.load(path)
    assert b.next_round == 4
    assert b.trainer.pruned and b.cfg == a.cfg
    # compacted param shapes survive exactly
    sa = [np.asarray(x).shape for x in jax.tree.leaves(a.params)]
    sb = [np.asarray(x).shape for x in jax.tree.leaves(b.params)]
    assert sa == sb
    # stacked persistent-Adam buffers were rebuilt at the prune boundary
    # to the compacted shapes and restored as such
    n = spec.fl.num_clients
    for p, m in zip(jax.tree.leaves(a.params),
                    jax.tree.leaves(b.trainer._opt_stack.mu)):
        assert m.shape == (n,) + np.asarray(p).shape
    # edge AccumulatedDistributions round-trip exactly
    for ea, eb in zip(a.trainer.edges, b.trainer.edges):
        assert ea.n == eb.n
        np.testing.assert_array_equal(ea.counts, eb.counts)


# ---------------------------------------------------------------------------
# CLI runner.
# ---------------------------------------------------------------------------

def test_runner_cli_run_then_resume(tmp_path):
    spec_path = tmp_path / "spec.json"
    out = str(tmp_path / "out")
    spec_path.write_text(SPEC.to_json())
    exp_runner.main(["--spec", str(spec_path), "--rounds", "1",
                     "--out", out])
    exp = exp_runner.main(["--out", out, "--resume", "--rounds", "2"])
    assert exp.next_round == 3
    with open(os.path.join(out, "history.json")) as f:
        hist = json.load(f)
    assert [h["round"] for h in hist["history"]] == [1, 2]
    assert hist["spec"]["method"] == "fedphd"
    # the resolved spec is materialized next to the checkpoint
    with open(os.path.join(out, "spec.json")) as f:
        assert ExperimentSpec.from_json(f.read()) == SPEC


# ---------------------------------------------------------------------------
# Legacy entry-point shims.
# ---------------------------------------------------------------------------

def test_legacy_entrypoints_still_work():
    """`FedPhD(...).run()` still unpacks as (history, evals) and
    `run_flat_fl` still returns FlatFLResult with dict-style history."""
    from repro.core.hfl import FedPhD
    from repro.fl.baselines import run_flat_fl

    clients, _, _ = make_clients(SPEC)
    evals_seen = []

    def eval_fn(params, cfg, r):
        evals_seen.append(r)
        return 1.25

    trainer = FedPhD(TINY_UNET, SPEC.fl, clients, rng_seed=0,
                     engine="sequential", prune=False, eval_fn=eval_fn)
    hist, evals = trainer.run(2, eval_every=2)
    assert hist is trainer.history and len(hist) == 2
    assert evals == [(2, 1.25)] and evals_seen == [2]
    assert hist[1].eval == 1.25                 # unified hook contract

    clients, _, _ = make_clients(SPEC)
    with pytest.warns(DeprecationWarning, match="run_flat_fl"):
        res = run_flat_fl("fedavg", TINY_UNET, SPEC.fl, clients, rounds=1,
                          rng_seed=0, engine="sequential")
    assert res.history[0]["comm_gb"] == res.history[0].comm_gb
    assert res.history[0]["round"] == 1


def test_use_flash_deprecated():
    """The flash boolean was subsumed by the backend axis; the shim
    still routes to the pallas attention path but warns."""
    from repro.models.common import ApplyOptions

    with pytest.warns(DeprecationWarning, match="use_flash"):
        ApplyOptions(use_flash=True)
    ApplyOptions()                         # the default stays silent
