"""Sweep subsystem: grid expansion (cartesian + include/exclude +
dedup + stable run-ids), the resumable manifest (kill mid-grid AND
mid-run, resume, match an unbroken sweep), aggregation math against
hand-computed values, and the CLI ``--sweep`` round-trip.

Runs on the same micro U-Net scale as test_experiment_api.py: sweeps
multiply whole experiment runs, so everything here is 2 rounds on an
8x8 model (except the process-pool smoke, which must use a *built-in*
model config — spawned workers re-import repro and never see this
module's registrations).
"""
import json
import os

import numpy as np
import pytest

from repro.configs import SMOKE_UNET, register_config
from repro.configs.base import FLConfig
from repro.data.synthetic import DatasetSpec
from repro.experiment import (DataSpec, ExperimentSpec, SweepResult,
                              SweepSpec, build_report, load_manifest,
                              register_dataset, report_markdown, run_id_of,
                              run_spec, run_sweep, spec_get, spec_with)
from repro.experiment import runner as exp_runner
from repro.experiment.sweep import manifest_status

TINY_UNET = SMOKE_UNET.replace(name="ddpm-unet-tiny-sweep", image_size=8,
                               base_channels=8, channel_mults=(1,),
                               num_res_blocks=1, attn_resolutions=())
register_config("ddpm-unet-tiny-sweep", TINY_UNET, overwrite=True)
register_dataset("tiny-sweep", DatasetSpec("tiny-sweep", num_classes=4,
                                           image_size=8,
                                           samples_per_class=32),
                 overwrite=True)

BASE = ExperimentSpec(
    name="sweep-base", method="fedavg", model="ddpm-unet-tiny-sweep",
    fl=FLConfig(num_clients=4, num_edges=1, local_epochs=1,
                edge_agg_every=1, cloud_agg_every=2, rounds=2,
                sparse_rounds=2, sh_a=1000.0),
    data=DataSpec(dataset="tiny-sweep", batch_size=8),
    engine="sequential", prune=False)


# ---------------------------------------------------------------------------
# Expansion.
# ---------------------------------------------------------------------------

def test_sweep_json_roundtrip():
    sweep = SweepSpec(name="t", base=BASE,
                      axes={"seed": [0, 1], "fl.participation": [0.5, 1.0]},
                      include=[{"seed": 7}],
                      exclude=[{"seed": 1, "fl.participation": 0.5}],
                      rounds=3, group_by=("fl.participation",))
    assert SweepSpec.from_json(sweep.to_json()) == sweep
    loaded = SweepSpec.from_json(sweep.to_json())
    assert isinstance(loaded.base, ExperimentSpec) and loaded.base == BASE


def test_expand_cartesian_order_and_ids():
    sweep = SweepSpec(name="t", base=BASE,
                      axes={"seed": [0, 1],
                            "method": ["fedavg", "fedprox"]})
    runs = sweep.expand()
    # deterministic: sorted axis names, values in declared order
    assert [r.run_id for r in runs] == [
        "method=fedavg,seed=0", "method=fedavg,seed=1",
        "method=fedprox,seed=0", "method=fedprox,seed=1"]
    # overrides are applied and everything else inherits the base
    assert runs[2].spec.method == "fedprox" and runs[2].spec.seed == 0
    assert runs[2].spec.fl == BASE.fl
    # specs are named by the grid point
    assert runs[0].spec.name == "t/method=fedavg,seed=0"
    # re-expansion is stable
    assert [r.run_id for r in sweep.expand()] == [r.run_id for r in runs]


def test_expand_nested_axes_include_exclude_dedup():
    sweep = SweepSpec(
        name="t", base=BASE,
        axes={"fl.participation": [0.5, 1.0], "seed": [0, 1]},
        # exclude matches on EFFECTIVE values (override or base field)
        exclude=[{"fl.participation": 0.5, "seed": 1},
                 {"method": "fedprox"}],        # base is fedavg: no hit
        include=[{"data.batch_size": 4},
                 # duplicates the (1.0, seed=0) grid point's concrete
                 # spec exactly -> deduped
                 {"fl.participation": 1.0, "seed": 0}])
    runs = sweep.expand()
    ids = [r.run_id for r in runs]
    assert "fl.participation=0.5,seed=1" not in ids        # excluded
    assert ids.count("fl.participation=1.0,seed=0") == 1   # deduped
    assert "data.batch_size=4" in ids                      # included
    by_id = {r.run_id: r for r in runs}
    assert by_id["data.batch_size=4"].spec.data.batch_size == 4
    assert by_id["fl.participation=0.5,seed=0"].spec.fl.participation == 0.5
    assert len(runs) == 4    # 4 grid - 1 excluded - 0 + 2 incl - 1 dedup


def test_expand_unknown_axis_raises():
    for axes in ({"nope": [1]}, {"fl.nope": [1]}, {"fl.rounds.x": [1]}):
        with pytest.raises(ValueError, match="axis"):
            SweepSpec(base=BASE, axes=axes).expand()


def test_from_dict_rejects_unknown_fields():
    """A typoed sweep JSON ("axis", "excludes") must fail loudly, not
    silently run a different grid."""
    good = SweepSpec(name="s", base=BASE).to_dict()
    for typo in ("axis", "excludes", "includ"):
        with pytest.raises(ValueError, match="unknown SweepSpec"):
            SweepSpec.from_dict({**good, typo: []})


def test_spec_paths_and_run_ids():
    assert spec_get(BASE, "fl.rounds") == 2
    assert spec_get(BASE.to_dict(), "data.batch_size") == 8
    s = spec_with(BASE, {"fl.rounds": 5, "method": "moon"})
    assert s.fl.rounds == 5 and s.method == "moon"
    assert s.data == BASE.data                   # untouched nested spec
    # ids are stable under dict ordering and filesystem-safe
    assert run_id_of({"seed": 0, "method": "fedavg"}) \
        == run_id_of({"method": "fedavg", "seed": 0}) \
        == "method=fedavg,seed=0"
    assert run_id_of({}) == "base"
    assert "/" not in run_id_of({"model": "a/b c"})


# ---------------------------------------------------------------------------
# Aggregation math (hand-computed; no training).
# ---------------------------------------------------------------------------

def _hist(rows):
    return [{"round": i + 1, "loss": l, "comm_gb": c, "params_m": p,
             "selected": [0], "eval": e, "edge_sh": None, "pruned": False}
            for i, (l, c, p, e) in enumerate(rows)]


def _manifest(sweep, entries):
    return {"format": 1, "sweep": sweep.to_dict(),
            "runs": {rid: e for rid, e in entries}}


def _entry(overrides, hist, wall=None, status="done"):
    return {"status": status, "overrides": overrides,
            "spec": spec_with(BASE, overrides).to_dict(), "ckpt": "x",
            "rounds_done": len(hist), "wall_s": wall, "history": hist,
            "error": None}


def test_aggregation_mean_std_group_by():
    sweep = SweepSpec(name="agg", base=BASE,
                      axes={"method": ["fedavg", "moon"], "seed": [0, 1]})
    man = _manifest(sweep, [
        ("method=fedavg,seed=0", _entry(
            {"method": "fedavg", "seed": 0},
            _hist([(0.5, 0.25, 1.0, None),
                   (1.0, 0.25, 1.0, {"fid": 10.0, "tag": "x"})]))),
        ("method=fedavg,seed=1", _entry(
            {"method": "fedavg", "seed": 1},
            _hist([(3.0, 0.5, 1.0, None),
                   (2.0, 0.5, 1.0, {"fid": 20.0, "ok": True})]))),
        ("method=moon,seed=0", _entry(
            {"method": "moon", "seed": 0},
            _hist([(4.0, 1.0, 2.0, None)]))),
        ("method=moon,seed=1", _entry(
            {"method": "moon", "seed": 1}, [], status="pending")),
    ])
    rep = build_report(man)                 # default group_by: ("method",)
    assert rep["group_by"] == ["method"]
    assert rep["total_runs"] == 4 and rep["done"] == 3
    assert not rep["complete"]

    g = {grp["key"]["method"]: grp for grp in rep["groups"]}
    fa = g["fedavg"]
    assert fa["n"] == 2
    # loss: final-round values 1.0 and 2.0 -> mean 1.5, population std 0.5
    assert fa["metrics"]["loss"] == {"mean": 1.5, "std": 0.5, "min": 1.0,
                                     "max": 2.0, "n": 2}
    # comm_gb: per-run TOTALS 0.5 and 1.0 -> mean 0.75, std 0.25
    assert fa["metrics"]["comm_gb"]["mean"] == pytest.approx(0.75)
    assert fa["metrics"]["comm_gb"]["std"] == pytest.approx(0.25)
    # eval.fid from the last recorded eval; non-numeric/bool keys dropped
    assert fa["metrics"]["eval.fid"]["mean"] == pytest.approx(15.0)
    assert fa["metrics"]["eval.fid"]["std"] == pytest.approx(5.0)
    assert "eval.tag" not in fa["metrics"]
    assert "eval.ok" not in fa["metrics"]
    # the pending moon seed=1 run is excluded: n=1, std collapses to 0
    mo = g["moon"]
    assert mo["n"] == 1
    assert mo["metrics"]["loss"] == {"mean": 4.0, "std": 0.0, "min": 4.0,
                                     "max": 4.0, "n": 1}

    # explicit group-by on a non-axis field groups everything together
    rep2 = build_report(man, group_by=("model",))
    assert len(rep2["groups"]) == 1
    assert rep2["groups"][0]["n"] == 3
    assert rep2["groups"][0]["metrics"]["loss"]["mean"] \
        == pytest.approx((1.0 + 2.0 + 4.0) / 3)


def test_report_markdown_table():
    sweep = SweepSpec(name="md", base=BASE, axes={"seed": [0, 1]},
                      group_by=("method",))
    man = _manifest(sweep, [
        ("seed=0", _entry({"seed": 0},
                          _hist([(1.0, 0.5, 1.0, None)]), wall=2.0)),
        ("seed=1", _entry({"seed": 1},
                          _hist([(2.0, 0.5, 1.0, None)]), wall=4.0)),
    ])
    md = report_markdown(build_report(man))
    lines = md.splitlines()
    assert lines[0].startswith("# sweep `md` — 2/2 runs")
    assert "| method | n | loss | comm_gb | params_m | wall_s |" in md
    # one data row: both seeds aggregate into the single fedavg group
    assert "| fedavg | 2 | 1.5 ± 0.5 |" in md


# ---------------------------------------------------------------------------
# Kill-and-resume: broken == unbroken (acceptance criterion).
# ---------------------------------------------------------------------------

def test_sweep_kill_and_resume_equals_unbroken(tmp_path):
    """Stop a sweep mid-grid (limit as the deterministic kill), pre-seed
    a second run's checkpoint to simulate a mid-run kill, resume, and
    match an unbroken sweep: identical run-id set, per-run histories,
    and aggregated report metrics (atol 1e-5)."""
    sweep = SweepSpec(name="kr", base=BASE,
                      axes={"method": ["fedavg", "fedphd"],
                            "seed": [0, 1]})
    runs = sweep.expand()
    assert len(runs) == 4

    unbroken = run_sweep(sweep, str(tmp_path / "unbroken"),
                         raise_on_error=True)
    assert unbroken.complete

    out = str(tmp_path / "broken")
    # kill #1: mid-grid after one run
    res1 = run_sweep(sweep, out, limit=1, raise_on_error=True)
    counts = manifest_status(res1.manifest)
    assert counts["done"] == 1 and counts["pending"] == 3
    # kill #2: one of the remaining runs dies mid-run — simulate by
    # running its spec to round 1 of 2 against the sweep's own ckpt path
    victim = runs[2]
    ckpt = os.path.join(out, "runs", victim.run_id, "ckpt.npz")
    os.makedirs(os.path.dirname(ckpt), exist_ok=True)
    run_spec(victim.spec, rounds=1, ckpt=ckpt)
    # resume: the manifest skips the done run, the victim continues from
    # its round-1 checkpoint, the rest run fresh
    res2 = run_sweep(sweep, out, raise_on_error=True)
    assert res2.complete
    assert res2.manifest["runs"][victim.run_id]["rounds_done"] == 2

    assert set(res2.manifest["runs"]) == set(unbroken.manifest["runs"])
    for rid in unbroken.manifest["runs"]:
        ha = unbroken.manifest["runs"][rid]["history"]
        hb = res2.manifest["runs"][rid]["history"]
        assert len(ha) == len(hb) == 2
        for ra, rb in zip(ha, hb):
            assert rb["loss"] == pytest.approx(ra["loss"], abs=1e-5)
            assert ra["comm_gb"] == rb["comm_gb"]
            assert ra["selected"] == rb["selected"]

    rep_a = build_report(unbroken.manifest)
    rep_b = build_report(res2.manifest)
    assert rep_a["complete"] and rep_b["complete"]
    for ga, gb in zip(rep_a["groups"], rep_b["groups"]):
        assert ga["key"] == gb["key"] and ga["n"] == gb["n"]
        for m in ("loss", "comm_gb", "params_m"):
            assert gb["metrics"][m]["mean"] \
                == pytest.approx(ga["metrics"][m]["mean"], abs=1e-5)
            assert gb["metrics"][m]["std"] \
                == pytest.approx(ga["metrics"][m]["std"], abs=1e-5)


def test_manifest_reconciles_edited_sweep(tmp_path):
    """Editing the sweep keeps completed runs whose spec is unchanged,
    resets changed ones, and drops stale run-ids."""
    out = str(tmp_path / "sw")
    s1 = SweepSpec(name="e", base=BASE, axes={"seed": [0, 1]})
    run_sweep(s1, out, raise_on_error=True)
    # grow the grid: seed 0/1 stay done, seed 2 is pending
    s2 = s1.replace(axes={"seed": [0, 1, 2]})
    from repro.experiment.sweep import init_manifest
    man = init_manifest(s2, out)
    assert man["runs"]["seed=0"]["status"] == "done"
    assert man["runs"]["seed=2"]["status"] == "pending"
    # change the base: every run's spec changed -> everything resets
    s3 = s1.replace(base=BASE.replace(lr=1e-3))
    man = init_manifest(s3, out)
    assert all(e["status"] == "pending" for e in man["runs"].values())
    assert "seed=2" not in man["runs"]           # stale id dropped
    # the reset runs must RERUN under the edited spec, not resume the
    # stale old-lr checkpoints sitting at the same run-id paths
    res = run_sweep(s3, out, raise_on_error=True)
    assert res.complete
    ckpt = os.path.join(out, res.manifest["runs"]["seed=0"]["ckpt"])
    with open(ckpt + ".manifest.json") as f:
        saved_spec = json.load(f)["metadata"]["spec"]
    assert saved_spec["lr"] == pytest.approx(1e-3)


def test_sweep_rounds_extension_reruns_done_runs(tmp_path):
    """Raising the sweep-level round target re-enters 'done' runs and
    EXTENDS them from their checkpoints — a finished sweep re-invoked
    with more rounds must not report the old short histories as
    complete."""
    out = str(tmp_path / "ext")
    sweep = SweepSpec(name="ext", base=BASE, axes={"seed": [0, 1]},
                      rounds=1)
    res = run_sweep(sweep, out, raise_on_error=True)
    assert all(e["rounds_done"] == 1
               for e in res.manifest["runs"].values())
    res = run_sweep(sweep.replace(rounds=2), out, raise_on_error=True)
    assert res.complete
    for e in res.manifest["runs"].values():
        assert e["rounds_done"] == 2
        assert [r["round"] for r in e["history"]] == [1, 2]
    # and an unchanged re-invocation is a no-op (nothing re-runs)
    before = json.dumps(res.manifest["runs"], sort_keys=True)
    res = run_sweep(sweep.replace(rounds=2), out, raise_on_error=True)
    assert json.dumps(res.manifest["runs"], sort_keys=True) == before


def test_failed_run_recorded_and_sweep_continues(tmp_path):
    sweep = SweepSpec(name="f", base=BASE,
                      axes={"model": ["ddpm-unet-tiny-sweep", "nope"]})
    res = run_sweep(sweep, str(tmp_path / "f"))
    sts = {rid: e["status"] for rid, e in res.manifest["runs"].items()}
    assert sts["model=nope"] == "failed"
    assert sts["model=ddpm-unet-tiny-sweep"] == "done"
    assert "nope" in res.manifest["runs"]["model=nope"]["error"]
    rep = build_report(res.manifest)
    assert not rep["complete"] and rep["done"] == 1


# ---------------------------------------------------------------------------
# CLI --sweep round-trip.
# ---------------------------------------------------------------------------

def test_runner_cli_sweep_roundtrip(tmp_path):
    sweep = SweepSpec(name="cli", base=BASE, axes={"seed": [0, 1]},
                      group_by=("method",))
    sweep_path = tmp_path / "grid.json"
    sweep_path.write_text(sweep.to_json())
    out = str(tmp_path / "out")

    # "kill" after one run, then resume with the SAME command line
    res = exp_runner.main(["--sweep", str(sweep_path), "--out", out,
                           "--max-runs", "1"])
    assert isinstance(res, SweepResult)
    assert manifest_status(res.manifest)["done"] == 1
    res = exp_runner.main(["--sweep", str(sweep_path), "--out", out])
    assert res.complete

    man = load_manifest(out)
    assert sorted(man["runs"]) == ["seed=0", "seed=1"]
    with open(os.path.join(out, "report.json")) as f:
        rep = json.load(f)
    assert rep["complete"] and rep["done"] == 2
    assert rep["groups"][0]["key"] == {"method": "fedavg"}
    assert rep["groups"][0]["metrics"]["loss"]["n"] == 2
    with open(os.path.join(out, "report.md")) as f:
        assert "| method | n |" in f.read()


def test_runner_cli_sweep_fails_on_failed_runs(tmp_path):
    sweep = SweepSpec(name="clif", base=BASE, axes={"model": ["nope"]})
    sweep_path = tmp_path / "grid.json"
    sweep_path.write_text(sweep.to_json())
    with pytest.raises(SystemExit):
        exp_runner.main(["--sweep", str(sweep_path),
                         "--out", str(tmp_path / "out")])


def test_runner_cli_sweep_rejects_single_run_flags(tmp_path):
    """Single-run overrides would be silently meaningless on a grid —
    the CLI refuses them instead of running something else."""
    sweep_path = tmp_path / "grid.json"
    sweep_path.write_text(SweepSpec(name="x", base=BASE).to_json())
    for flags in (["--method", "fedavg"], ["--seed", "3"],
                  ["--eval-every", "1"], ["--resume"]):
        with pytest.raises(SystemExit, match="incompatible"):
            exp_runner.main(["--sweep", str(sweep_path),
                             "--out", str(tmp_path / "out"), *flags])
    # and the mirror: sweep-only flags require --sweep
    for flags in (["--max-runs", "1"], ["--executor", "process"],
                  ["--group-by", "method"]):
        with pytest.raises(SystemExit, match="--sweep"):
            exp_runner.main(["--preset", "smoke",
                             "--out", str(tmp_path / "out"), *flags])
    # --max-workers only makes sense fanning out over a pool
    with pytest.raises(SystemExit, match="--executor process"):
        exp_runner.main(["--sweep", str(sweep_path),
                         "--out", str(tmp_path / "out"),
                         "--max-workers", "2"])


# ---------------------------------------------------------------------------
# Process-pool executor.
# ---------------------------------------------------------------------------

def test_process_executor_rejects_eval_fn(tmp_path):
    sweep = SweepSpec(name="p", base=BASE, axes={"seed": [0]})
    with pytest.raises(ValueError, match="eval_fn"):
        run_sweep(sweep, str(tmp_path / "p"), executor="process",
                  eval_fn=lambda *a: 0)


def test_process_executor_smoke(tmp_path):
    """One tiny run through the spawn-context pool.  Must use a BUILT-IN
    model/dataset: the worker re-imports repro and never sees this
    module's registrations."""
    base = ExperimentSpec(
        name="pool", method="fedavg", model="ddpm-unet-smoke",
        fl=FLConfig(num_clients=2, num_edges=1, local_epochs=1,
                    edge_agg_every=1, cloud_agg_every=2, rounds=1,
                    sparse_rounds=2, sh_a=1000.0),
        data=DataSpec(dataset="smoke", batch_size=32),
        engine="sequential", prune=False)
    sweep = SweepSpec(name="pool", base=base, axes={"seed": [0]})
    res = run_sweep(sweep, str(tmp_path / "pool"), executor="process",
                    max_workers=1, raise_on_error=True)
    assert res.complete
    (entry,) = res.manifest["runs"].values()
    assert entry["rounds_done"] == 1
    assert np.isfinite(entry["history"][0]["loss"])
    # the worker's checkpoints landed in the sweep layout on disk
    assert os.path.exists(os.path.join(str(tmp_path / "pool"),
                                       entry["ckpt"] + ".manifest.json"))
