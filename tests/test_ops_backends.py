"""Compute-backend dispatch layer (repro.models.ops): xla vs
pallas(interpret) vs ref parity per op on real UNet/pruning shapes,
under vmap + scan, through gradients, on the masked sparse-phase
forward, and end-to-end on a FedPhD run through the
sparse -> prune -> plain transition."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SMOKE_UNET
from repro.configs.base import FLConfig, InputShape
from repro.core import pruning as P
from repro.core.hfl import FedPhD
from repro.data import SMOKE_DATA, ClientData, make_dataset, shards_per_client
from repro.experiment import DataSpec, Experiment, ExperimentSpec
from repro.fl.client import Client
from repro.models import model, ops

BACKENDS = ("xla", "pallas", "ref")


def _allclose(got, want, atol=1e-5):
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol)


def _mask(key, n, ratio=0.44):
    return (jax.random.uniform(key, (n,)) >= ratio).astype(jnp.float32)


# ---------------------------------------------------------------------------
# per-op parity on real CIFAR-10 U-Net shapes (tile-aligned: the pallas
# leg actually runs the kernels, not the fallback oracles)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ("pallas", "ref"))
def test_masked_matmul_parity(backend, rng):
    ks = jax.random.split(rng, 4)
    x = jax.random.normal(ks[0], (512, 256))         # B*HW x C at 16x16
    # fan-in-scaled weights (conv_init / dense_p scale): outputs O(1),
    # so atol 1e-5 compares accumulate-order noise, not magnitude
    w = jax.random.normal(ks[1], (256, 768)) * (256 ** -0.5)
    cm, rm = _mask(ks[2], 768), _mask(ks[3], 256)
    want = ops.masked_matmul(x, w, cm, rm, backend="xla")
    _allclose(ops.masked_matmul(x, w, cm, rm, backend=backend), want)
    # None masks = plain matmul
    _allclose(ops.matmul(x, w, backend=backend),
              ops.matmul(x, w, backend="xla"))


@pytest.mark.parametrize("backend", ("pallas", "ref"))
@pytest.mark.parametrize("masked", (False, True))
def test_conv_parity_unet_shapes(backend, masked, rng):
    """3x3 res-conv (128->256 @16x16) and the 1x1 qkv conv (256->768):
    the paper model's two conv flavors, at im2col-tile-aligned sizes."""
    ks = jax.random.split(rng, 6)
    for (kh, cin, cout, hw) in ((3, 128, 256, 16), (1, 256, 768, 16)):
        p = {"w": jax.random.normal(ks[0], (kh, kh, cin, cout)) * 0.05,
             "b": jax.random.normal(ks[1], (cout,)) * 0.1}
        x = jax.random.normal(ks[2], (2, hw, hw, cin))
        cm = _mask(ks[3], cout) if masked else None
        rm = _mask(ks[4], cin) if masked else None
        want = ops.conv(p, x, backend="xla", col_mask=cm, row_mask=rm)
        got = ops.conv(p, x, backend=backend, col_mask=cm, row_mask=rm)
        _allclose(got, want)


@pytest.mark.parametrize("backend", ("pallas", "ref"))
def test_conv_masked_equals_prezeroed_weights(backend, rng):
    """The masked conv must equal a plain conv of apply_masks-style
    pre-zeroed weights — the sparse-phase contract."""
    ks = jax.random.split(rng, 4)
    p = {"w": jax.random.normal(ks[0], (3, 3, 128, 256)) * 0.05,
         "b": jax.random.normal(ks[1], (256,)) * 0.1}
    x = jax.random.normal(ks[2], (2, 16, 16, 128))
    cm = _mask(ks[3], 256)
    pz = {"w": p["w"] * cm[None, None, None, :], "b": p["b"] * cm}
    want = ops.conv(pz, x, backend="xla")
    _allclose(ops.conv(p, x, backend=backend, col_mask=cm), want)


@pytest.mark.parametrize("backend", ("pallas", "ref"))
@pytest.mark.parametrize("shape,causal,window", [
    ((2, 256, 1, 256), False, 0),    # U-Net attn block @16x16, C=256
    ((2, 256, 4, 64), True, 0),      # transformer causal heads
    ((2, 256, 4, 64), True, 128),    # sliding window
])
def test_attention_parity(backend, shape, causal, window, rng):
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], shape)
    k = jax.random.normal(ks[1], shape)
    v = jax.random.normal(ks[2], shape)
    want = ops.attention(q, k, v, causal=causal, window=window,
                         backend="xla")
    got = ops.attention(q, k, v, causal=causal, window=window,
                        backend=backend)
    _allclose(got, want)


@pytest.mark.parametrize("backend", ("pallas", "ref"))
def test_attention_parity_gqa(backend, rng):
    """Hkv < Hq: every backend must expand KV groups identically."""
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (2, 256, 4, 64))
    k = jax.random.normal(ks[1], (2, 256, 2, 64))
    v = jax.random.normal(ks[2], (2, 256, 2, 64))
    want = ops.attention(q, k, v, causal=True, backend="xla")
    got = ops.attention(q, k, v, causal=True, backend=backend)
    _allclose(got, want)


@pytest.mark.parametrize("backend", ("pallas", "ref"))
def test_group_sq_norms_parity_on_unet_members(backend, rng):
    """Eq. 17 reductions on the actual U-Net PruneGroup member layouts:
    conv1 out-channels (axis 3), conv2 in-channels (axis 2), and a
    chunked qkv member — routed through the group_l2_norms kernel."""
    params = model.init(rng, SMOKE_UNET)
    groups = P.build_groups(SMOKE_UNET, params)
    for g in groups:
        want = P.group_sq_norms(params, g, backend="xla")
        got = P.group_sq_norms(params, g, backend=backend)
        _allclose(got, want, atol=1e-4)
    # scores end-to-end
    sx = P.l2_scores(params, groups, backend="xla")
    sb = P.l2_scores(params, groups, backend=backend)
    for name in sx:
        _allclose(sb[name], sx[name], atol=1e-4)


# ---------------------------------------------------------------------------
# parity inside the round engine's program structure: vmap (client
# axis, weights batched) x lax.scan (step axis) x grad
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ("pallas", "ref"))
def test_ops_parity_under_vmap_and_scan(backend, rng):
    ks = jax.random.split(rng, 3)
    C, S = 3, 2                                    # clients x scan steps
    ws = jax.random.normal(ks[0], (C, 1, 1, 128, 128)) * 0.05
    bs = jnp.zeros((C, 128))
    xs = jax.random.normal(ks[1], (S, 2, 16, 16, 128))

    def one_client(w, b, bk):
        def body(carry, x):
            y = ops.conv({"w": w, "b": b}, x, backend=bk)
            return carry + jnp.sum(y), y
        return jax.lax.scan(body, 0.0, xs)

    def run(bk):
        return jax.jit(jax.vmap(lambda w, b: one_client(w, b, bk)))(ws, bs)

    tot_x, ys_x = run("xla")
    tot_b, ys_b = run(backend)
    _allclose(ys_b, ys_x)
    _allclose(tot_b, tot_x, atol=1e-2)             # (C,) sums over 2*16*16*128


@pytest.mark.parametrize("backend", ("pallas", "ref"))
def test_grad_parity_through_ops(backend, rng):
    """custom_vjp routes: masked matmul, attention, group reductions."""
    ks = jax.random.split(rng, 5)
    x = jax.random.normal(ks[0], (256, 128))
    w = jax.random.normal(ks[1], (128, 256)) * 0.1
    cm, rm = _mask(ks[2], 256), _mask(ks[3], 128)

    def f(bk):
        return lambda w_: jnp.sum(
            jnp.tanh(ops.masked_matmul(x, w_, cm, rm, backend=bk)))
    _allclose(jax.grad(f(backend))(w), jax.grad(f("xla"))(w))

    q = jax.random.normal(ks[4], (1, 256, 1, 128))

    def a(bk):
        return lambda q_: jnp.sum(
            ops.attention(q_, q_, q_, backend=bk) ** 2)
    _allclose(jax.grad(a(backend))(q), jax.grad(a("xla"))(q), atol=1e-4)

    def gsq(bk):
        return lambda w_: jnp.sum(
            ops.group_sq_norms_2d(w_, 16, backend=bk) ** 2)
    _allclose(jax.grad(gsq(backend))(w), jax.grad(gsq("xla"))(w), atol=1e-4)


# ---------------------------------------------------------------------------
# masked sparse-phase forward
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_masked_forward_matches_prezeroed_reference(backend, rng):
    """apply_unet(..., masks=) == apply_unet(apply_masks(params)) — the
    block-masked sparse phase vs today's pre-zeroed weights, on every
    backend (incl. the loss gradient existing on the pallas route)."""
    cfg = SMOKE_UNET.replace(backend=backend)
    params = model.init(rng, SMOKE_UNET)
    groups = P.build_groups(SMOKE_UNET, params)
    masks = P.make_masks(P.l2_scores(params, groups), groups, 0.44)
    batch = model.make_inputs(rng, SMOKE_UNET, InputShape("t", 0, 4, "train"))
    want = model.loss_fn(P.apply_masks(params, groups, masks), cfg,
                         batch, rng)
    got = model.loss_fn(params, cfg, batch, rng, masks=masks)
    _allclose(got, want)
    g = jax.grad(lambda p: model.loss_fn(p, cfg, batch, rng, masks=masks))(
        params)
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(g))


# ---------------------------------------------------------------------------
# end-to-end: backend equivalence of a FedPhD run through the
# sparse -> prune -> plain transition, and the selection/threading knobs
# ---------------------------------------------------------------------------

def _clients(n=4, batch_size=16):
    images, labels = make_dataset(SMOKE_DATA, seed=0)
    parts = shards_per_client(labels, num_clients=n, classes_per_client=1,
                              seed=0)
    return [Client(i, ClientData(images[p], labels[p],
                                 batch_size=batch_size, seed=i),
                   SMOKE_DATA.num_classes) for i, p in enumerate(parts)]


FL = FLConfig(num_clients=4, num_edges=2, local_epochs=1, edge_agg_every=1,
              cloud_agg_every=2, rounds=3, sparse_rounds=2, prune_ratio=0.44,
              sh_a=1000.0)


def test_fedphd_run_equivalent_across_backends():
    """xla vs ref over the sparse -> prune -> plain transition: params
    atol 1e-5, comm_gb bitwise, identical selections/prune rounds."""
    runs = {}
    for backend in ("xla", "ref"):
        t = FedPhD(SMOKE_UNET.replace(backend=backend), FL, _clients(),
                   rng_seed=0)
        hist, _ = t.run(3)
        runs[backend] = (t, hist)
    (tx, hx), (tr, hr) = runs["xla"], runs["ref"]
    assert any(h.pruned for h in hx), "prune transition must be covered"
    for a, b in zip(hx, hr):
        assert a.comm_gb == b.comm_gb
        assert a.selected == b.selected
        assert a.pruned == b.pruned
        assert np.isclose(a.loss, b.loss, atol=1e-4)
    assert tx.cfg.replace(backend="") == tr.cfg.replace(backend="")
    for x, y in zip(jax.tree.leaves(tx.params), jax.tree.leaves(tr.params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-5)


def test_backend_resolution_and_env_knob(monkeypatch):
    assert ops.resolve_backend("pallas") == "pallas"
    monkeypatch.delenv("FEDPHD_BACKEND", raising=False)
    assert ops.resolve_backend(None) == "xla"
    monkeypatch.setenv("FEDPHD_BACKEND", "ref")
    assert ops.resolve_backend(None) == "ref"
    assert ops.resolve_backend("xla") == "xla"      # explicit beats env
    with pytest.raises(ValueError):
        ops.resolve_backend("cuda")
    # trainers bake the resolved backend into their frozen config
    t = FedPhD(SMOKE_UNET, FL, _clients(), rng_seed=0, prune=False)
    assert t.cfg.backend == "ref"


def test_spec_threads_backend_to_trainer():
    spec = ExperimentSpec(
        name="bk", method="fedphd", model="ddpm-unet-smoke",
        fl=FL, backend="ref", engine="sequential",
        data=DataSpec(dataset="smoke", batch_size=16))
    loaded = ExperimentSpec.from_json(spec.to_json())
    assert loaded.backend == "ref"
    exp = Experiment(spec)
    assert exp.trainer.cfg.backend == "ref"
