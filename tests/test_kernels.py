"""Per-kernel shape/dtype sweeps: pallas_call(interpret=True) vs ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.block_masked_matmul.block_masked_matmul import (
    block_masked_matmul)
from repro.kernels.block_masked_matmul.ref import block_masked_matmul_ref
from repro.kernels.flash_attention.flash_attention import flash_attention_bhsd
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.rglru_scan.rglru_scan import rglru_scan
from repro.kernels.rglru_scan.ref import rglru_scan_ref
from repro.kernels.group_l2_norms.group_l2_norms import group_l2_norms
from repro.kernels.group_l2_norms.ref import group_l2_norms_ref


@pytest.mark.parametrize("M,K,N", [(128, 128, 128), (256, 384, 128),
                                   (128, 256, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("ratio", [0.0, 0.44, 0.9])
def test_block_masked_matmul(M, K, N, dtype, ratio, rng):
    ks = jax.random.split(rng, 4)
    x = jax.random.normal(ks[0], (M, K)).astype(dtype)
    w = jax.random.normal(ks[1], (K, N)).astype(dtype)
    cm = (jax.random.uniform(ks[2], (N,)) >= ratio).astype(jnp.float32)
    rm = (jax.random.uniform(ks[3], (K,)) >= ratio / 2).astype(jnp.float32)
    got = block_masked_matmul(x, w, cm, rm, interpret=True)
    want = block_masked_matmul_ref(x, w, cm, rm)
    # bf16 needs an rtol term: accumulation-order rounding over large K
    # scales with |value| and can clear any fixed atol on outliers
    atol, rtol = (1e-4, 0.0) if dtype == jnp.float32 else (0.15, 1e-2)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=atol, rtol=rtol)


def test_block_masked_matmul_skips_whole_blocks(rng):
    """A fully-masked N-block must produce exactly zero output columns."""
    x = jax.random.normal(rng, (128, 128))
    w = jax.random.normal(jax.random.fold_in(rng, 1), (128, 256))
    cm = jnp.concatenate([jnp.zeros(128), jnp.ones(128)])
    rm = jnp.ones(128)
    got = block_masked_matmul(x, w, cm, rm, interpret=True)
    assert float(jnp.max(jnp.abs(got[:, :128]))) == 0.0
    assert float(jnp.max(jnp.abs(got[:, 128:]))) > 0.0


@pytest.mark.parametrize("Sq,Skv", [(128, 128), (256, 256), (128, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [0, 128])
def test_flash_attention(Sq, Skv, dtype, window, rng):
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (4, Sq, 64)).astype(dtype)
    k = jax.random.normal(ks[1], (4, Skv, 64)).astype(dtype)
    v = jax.random.normal(ks[2], (4, Skv, 64)).astype(dtype)
    got = flash_attention_bhsd(q, k, v, causal=True, window=window,
                               interpret=True)
    want = flash_attention_ref(q, k, v, causal=True, window=window)
    atol = 2e-3 if dtype == jnp.float32 else 0.05
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol)


@pytest.mark.parametrize("S,W", [(256, 128), (512, 256), (1024, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_scan(S, W, dtype, rng):
    ks = jax.random.split(rng, 2)
    a = jax.random.uniform(ks[0], (2, S, W), minval=0.4,
                           maxval=0.999).astype(dtype)
    b = jax.random.normal(ks[1], (2, S, W)).astype(dtype)
    got = rglru_scan(a, b, bs=128, interpret=True)
    want = rglru_scan_ref(a, b)
    atol = 1e-4 if dtype == jnp.float32 else 0.25
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol)


@pytest.mark.parametrize("K,G,C", [(128, 8, 64), (256, 16, 32), (64, 4, 128)])
def test_group_l2_norms(K, G, C, rng):
    w = jax.random.normal(rng, (K, G * C))
    got = group_l2_norms(w, G, interpret=True)
    want = group_l2_norms_ref(w, G)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)
