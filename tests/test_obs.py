"""Observability layer (repro.obs): resolve-helper precedence, trace
schema golden keys, the disabled-spec bitwise no-op on both round
engines, compile-tracker recompile detection, measured pipeline
overlap, sweep scheduling spans surviving preemption + manifest
reload, and the unified CLI metrics schema."""
import dataclasses
import json
import os

import numpy as np
import jax
import pytest

from repro.configs import SMOKE_UNET, register_config
from repro.configs.base import FLConfig
from repro.core.hfl import FedPhD
from repro.data import ClientData, shards_per_client
from repro.data.synthetic import DatasetSpec, make_dataset
from repro.experiment import (DataSpec, ExperimentSpec, FakeCluster,
                              K8sExecutor, SweepSpec, register_dataset,
                              run_sweep)
from repro.experiment.cli import (METRICS_SCHEMA, cli_obs_spec,
                                  make_cli_tracer, write_metrics)
from repro.experiment.report import run_scalars
from repro.experiment.resolve import (BACKENDS, KNOBS, knob_source,
                                      resolve_engine, resolve_knob,
                                      resolve_obs, validate_env)
from repro.fl.baselines import FlatTrainer
from repro.fl.client import Client
from repro.obs.compile_tracker import CompileTracker, cache_size
from repro.obs.metrics import summarize_trace
from repro.obs.spec import ObsSpec
from repro.obs.trace import (COUNTER_KEYS, EVENT_KEYS, META_KEYS, NULL_TRACER,
                             SCHEMA_VERSION, SPAN_KEYS, Tracer, make_tracer)

MICRO_UNET = SMOKE_UNET.replace(name="ddpm-unet-tiny-obs", image_size=8,
                                base_channels=8, channel_mults=(1,),
                                num_res_blocks=1, attn_resolutions=())
MICRO_DATA = DatasetSpec("tiny-obs", num_classes=4, image_size=8,
                         samples_per_class=32)

FL = FLConfig(num_clients=4, num_edges=1, local_epochs=1, edge_agg_every=1,
              cloud_agg_every=2, rounds=3, sh_a=1000.0)


def make_clients(n=4, batch_size=8):
    images, labels = make_dataset(MICRO_DATA, seed=0)
    parts = shards_per_client(labels, num_clients=n, classes_per_client=1,
                              seed=0)
    return [Client(i, ClientData(images[p], labels[p],
                                 batch_size=batch_size, seed=i),
                   MICRO_DATA.num_classes) for i, p in enumerate(parts)]


def read_lines(path):
    with open(path) as f:
        return [json.loads(ln) for ln in f if ln.strip()]


# -- satellite: the one $FEDPHD_* resolution code path ------------------------

def test_resolve_precedence_matrix(monkeypatch):
    """explicit > $FEDPHD_<KNOB> > default, for every knob; '' means
    unset at BOTH levels; unknown values raise at resolution time."""
    for name, knob in KNOBS.items():
        monkeypatch.delenv(knob.env, raising=False)
        assert resolve_knob(name) == knob.default
        assert knob_source(name) == "default"
        env_val = next(c for c in knob.choices if c != knob.default)
        explicit = knob.default
        monkeypatch.setenv(knob.env, env_val)
        assert resolve_knob(name) == env_val
        assert knob_source(name) == "env"
        # explicit beats env even when explicit happens to be the default
        assert resolve_knob(name, explicit) == explicit
        assert knob_source(name, explicit) == "explicit"
        # '' is "not set" on both legs
        monkeypatch.setenv(knob.env, "")
        assert resolve_knob(name, "") == knob.default
        monkeypatch.setenv(knob.env, env_val)
        assert resolve_knob(name, "") == env_val
        # typos fail fast, never fall back silently
        with pytest.raises(ValueError, match=f"unknown {name}"):
            resolve_knob(name, "bogus")
        monkeypatch.setenv(knob.env, "bogus")
        with pytest.raises(ValueError, match="from env"):
            resolve_knob(name)
        with pytest.raises(RuntimeError, match=knob.env):
            validate_env(name)
        monkeypatch.delenv(knob.env, raising=False)
        assert validate_env(name) is None


def test_resolve_engine_strictness(monkeypatch):
    monkeypatch.delenv("FEDPHD_ENGINE", raising=False)
    assert resolve_engine(None) == ("auto", False)
    assert resolve_engine("vectorized") == ("vectorized", True)
    monkeypatch.setenv("FEDPHD_ENGINE", "sequential")
    # env-selected engines are non-strict (matrix legs stay green on
    # ragged fixtures); explicit choices are strict
    assert resolve_engine(None) == ("sequential", False)
    assert resolve_engine("vectorized") == ("vectorized", True)


def test_resolve_obs_aliases(monkeypatch):
    for raw, want in (("1", True), ("true", True), ("YES", True),
                      ("on", True), ("0", False), ("false", False),
                      ("no", False), ("off", False)):
        monkeypatch.setenv("FEDPHD_OBS", raw)
        assert resolve_obs() is want
    monkeypatch.delenv("FEDPHD_OBS", raising=False)
    assert resolve_obs() is False
    assert resolve_obs("on") is True


def test_obs_spec_resolution_and_roundtrip(monkeypatch):
    monkeypatch.delenv("FEDPHD_OBS", raising=False)
    assert ObsSpec().resolved_enabled is False
    assert ObsSpec(enabled=True).resolved_enabled is True
    monkeypatch.setenv("FEDPHD_OBS", "on")
    assert ObsSpec().resolved_enabled is True          # env leg
    assert ObsSpec(enabled=False).resolved_enabled is False  # explicit wins
    with pytest.raises(ValueError, match="flush_every"):
        ObsSpec(flush_every=0)
    spec = ObsSpec(enabled=True, trace="t.jsonl", flush_every=8,
                   compile_tracking=False)
    assert ObsSpec.from_dict(json.loads(json.dumps(spec.to_dict()))) == spec
    # unknown keys from future manifests are dropped, not fatal
    assert ObsSpec.from_dict({"enabled": True, "shiny": 1}) \
        == ObsSpec(enabled=True)


def test_experiment_spec_carries_obs():
    spec = ExperimentSpec(name="obs-rt", method="fedavg", model="m",
                          fl=FL, data=DataSpec(dataset="d", batch_size=8),
                          obs=ObsSpec(enabled=True, trace="x.jsonl"))
    back = ExperimentSpec.from_dict(json.loads(spec.to_json()))
    assert back.obs == spec.obs
    # obs.* is addressable as a sweep axis like fl.* / fault.*
    grid = SweepSpec(name="g", base=spec,
                     axes={"obs.enabled": [False, True], "seed": [0]})
    runs = grid.expand()
    assert {run.overrides["obs.enabled"] for run in runs} == {False, True}
    assert {run.spec.obs.enabled for run in runs} == {False, True}


# -- trace schema -------------------------------------------------------------

def test_trace_schema_golden_keys(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    tr = Tracer(path)
    with tr.span("round/dispatch", round=1):
        pass
    tr.record_span("serve/tick", 1.0, 2.5, active=3)
    tr.event("fault/draw", round=1, dropped=0)
    tr.counter("compile/step", 1, unexpected=0)
    tr.close()
    lines = read_lines(path)
    golden = {"meta": META_KEYS, "span": SPAN_KEYS,
              "event": EVENT_KEYS, "counter": COUNTER_KEYS}
    assert [ln["ev"] for ln in lines] == ["meta", "span", "span",
                                          "event", "counter"]
    for ln in lines:
        assert set(ln) == set(golden[ln["ev"]])
    assert lines[0]["schema"] == SCHEMA_VERSION
    assert lines[2]["dur_s"] == pytest.approx(1.5)
    # reopening appends a fresh meta line: sessions delimit in-band,
    # so perf_counter stamps are never compared across processes
    Tracer(path).close()
    metas = [ln for ln in read_lines(path) if ln["ev"] == "meta"]
    assert len(metas) == 2


def test_make_tracer_resolution(tmp_path, monkeypatch):
    monkeypatch.delenv("FEDPHD_OBS", raising=False)
    assert make_tracer(ObsSpec()) is NULL_TRACER
    assert make_tracer(None) is NULL_TRACER
    monkeypatch.setenv("FEDPHD_OBS", "on")
    tr = make_tracer(ObsSpec(), default_path=str(tmp_path / "a.jsonl"))
    assert tr.enabled and tr.path.endswith("a.jsonl")
    tr.close()
    # spec path beats the caller default
    tr = make_tracer(ObsSpec(trace=str(tmp_path / "b.jsonl")),
                     default_path=str(tmp_path / "a.jsonl"))
    assert tr.path.endswith("b.jsonl")
    tr.close()


# -- the hard invariant: obs disabled is a bitwise no-op ---------------------

@pytest.mark.parametrize("engine", ["sequential", "vectorized"])
def test_disabled_obs_bitwise_noop_fedphd(engine, tmp_path):
    """Same seed, with and without a bound tracer: parameters bitwise
    identical, histories identical — tracing never touches RNG or
    numerics on either engine."""
    plain = FedPhD(MICRO_UNET, FL, make_clients(), rng_seed=0,
                   engine=engine, prune=False)
    plain.run(2)
    tracer = Tracer(str(tmp_path / f"{engine}.jsonl"))
    traced = FedPhD(MICRO_UNET, FL, make_clients(), rng_seed=0,
                    engine=engine, prune=False, tracer=tracer)
    traced.run(2)
    tracer.close()
    for a, b in zip(jax.tree.leaves(plain.params),
                    jax.tree.leaves(traced.params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert [h.to_dict() for h in plain.history] \
        == [h.to_dict() for h in traced.history]
    # and the traced leg actually emitted round phase spans (the
    # sequential reference loop syncs per batch, so it gets only the
    # one dispatch span; loss_sync exists on the deferred-sync engine)
    names = {ln["name"] for ln in read_lines(tracer.path)
             if ln["ev"] == "span"}
    want = {"round/dispatch"} if engine == "sequential" \
        else {"round/dispatch", "round/loss_sync"}
    assert want <= names


def test_disabled_obs_bitwise_noop_flat(tmp_path):
    plain = FlatTrainer("fedavg", MICRO_UNET, FL, make_clients(),
                        rng_seed=0, engine="vectorized")
    plain.run(2)
    tracer = Tracer(str(tmp_path / "flat.jsonl"))
    traced = FlatTrainer("fedavg", MICRO_UNET, FL, make_clients(),
                         rng_seed=0, engine="vectorized", tracer=tracer)
    traced.run(2)
    tracer.close()
    for a, b in zip(jax.tree.leaves(plain.params),
                    jax.tree.leaves(traced.params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert [h.to_dict() for h in plain.history] \
        == [h.to_dict() for h in traced.history]


# -- compile tracker ----------------------------------------------------------

def test_compile_tracker_catches_induced_recompile(tmp_path):
    path = str(tmp_path / "c.jsonl")
    tracer = Tracer(path)
    tracker = CompileTracker(tracer)

    @jax.jit
    def f(x):
        return x * 2

    assert tracker.watch("f", f)
    f(np.ones((4,), np.float32))
    assert tracker.check() == 0            # the expected first compile
    assert tracker.compiles() == 1 and tracker.recompiles() == 0
    f(np.ones((8,), np.float32))           # new shape -> a real recompile
    assert tracker.check() == 1
    assert tracker.recompiles() == 1
    # a re-watch is a DECLARED recompile boundary (the trainers re-watch
    # after pruning): the next compile is expected again
    assert tracker.watch("f", f)
    f(np.ones((16,), np.float32))
    assert tracker.check() == 0
    assert tracker.recompiles() == 1
    tracer.close()
    counters = [ln for ln in read_lines(path) if ln["ev"] == "counter"]
    assert [c["attrs"]["unexpected"] for c in counters] == [0, 1, 0]
    assert all(c["name"] == "compile/f" for c in counters)


def test_cache_size_degrades_gracefully():
    assert cache_size(lambda x: x) is None
    tracker = CompileTracker(NULL_TRACER)
    assert tracker.watch("plain", lambda x: x) is False
    assert tracker.check() == 0


# -- trace-derived metrics ----------------------------------------------------

def test_traced_run_overlap_and_zero_recompiles(tmp_path):
    """A pipelined traced run: phase spans per round, a measurable
    overlap window, and zero steady-state recompiles (the jit caches
    only grow at the declared first-compile boundaries)."""
    path = str(tmp_path / "run.jsonl")
    # a config name unique to this test: the round engine is memoized
    # on the full config, so this guarantees a FRESH jit cache — the
    # compile counter must see the expected first compile
    cfg = MICRO_UNET.replace(name="ddpm-unet-tiny-obs-traced")
    tr = FedPhD(cfg, FL, make_clients(), rng_seed=0,
                engine="vectorized", prune=False, tracer=Tracer(path))
    tr.run(3)
    tr._obs.close()
    ts = summarize_trace(path)
    for phase in ("round/host_prep", "round/h2d", "round/dispatch",
                  "round/loss_sync"):
        assert ts["phases"][phase]["n"] >= 3, phase
    assert ts["rounds"] == 3
    assert ts["overlap_ratio"] is not None
    assert 0.0 <= ts["overlap_ratio"] <= 1.0
    assert ts["compiles"] >= 1
    assert ts["recompiles"] == 0


def test_summarize_trace_sessions_split():
    events = [
        {"ev": "meta", "schema": 1, "wall_time": 0.0, "attrs": {}},
        {"ev": "span", "name": "round/dispatch", "t0": 0.0, "t1": 1.0,
         "dur_s": 1.0, "attrs": {"round": 1}},
        {"ev": "span", "name": "round/h2d", "t0": 1.2, "t1": 1.8,
         "dur_s": 0.6, "attrs": {"round": 2}},
        {"ev": "span", "name": "round/loss_sync", "t0": 2.0, "t1": 2.1,
         "dur_s": 0.1, "attrs": {"round": 1}},
        {"ev": "meta", "schema": 1, "wall_time": 9.0, "attrs": {}},
        {"ev": "span", "name": "round/dispatch", "t0": 0.0, "t1": 0.5,
         "dur_s": 0.5, "attrs": {"round": 3}},
    ]
    ts = summarize_trace(events)
    assert ts["sessions"] == 2
    # round 2's h2d (0.6s) hides fully inside round 1's 1.0s window;
    # the second session contributes no window (no loss_sync)
    assert ts["overlap_window_s"] == pytest.approx(1.0)
    assert ts["overlap_hidden_s"] == pytest.approx(0.6)
    assert ts["overlap_ratio"] == pytest.approx(0.6)


# -- sweep scheduling spans ---------------------------------------------------

register_config("ddpm-unet-tiny-obs", MICRO_UNET, overwrite=True)
register_dataset("tiny-obs", MICRO_DATA, overwrite=True)

SWEEP_BASE = ExperimentSpec(
    name="obs-sweep-base", method="fedavg", model="ddpm-unet-tiny-obs",
    fl=dataclasses.replace(FL, rounds=2),
    data=DataSpec(dataset="tiny-obs", batch_size=8),
    engine="sequential", prune=False)
SWEEP = SweepSpec(name="obs-sweep", base=SWEEP_BASE,
                  axes={"seed": [0, 1]})


def test_sweep_spans_survive_preemption_and_reload(tmp_path):
    """The executor records queue/attempt/backoff spans into the
    manifest; a preempted attempt surfaces as outcome="preempted", the
    retry as "done" — and the spans survive a manifest reload (the
    kill-and-resume path re-reads sweep.json)."""
    rid = "seed=0"
    exe = K8sExecutor(cluster=FakeCluster(preempt_once={rid: 1}),
                      poll_s=0.0)
    res = run_sweep(SWEEP, str(tmp_path), executor=exe, max_retries=1)
    assert res.complete
    trace = res.manifest["runs"][rid]["trace"]
    outcomes = [s["attrs"]["outcome"] for s in trace
                if s["name"] == "sweep/attempt"]
    assert outcomes == ["preempted", "done"]
    assert any(s["name"] == "sweep/backoff" for s in trace)
    queue = [s for s in trace if s["name"] == "sweep/queue"]
    assert len(queue) == 2                 # initial launch + the retry
    assert all(s["dur_s"] >= 0 for s in trace)
    # epoch stamps: spans are ordered across attempts within one entry
    attempts = [s for s in trace if s["name"] == "sweep/attempt"]
    assert attempts[0]["t1"] <= attempts[1]["t0"]

    # resume on the same out dir: nothing reruns, spans survive
    exe2 = K8sExecutor(cluster=FakeCluster(fail_submits=True), poll_s=0.0)
    res2 = run_sweep(SWEEP, str(tmp_path), executor=exe2)
    assert res2.complete
    assert res2.manifest["runs"][rid]["trace"] == trace


def test_sequential_executor_records_spans(tmp_path):
    res = run_sweep(SWEEP, str(tmp_path))
    for entry in res.manifest["runs"].values():
        names = [s["name"] for s in entry["trace"]]
        assert "sweep/queue" in names and "sweep/attempt" in names
        done = [s for s in entry["trace"] if s["name"] == "sweep/attempt"]
        assert done[-1]["attrs"]["outcome"] == "done"


def test_report_scheduling_scalars():
    entry = {
        "status": "done", "attempts": 2, "wall_s": 5.0,
        "history": [{"loss": 0.5, "comm_gb": 0.1, "params_m": 1.0}],
        "trace": [
            {"ev": "span", "name": "sweep/queue", "t0": 0.0, "t1": 1.0,
             "dur_s": 1.0, "attrs": {"attempt": 0}},
            {"ev": "span", "name": "sweep/attempt", "t0": 1.0, "t1": 3.0,
             "dur_s": 2.0, "attrs": {"outcome": "preempted"}},
            {"ev": "span", "name": "sweep/backoff", "t0": 3.0, "t1": 3.5,
             "dur_s": 0.5, "attrs": {"attempt": 1}},
            {"ev": "span", "name": "sweep/queue", "t0": 3.5, "t1": 4.0,
             "dur_s": 0.5, "attrs": {"attempt": 1}},
            {"ev": "span", "name": "sweep/attempt", "t0": 4.0, "t1": 5.0,
             "dur_s": 1.0, "attrs": {"outcome": "done"}},
        ],
    }
    out = run_scalars(entry)
    assert out["attempts"] == 2.0
    assert out["queue_s"] == pytest.approx(1.5)
    # retry cost = the backoff window + the preempted attempt's wall
    assert out["retry_s"] == pytest.approx(2.5)


# -- unified CLI surface ------------------------------------------------------

def test_cli_obs_spec_forms():
    assert cli_obs_spec(None) == ObsSpec()              # defer to env
    assert cli_obs_spec("") == ObsSpec(enabled=True)    # bare --trace
    assert cli_obs_spec("t.jsonl") \
        == ObsSpec(enabled=True, trace="t.jsonl")       # pinned path


def test_make_cli_tracer(tmp_path, monkeypatch):
    monkeypatch.delenv("FEDPHD_OBS", raising=False)
    assert make_cli_tracer(None).enabled is False
    tr = make_cli_tracer("", default_path=str(tmp_path / "d.jsonl"))
    assert tr.enabled and tr.path.endswith("d.jsonl")
    tr.close()


def test_write_metrics_envelope(tmp_path):
    path = str(tmp_path / "m.json")
    write_metrics(path, "serve", {"images": 8, "compiles": 1})
    with open(path) as f:
        m = json.load(f)
    # envelope keys ADD to the flat metric keys: existing CI assertions
    # like m["images"] keep working across runner and serve
    assert m["schema"] == METRICS_SCHEMA and m["kind"] == "serve"
    assert m["images"] == 8 and m["compiles"] == 1
