"""Flat-baseline round engines: per-method sequential-vs-vectorized
equivalence (params atol 1e-5, bitwise comm_gb incl. FedDiffuse's
shared-fraction and SCAFFOLD's 2x volume, identical participation
selections), persistent per-client Adam state, and the ragged-client
fallback.

Runs on a micro U-Net (not SMOKE_UNET): the equivalence matrix is
5 methods x 2 engines and MOON's contrastive loss traces three model
applications, so compile time dominates at any larger scale.
"""
import dataclasses
import warnings

import numpy as np
import jax
import pytest

from repro.configs import SMOKE_UNET
from repro.configs.base import FLConfig
from repro.data import ClientData, shards_per_client
from repro.data.synthetic import DatasetSpec, make_dataset
from repro.fl.baselines import FLAT_METHODS, FlatTrainer

from repro.fl.client import Client

MICRO_UNET = SMOKE_UNET.replace(name="ddpm-unet-tiny", image_size=8,
                                base_channels=8, channel_mults=(1,),
                                num_res_blocks=1, attn_resolutions=())
MICRO_DATA = DatasetSpec("tiny", num_classes=4, image_size=8,
                         samples_per_class=32)

FL = FLConfig(num_clients=4, num_edges=1, local_epochs=1, edge_agg_every=1,
              cloud_agg_every=2, rounds=3, sh_a=1000.0)


def make_clients(n=4, batch_size=8):
    """Fresh clients each call: ClientData holds a stateful shuffle RNG,
    so both engines must consume it from the same starting state."""
    images, labels = make_dataset(MICRO_DATA, seed=0)
    parts = shards_per_client(labels, num_clients=n, classes_per_client=1,
                              seed=0)
    return [Client(i, ClientData(images[p], labels[p],
                                 batch_size=batch_size, seed=i),
                   MICRO_DATA.num_classes) for i, p in enumerate(parts)]


def assert_params_close(a, b, atol=1e-5):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32), atol=atol)


def run_flat(method, fl=FL, clients=None, rounds=3, **kw):
    """run_flat_fl is deprecated — construct FlatTrainer directly.
    RoundRecord keeps dict-style access, so assertions read the same."""
    tr = FlatTrainer(method, MICRO_UNET, fl,
                     make_clients() if clients is None else clients,
                     rng_seed=0, **kw)
    tr.run(rounds)
    return tr


def run_pair(method, fl=FL, rounds=3, **kw):
    seq = run_flat(method, fl, rounds=rounds, engine="sequential", **kw)
    vec = run_flat(method, fl, rounds=rounds, engine="vectorized", **kw)
    return seq, vec


@pytest.mark.parametrize("method", FLAT_METHODS)
def test_flat_engine_equivalence(method):
    """Final params atol 1e-5; bitwise-equal comm_gb history (incl. the
    FedDiffuse shared-fraction and SCAFFOLD 2x volumes); identical
    participation selections under the same seed."""
    seq, vec = run_pair(method)
    for a, b in zip(seq.history, vec.history):
        assert a["comm_gb"] == b["comm_gb"]
        assert a["selected"] == b["selected"]
        assert np.isclose(a["loss"], b["loss"], atol=1e-4)
    assert_params_close(seq.params, vec.params)


def test_comm_volume_shape():
    """FedDiffuse ships the shared fraction, SCAFFOLD ships 2x (model +
    control variate) — identical on both engines, asserted vs fedavg."""
    ref, _ = run_pair("fedavg", rounds=1)
    dif, _ = run_pair("feddiffuse", rounds=1)
    sca, _ = run_pair("scaffold", rounds=1)
    base = ref.history[0]["comm_gb"]
    assert dif.history[0]["comm_gb"] < base
    assert sca.history[0]["comm_gb"] == 2 * base


@pytest.mark.parametrize("method", ["fedavg", "scaffold"])
def test_persistent_opt_equivalence(method):
    """Persistent per-client Adam moments, gathered/scattered by a
    partial participation selection, match across engines."""
    fl = dataclasses.replace(FL, participation=0.5)
    seq, vec = run_pair(method, fl=fl, persistent_opt=True)
    for a, b in zip(seq.history, vec.history):
        assert a["selected"] == b["selected"]
    assert_params_close(seq.params, vec.params)


def test_persistent_opt_changes_trajectory():
    """persistent_opt=False must preserve paper semantics (fresh Adam
    per round) — so turning it on must actually change the result."""
    off = run_flat("fedavg", rounds=2, engine="vectorized")
    on = run_flat("fedavg", rounds=2, engine="vectorized",
                  persistent_opt=True)
    diffs = [float(np.max(np.abs(np.asarray(x) - np.asarray(y))))
             for x, y in zip(jax.tree.leaves(off.params),
                             jax.tree.leaves(on.params))]
    assert max(diffs) > 1e-6


def test_flat_vectorized_raises_on_ragged():
    cls = make_clients()
    cls[0].data.batch_size = 4
    with pytest.raises(ValueError):
        run_flat("fedavg", clients=cls, rounds=1, engine="vectorized")


def test_flat_auto_ragged_single_warning():
    """Ragged clients route to the sequential path silently (no crash)
    with exactly one warning across all rounds."""
    cls = make_clients()
    cls[0].data.batch_size = 4
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        res = run_flat("fedavg", clients=cls, rounds=2, engine="auto")
    ragged = [w for w in caught if "sequential" in str(w.message)]
    assert len(ragged) == 1
    assert all(np.isfinite(h["loss"]) for h in res.history)


def test_flat_run_pipelined_equals_stepped():
    """``run()`` double-buffers rounds (the FedPhD
    ``_start_round``/``_finish_round`` split, adopted by the flat
    trainers): round r+1 is dispatched before round r's losses sync.
    Trajectories — loss, comm_gb, selections, eval snapshots — must be
    identical to stepping ``run_round`` directly."""
    for method in ("fedavg", "scaffold"):
        evals = {"stepped": [], "piped": []}

        def eval_fn(tag):
            return lambda params, cfg, r: (
                evals[tag].append(float(np.asarray(
                    jax.tree.leaves(params)[0]).sum())) or r)

        stepped = FlatTrainer(method, MICRO_UNET, FL, make_clients(),
                              rng_seed=0, engine="vectorized",
                              eval_fn=eval_fn("stepped"), eval_every=2)
        piped = FlatTrainer(method, MICRO_UNET, FL, make_clients(),
                            rng_seed=0, engine="vectorized",
                            eval_fn=eval_fn("piped"), eval_every=2)
        for r in range(1, 4):
            stepped.run_round(r)
        piped.run(3)
        for a, b in zip(stepped.history, piped.history):
            assert a.loss == b.loss and a.comm_gb == b.comm_gb
            assert a.selected == b.selected and a.eval == b.eval
        # the eval hook saw the same (snapshotted) params in both modes
        assert evals["stepped"] == evals["piped"]


def test_flat_run_finalizes_pending_on_raise():
    """The try/finally orphan-round guard: a ``_start_round`` that
    raises mid-``run()`` (strict vectorized hitting a ragged selection)
    must not orphan the already-dispatched previous round — its record
    lands in history before the exception propagates."""
    tr = FlatTrainer("fedavg", MICRO_UNET, FL, make_clients(),
                     rng_seed=0, engine="vectorized")
    orig = tr._start_round

    def raise_on_round_2(r):
        if r == 2:
            raise ValueError("boom")
        return orig(r)

    tr._start_round = raise_on_round_2
    with pytest.raises(ValueError, match="boom"):
        tr.run(3)
    # round 1 executed and was finalized by the guard
    assert [rec.round for rec in tr.history] == [1]
    assert np.isfinite(tr.history[0].loss)


def test_flat_run_eval_failure_loses_eval_not_round():
    """A raising eval_fn mid-pipelined-``run()`` must not orphan
    executed rounds: the failing round is recorded (without its eval),
    the already-dispatched next round is finalized by the guard, and
    history stays contiguous — so a later run()/resume does not re-run
    applied rounds."""
    def eval_fn(params, cfg, r):
        if r == 2:
            raise RuntimeError("eval boom")
        return r

    tr = FlatTrainer("fedavg", MICRO_UNET, FL, make_clients(),
                     rng_seed=0, engine="vectorized",
                     eval_fn=eval_fn, eval_every=1)
    with pytest.raises(RuntimeError, match="eval boom"):
        tr.run(3)
    assert [rec.round for rec in tr.history] == [1, 2, 3]
    assert tr.history[0].eval == 1
    assert tr.history[1].eval is None       # the eval was lost...
    assert np.isfinite(tr.history[1].loss)  # ...the round was not
    assert tr.history[2].eval == 3


def test_flat_trainer_interleaves_engines():
    """FlatTrainer steps round-by-round (the bench substrate), and both
    engines share one state store: a trainer can switch paths in either
    direction without losing SCAFFOLD control variates (the engine is
    built even for sequential trainers — memoized, compiled lazily)."""
    tr = FlatTrainer("scaffold", MICRO_UNET, FL, make_clients(),
                     rng_seed=0, engine="auto")
    rec1 = tr.run_round(1)
    assert np.isfinite(rec1["loss"])
    tr.engine = "sequential"          # force the reference path
    rec2 = tr.run_round(2)
    assert np.isfinite(rec2["loss"])
    tr.engine = "auto"                # and back to the vectorized path
    rec3 = tr.run_round(3)
    assert np.isfinite(rec3["loss"])
    assert len(tr.history) == 3

    seq_first = FlatTrainer("fedavg", MICRO_UNET, FL, make_clients(),
                            rng_seed=0, engine="sequential")
    seq_first.run_round(1)
    seq_first.engine = "auto"         # sequential-born trainer can switch
    rec = seq_first.run_round(2)
    assert np.isfinite(rec["loss"])
