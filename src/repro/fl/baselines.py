"""Flat (single-tier) FL baselines from the paper's Table II:

FedAvg [6], FedProx [21], FedDiffuse [15] (partial-parameter updates),
MOON [22] (model-contrastive), SCAFFOLD [23] (control variates), plus
centralized training.  All share the client substrate in fl/client.py.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig, ModelConfig
from repro.core.aggregation import aggregate_fedavg
from repro.fl.client import Client, make_local_step, run_local
from repro.fl.comm import CommModel
from repro.models import model
from repro.optim import adam_init, adam_update


# ---------------------------------------------------------------------------
# FedDiffuse parameter partition: shared (communicated) vs local subsets.
# de Goede et al. split the U-Net; we share the encoder half (down+mid+temb)
# and keep the decoder (up, out) local — their "UDEC" variant mirrored.
# ---------------------------------------------------------------------------
_SHARED_KEYS_UNET = ("conv_in", "temb1", "temb2", "down", "mid")


def _split_shared(params: Dict, cfg: ModelConfig):
    if cfg.arch_type == "unet":
        shared = {k: v for k, v in params.items() if k in _SHARED_KEYS_UNET}
        local = {k: v for k, v in params.items() if k not in _SHARED_KEYS_UNET}
        return shared, local
    # transformers: share everything except the lm head / final norm
    local_keys = ("final_norm", "lm_head")
    shared = {k: v for k, v in params.items() if k not in local_keys}
    local = {k: v for k, v in params.items() if k in local_keys}
    return shared, local


def _merge(shared: Dict, local: Dict) -> Dict:
    out = dict(shared)
    out.update(local)
    return out


def shared_fraction(params: Dict, cfg: ModelConfig) -> float:
    shared, local = _split_shared(params, cfg)
    sb = sum(x.size for x in jax.tree.leaves(shared))
    lb = sum(x.size for x in jax.tree.leaves(local))
    return sb / max(sb + lb, 1)


@dataclasses.dataclass
class FlatFLResult:
    history: List[Dict]
    params: Dict


def run_flat_fl(method: str, cfg: ModelConfig, fl: FLConfig,
                clients: List[Client], *, rounds: Optional[int] = None,
                lr: float = 2e-4, rng_seed: int = 0,
                eval_fn: Optional[Callable] = None,
                eval_every: int = 0) -> FlatFLResult:
    """method in {fedavg, fedprox, feddiffuse, moon, scaffold}."""
    assert method in ("fedavg", "fedprox", "feddiffuse", "moon", "scaffold")
    rounds = rounds or fl.rounds
    np_rng = np.random.default_rng(rng_seed)
    rng = jax.random.PRNGKey(rng_seed)
    rng, sub = jax.random.split(rng)
    params = model.init(sub, cfg)
    comm = CommModel()
    mbytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))

    step_fn = make_local_step(cfg, fl, method=method, lr=lr)
    opt_zero = adam_init(params)   # one zero-tree, reused by every client

    # method-specific state
    zeros_like = lambda t: jax.tree.map(
        lambda p: jnp.zeros_like(p, jnp.float32), t)
    c_global = zeros_like(params) if method == "scaffold" else None
    c_locals = {c.cid: zeros_like(params) for c in clients} \
        if method == "scaffold" else {}
    prev_locals: Dict[int, Dict] = {}      # MOON
    local_parts: Dict[int, Dict] = {}      # FedDiffuse

    history: List[Dict] = []
    for r in range(1, rounds + 1):
        C = max(1, round(fl.participation * len(clients)))
        sel = np_rng.choice(len(clients), size=C, replace=False)
        client_models, counts, losses = [], [], []
        c_deltas = []
        for cid in sel:
            cl = clients[cid]
            start = params
            if method == "feddiffuse" and cid in local_parts:
                shared, _ = _split_shared(params, cfg)
                start = _merge(shared, local_parts[cid])
            ctx = {}
            if method in ("fedprox", "moon"):
                ctx["global_params"] = params
            if method == "moon":
                ctx["prev_params"] = prev_locals.get(cid, params)
            if method == "scaffold":
                ctx["c_local"] = c_locals[cid]
                ctx["c_global"] = c_global
            rng, sub = jax.random.split(rng)
            new_p, _, loss = run_local(step_fn, start, cl,
                                       epochs=fl.local_epochs, rng=sub,
                                       ctx=ctx, opt_state=opt_zero)
            losses.append(loss)
            counts.append(cl.n_samples)
            if method == "moon":
                prev_locals[cid] = new_p
            if method == "feddiffuse":
                shared, local = _split_shared(new_p, cfg)
                local_parts[cid] = local
                client_models.append(shared)
            else:
                client_models.append(new_p)
            if method == "scaffold":
                # c_i+ = c_i - c + (x - y_i) / (K * lr)
                steps = fl.local_epochs * max(
                    len(cl.data) // cl.data.batch_size, 1)
                scale = 1.0 / (steps * lr)
                new_ci = jax.tree.map(
                    lambda ci, c, x, y: ci - c + scale
                    * (x.astype(jnp.float32) - y.astype(jnp.float32)),
                    c_locals[cid], c_global, start, new_p)
                c_deltas.append(jax.tree.map(lambda a, b: a - b, new_ci,
                                             c_locals[cid]))
                c_locals[cid] = new_ci

        agg = aggregate_fedavg(client_models, counts)
        if method == "feddiffuse":
            _, local = _split_shared(params, cfg)
            params = _merge(agg, local)
            vol = mbytes * shared_fraction(params, cfg)
        else:
            params = agg
            vol = mbytes
        if method == "scaffold":
            mean_dc = aggregate_fedavg(c_deltas, [1] * len(c_deltas))
            frac = len(sel) / len(clients)
            c_global = jax.tree.map(lambda c, d: c + frac * d, c_global,
                                    mean_dc)
            vol = mbytes * 2  # model + control variate
        comm_gb = comm.flat_fl_round(vol, len(sel)) / 1e9
        rec = {"round": r, "loss": float(np.mean(losses)),
               "comm_gb": comm_gb}
        if eval_fn and eval_every and r % eval_every == 0:
            rec["eval"] = eval_fn(params, cfg, r)
        history.append(rec)
    return FlatFLResult(history=history, params=params)


def run_centralized(cfg: ModelConfig, images: np.ndarray, *, steps: int,
                    batch_size: int, lr: float = 2e-4, rng_seed: int = 0,
                    use_ema: bool = True):
    """Centralized baseline (paper: 500K steps + EMA; scaled down here)."""
    from repro.optim import ema_init, ema_update
    rng = jax.random.PRNGKey(rng_seed)
    rng, sub = jax.random.split(rng)
    params = model.init(sub, cfg)
    opt_state = adam_init(params)
    ema = ema_init(params) if use_ema else None
    np_rng = np.random.default_rng(rng_seed)

    @jax.jit
    def step(params, opt_state, batch, rng):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss_fn(p, cfg, batch, rng))(params)
        params, opt_state = adam_update(grads, opt_state, params, lr=lr,
                                        grad_clip=1.0)
        return params, opt_state, loss

    losses = []
    for _ in range(steps):
        sel = np_rng.integers(0, len(images), size=batch_size)
        batch = {"images": jnp.asarray(images[sel])}
        rng, sub = jax.random.split(rng)
        params, opt_state, loss = step(params, opt_state, batch, sub)
        losses.append(float(loss))
        if use_ema:
            ema = ema_update(ema, params, 0.999)
    final = jax.tree.map(lambda e, p: e.astype(p.dtype), ema, params) \
        if use_ema else params
    return final, losses
