"""Flat (single-tier) FL baselines from the paper's Table II:

FedAvg [6], FedProx [21], FedDiffuse [15] (partial-parameter updates),
MOON [22] (model-contrastive), SCAFFOLD [23] (control variates), plus
centralized training.  All share the client substrate in fl/client.py.

Like FedPhD's hierarchical loop, every baseline runs on either of two
interchangeable engines (``run_flat_fl(..., engine=)``):

  "sequential"  — the numerical reference: one jitted step per batch,
                  Python-side aggregation (fl/client.py:run_local);
  "vectorized"  — ONE jitted program per round (vmap clients x scan
                  batches, fused FedAvg einsum, device-side SCAFFOLD
                  c_i+ update and delta mean) via the E=1 special case
                  of repro.fl.engine.make_round_engine, with the
                  method's per-client anchors (FedProx/MOON params,
                  SCAFFOLD control variates, FedDiffuse local subtrees)
                  stacked into a (C, ...) ctx pytree;
  "auto"        — vectorized whenever the selected clients share a
                  batch shape, sequential (with a one-time warning)
                  otherwise.

Method state that persists across rounds (MOON's previous local
models, FedDiffuse's local parameter subtrees, SCAFFOLD's c_i, and —
with ``persistent_opt`` — per-client Adam moments) lives in stacked
device buffers with a leading (N,) client axis, gathered/scattered by
the round's participation selection; both engines read and write the
same buffers, so "auto" may switch engines between rounds without
losing state.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig, ModelConfig
from repro.core.aggregation import (aggregate_fedavg, fedavg_weights,
                                    normalize_weights, uniform_weights,
                                    weighted_average,
                                    weighted_average_stacked)
from repro.data.pipeline import stack_round
from repro.fl.client import Client, make_local_step, run_local
from repro.fl.comm import CommModel
from repro.fl.compress import (QUANTS, downlink_bytes,
                               ef_roundtrip_jit as _ef_jit, uplink_bytes)
from repro.fl.engine import (adam_stack_from_tree, make_round_engine,
                             resolve_engine, resolve_store, route_engine,
                             stacked_adam_init, stacked_zeros, store_tree,
                             tree_gather, tree_scatter)
from repro.fl.faults import (FaultSpec, apply_late, late_delta,
                             make_fault_model)
from repro.fl.record import RoundRecord, RunResult, evals_of
from repro.models import model
from repro.models.ops import resolve_backend, resolve_precision
from repro.obs.compile_tracker import CompileTracker
from repro.obs.trace import NULL_TRACER
from repro.optim import adam_init, adam_update

FLAT_METHODS = ("fedavg", "fedprox", "feddiffuse", "moon", "scaffold")


# ---------------------------------------------------------------------------
# FedDiffuse parameter partition: shared (communicated) vs local subsets.
# de Goede et al. split the U-Net; we share the encoder half (down+mid+temb)
# and keep the decoder (up, out) local — their "UDEC" variant mirrored.
# ---------------------------------------------------------------------------
_SHARED_KEYS_UNET = ("conv_in", "temb1", "temb2", "down", "mid")


def _split_shared(params: Dict, cfg: ModelConfig):
    if cfg.arch_type == "unet":
        shared = {k: v for k, v in params.items() if k in _SHARED_KEYS_UNET}
        local = {k: v for k, v in params.items() if k not in _SHARED_KEYS_UNET}
        return shared, local
    # transformers: share everything except the lm head / final norm
    local_keys = ("final_norm", "lm_head")
    shared = {k: v for k, v in params.items() if k not in local_keys}
    local = {k: v for k, v in params.items() if k in local_keys}
    return shared, local


def _merge(shared: Dict, local: Dict) -> Dict:
    out = dict(shared)
    out.update(local)
    return out


def shared_fraction(params: Dict, cfg: ModelConfig) -> float:
    shared, local = _split_shared(params, cfg)
    sb = sum(x.size for x in jax.tree.leaves(shared))
    lb = sum(x.size for x in jax.tree.leaves(local))
    return sb / max(sb + lb, 1)


@dataclasses.dataclass
class FlatFLResult:
    """Legacy ``run_flat_fl`` return shim.  ``history`` now holds the
    shared :class:`repro.fl.record.RoundRecord` schema (dict-style
    ``h["loss"]`` access still works)."""
    history: List[RoundRecord]
    params: Dict


def _rows_or_default(rows, default_tree, seen_rows):
    """Per-leaf select: stored row if the client has participated
    before, the current global value otherwise (the sequential path's
    ``dict.get(cid, params)`` semantics, vectorized)."""
    m = jnp.asarray(np.asarray(seen_rows, bool))
    pick = lambda r, g: jnp.where(m.reshape((-1,) + (1,) * g.ndim),
                                  r, g[None])
    return jax.tree.map(pick, rows, default_tree)


class FlatTrainer:
    """Round-stepped flat-FL trainer (the substrate of ``run_flat_fl``;
    exposed so benchmarks can interleave engines round-by-round)."""

    def __init__(self, method: str, cfg: ModelConfig, fl: FLConfig,
                 clients: List[Client], *, lr: float = 2e-4,
                 rng_seed: int = 0, engine: Optional[str] = None,
                 persistent_opt: bool = False, state_store: str = "auto",
                 mesh=None, client_axis: str = "data",
                 eval_fn: Optional[Callable] = None, eval_every: int = 0,
                 aggregation: str = "fedavg",
                 fault: Optional[FaultSpec] = None,
                 quant: str = "none", tracer=None):
        assert method in FLAT_METHODS
        if quant not in QUANTS:
            raise ValueError(f"unknown quant {quant!r}; expected one of "
                             f"{QUANTS}")
        self.quant = quant
        self.method = method
        if aggregation not in ("fedavg", "staleness"):
            raise ValueError(f"unknown flat aggregation {aggregation!r}")
        if aggregation == "staleness" and method != "fedavg":
            raise ValueError("staleness aggregation is a FedAvg variant "
                             f"(got method={method!r})")
        # "staleness" == FedAvg over on-time reporters + the buffered
        # late-delta merge; with no stragglers it IS FedAvg exactly
        self.aggregation = aggregation
        # pin the resolved compute backend + precision (one code path:
        # repro.experiment.resolve) so every compiled step/round program
        # and the memoized engine key carry concrete values — mirrors
        # FedPhD
        self.cfg = cfg = cfg.replace(
            backend=resolve_backend(cfg.backend),
            precision=resolve_precision(cfg.precision))
        # obs tracing: NULL_TRACER (the default) makes every span/event
        # call site a no-op — tracing never touches RNG or numerics
        self._obs = NULL_TRACER
        self._obs_compile = None
        self.fl = fl
        self.clients = clients
        self.lr = lr
        self.engine, self._engine_strict = resolve_engine(engine)
        self.persistent_opt = persistent_opt
        self.eval_fn = eval_fn
        self.eval_every = eval_every
        self._warned_ragged = False

        # fault injection (mirrors FedPhD): disabled spec -> no model,
        # every fault branch collapses to the fault-free path
        self.fault = fault if (fault is not None and fault.enabled) else None
        self._faults = make_fault_model(self.fault, len(clients), rng_seed)
        self._late_buf = None   # flat topology: one edge, one buffer

        self.np_rng = np.random.default_rng(rng_seed)
        self.rng = jax.random.PRNGKey(rng_seed)
        self.rng, sub = jax.random.split(self.rng)
        self.params = model.init(sub, cfg)
        self.comm = CommModel()
        self.mbytes = sum(x.size * x.dtype.itemsize
                          for x in jax.tree.leaves(self.params))

        self.step_fn = make_local_step(cfg, fl, method=method, lr=lr)
        self._opt_zero = adam_init(self.params)  # shared fresh-Adam tree
        # unroll=1: block-unrolling the scan lets XLA fuse ACROSS local
        # steps, which reassociates fp ops enough that FedProx/SCAFFOLD
        # Adam trajectories drift past atol 1e-5 from the sequential
        # reference; step-at-a-time keeps the baselines bit-stable (the
        # speedup is dispatch-bound anyway — see baseline_engine_bench).
        # Built unconditionally (memoized, jit-compiled only on first
        # call) so a trainer may switch self.engine between rounds.
        self.mesh = mesh
        self.client_axis = client_axis
        self._round_engine = make_round_engine(cfg, fl, method=method,
                                               lr=lr, unroll=1,
                                               mesh=mesh,
                                               client_axis=client_axis,
                                               quant=quant)

        n = len(clients)
        # stacked (N,) method state lives on device by default; for
        # large populations with small participation it moves to host
        # numpy and only the selected rows are staged per round
        self._store = resolve_store(
            state_store, n, max(1, round(fl.participation * n)))
        host = self._store == "host"
        self._opt_stack = stacked_adam_init(self.params, n, host=host) \
            if persistent_opt else None
        zeros_like = lambda t: jax.tree.map(
            lambda p: jnp.zeros_like(p, jnp.float32), t)
        # method state, all with a leading (N,) client axis; `seen`
        # marks clients that have participated (unseen rows default to
        # the current global model, matching the reference dict.get)
        self.c_global = zeros_like(self.params) \
            if method == "scaffold" else None
        self._c_local_stack = stacked_zeros(self.params, n,
                                            dtype=jnp.float32, host=host) \
            if method == "scaffold" else None
        self._prev_stack = stacked_zeros(self.params, n, host=host) \
            if method == "moon" else None
        self._local_stack = stacked_zeros(
            _split_shared(self.params, cfg)[1], n, host=host) \
            if method == "feddiffuse" else None
        # per-client error-feedback residuals for the quantized uplink
        # (repro.fl.compress): fp32, params-congruent, same residency
        # rules as the other stacked method state
        self._err_stack = stacked_zeros(self.params, n,
                                        dtype=np.float32, host=host) \
            if quant != "none" else None
        self._seen = np.zeros(n, bool)

        self.history: List[RoundRecord] = []
        if tracer is not None:
            self.bind_tracer(tracer)

    # -- observability -------------------------------------------------------
    def bind_tracer(self, tracer) -> None:
        """Attach an obs tracer (repro.obs): subsequent rounds emit
        phase spans / fault events / compile counters through it.
        ``None`` (or the NULL_TRACER) keeps the no-op path."""
        self._obs = tracer if tracer is not None else NULL_TRACER
        self._obs_compile = CompileTracker(self._obs) \
            if (self._obs.enabled
                and getattr(self._obs, "compile_tracking", False)) else None
        if self._obs_compile is not None:
            self._obs_compile.watch("step_fn", self.step_fn)
            self._obs_compile.watch("round_engine", self._round_engine)

    # -- engine routing ------------------------------------------------------
    def _use_vectorized(self, round_clients) -> bool:
        use, self._warned_ragged = route_engine(
            self.engine, self._engine_strict, round_clients,
            self._warned_ragged, "FlatTrainer", method=self.method)
        return use

    # -- reference path ------------------------------------------------------
    def _round_sequential(self, sel, subs, faults=None):
        """Per-client reference loop.  Under an active fault schedule:
        non-arrived clients run zero steps (RNG lockstep preserved),
        budgets truncate local training, only on-time reporters enter
        the FedAvg einsum (renormalized) or update client-local state,
        and late clients feed the staleness buffer."""
        method, fl, cfg, params = self.method, self.fl, self.cfg, self.params
        client_models, counts, losses, c_deltas = [], [], [], []
        late_models, late_counts = [], []
        for i, cid in enumerate(sel):
            cid = int(cid)
            cl = self.clients[cid]
            budget = faults.budget_of(cid) if faults else None
            completed = faults is None or faults.completed_of(cid)
            reporting = faults is None or faults.reporting_of(cid)
            start = params
            if method == "feddiffuse" and self._seen[cid]:
                shared, _ = _split_shared(params, cfg)
                start = _merge(shared, tree_gather(self._local_stack, cid))
            ctx = {}
            if method in ("fedprox", "moon"):
                ctx["global_params"] = params
            if method == "moon":
                ctx["prev_params"] = tree_gather(self._prev_stack, cid) \
                    if self._seen[cid] else params
            if method == "scaffold":
                ctx["c_local"] = tree_gather(self._c_local_stack, cid)
                ctx["c_global"] = self.c_global
            opt_in = tree_gather(self._opt_stack, cid) \
                if self.persistent_opt else self._opt_zero
            new_p, opt_out, loss = run_local(self.step_fn, start, cl,
                                             epochs=fl.local_epochs,
                                             rng=subs[i], ctx=ctx,
                                             opt_state=opt_in,
                                             max_steps=budget)
            losses.append(loss)
            if self.persistent_opt and completed:
                self._opt_stack = tree_scatter(self._opt_stack, cid, opt_out)
            if method == "moon" and completed:
                self._prev_stack = tree_scatter(self._prev_stack, cid, new_p)
                self._seen[cid] = True
            if method == "feddiffuse" and completed:
                shared, local = _split_shared(new_p, cfg)
                self._local_stack = tree_scatter(self._local_stack, cid,
                                                 local)
                self._seen[cid] = True
            if reporting:
                counts.append(cl.n_samples)
                up_p = new_p
                if self.quant != "none":
                    # quantized uplink: the server decodes start + deq;
                    # the residual persists as this client's error
                    # buffer.  Client-local state (MOON prev models,
                    # FedDiffuse local subtrees, SCAFFOLD variates)
                    # keeps the TRUE new_p above — it never hits the
                    # wire.  Delta base is the per-client start (for
                    # FedDiffuse that includes the local rows, matching
                    # the vectorized engine's lane start).
                    delta = jax.tree.map(lambda a, b: a - b, new_p, start)
                    e_row = store_tree(
                        tree_gather(self._err_stack, cid), "device")
                    deq, new_err = _ef_jit(delta, e_row, self.quant)
                    self._err_stack = tree_scatter(self._err_stack, cid,
                                                   new_err)
                    up_p = jax.tree.map(lambda s, d: s + d, start, deq)
                client_models.append(_split_shared(up_p, cfg)[0]
                                     if method == "feddiffuse" else up_p)
            elif faults is not None and faults.late_of(cid):
                late_models.append(new_p)
                late_counts.append(cl.n_samples)
            if method == "scaffold" and completed:
                # c_i+ = c_i - c + (x - y_i) / (K * lr); K = executed
                # steps (the fault budget when truncated; clamp dodges
                # a 0-step inf that the zero delta would NaN-multiply)
                steps = budget if faults else \
                    fl.local_epochs * cl.data.steps_per_epoch
                scale = 1.0 / (max(steps, 1) * self.lr)
                ci = ctx["c_local"]
                new_ci = jax.tree.map(
                    lambda ci_, c, x, y: ci_ - c + scale
                    * (x.astype(jnp.float32) - y.astype(jnp.float32)),
                    ci, self.c_global, start, new_p)
                c_deltas.append(jax.tree.map(lambda a, b: a - b, new_ci, ci))
                self._c_local_stack = tree_scatter(self._c_local_stack, cid,
                                                   new_ci)

        # graceful degradation: no reporter -> the server keeps params
        agg = aggregate_fedavg(client_models, counts) \
            if client_models else (_split_shared(params, cfg)[0]
                                   if method == "feddiffuse" else params)
        if self.aggregation == "staleness":
            buf, self._late_buf = self._late_buf, None
            if buf is not None:         # merge last round's stragglers
                agg = apply_late(agg, buf, self.fault.staleness
                                 if self.fault else 0.0)
            if late_models:
                tot = max(sum(counts) + sum(late_counts), 1)
                self._late_buf = late_delta(
                    late_models, params, [n / tot for n in late_counts])
        if method == "feddiffuse":
            _, local = _split_shared(params, cfg)
            self.params = _merge(agg, local)
        else:
            self.params = agg
        if method == "scaffold" and c_deltas:
            mean_dc = weighted_average(c_deltas,
                                       uniform_weights(len(c_deltas)))
            frac = len(c_deltas) / len(self.clients)
            self.c_global = jax.tree.map(lambda c, d: c + frac * d,
                                         self.c_global, mean_dc)
        return losses

    # -- device-resident path ------------------------------------------------
    def _round_vectorized(self, sel, subs, faults=None, r=0):
        """E=1 engine round.  Faults stay shape-static: budgets AND a
        prefix into the (C, S) valid mask, non-reporting clients get a
        zero aggregation weight (renormalized among reporters), and
        late deltas return via the ``w_late`` einsum."""
        method, fl, cfg, params = self.method, self.fl, self.cfg, self.params
        obs = self._obs
        with obs.span("round/host_prep", round=r):
            sel_arr = np.asarray(sel)
            sel_clients = [self.clients[int(cid)] for cid in sel]
            counts = [cl.n_samples for cl in sel_clients]
            rep = np.asarray([faults is None or faults.reporting_of(int(c))
                              for c in sel], bool)
            comp = np.asarray([faults is None or faults.completed_of(int(c))
                               for c in sel], bool)

            batches, valid, padded = stack_round(
                [cl.data for cl in sel_clients], fl.local_epochs)
            if faults is not None:
                budgets = np.asarray([faults.budget_of(int(c)) for c in sel])
                prefix = np.arange(valid.shape[1])[None, :] < budgets[:, None]
                padded = padded or not bool(prefix.all())
                valid = valid & prefix
        with obs.span("round/h2d", round=r):
            batches = {k: jnp.asarray(v) for k, v in batches.items()}
            valid = jnp.asarray(valid)
            rngs = jnp.stack(subs)
        # the flat topology is the E=1 special case of the edge engine
        server = jax.tree.map(lambda leaf: leaf[None], params)
        edge_idx = jnp.zeros((len(sel),), jnp.int32)
        w = np.zeros(len(sel), np.float32)
        if rep.any():
            w[rep] = normalize_weights(
                fedavg_weights([c for c, m in zip(counts, rep) if m]))
        w_row = jnp.asarray(w[None])
        w_late = None
        if self.aggregation == "staleness" and faults is not None:
            late = np.asarray([faults.late_of(int(c)) for c in sel], bool)
            if late.any():
                tot = max(int(np.sum(np.asarray(counts)[rep]))
                          + int(np.sum(np.asarray(counts)[late])), 1)
                wl = np.zeros(len(sel), np.float32)
                wl[late] = np.asarray(counts, np.float32)[late] / tot
                w_late = jnp.asarray(wl[None])

        ctx = None
        if method in ("fedprox", "moon"):
            ctx = {"global_params": params}
        if method == "moon":
            rows = tree_gather(self._prev_stack, sel_arr)
            ctx["prev_params"] = _rows_or_default(rows, params,
                                                  self._seen[sel_arr])
        if method == "feddiffuse":
            _, local_g = _split_shared(params, cfg)
            rows = tree_gather(self._local_stack, sel_arr)
            ctx = {"local_params": _rows_or_default(rows, local_g,
                                                    self._seen[sel_arr])}
        if method == "scaffold":
            steps = np.asarray(budgets, np.float64) if faults is not None \
                else np.asarray([fl.local_epochs * cl.data.steps_per_epoch
                                 for cl in sel_clients], np.float64)
            # clamp: a 0-step budget would make scale inf and the zero
            # (x - y) delta NaN under inf*0
            scale = 1.0 / (np.maximum(steps, 1) * self.lr)
            ctx = {"c_local": tree_gather(self._c_local_stack, sel_arr),
                   "c_global": self.c_global,
                   "scale": jnp.asarray(scale, jnp.float32)}

        # host store: gathered rows are numpy — stage the opt rows to
        # device explicitly (numpy inputs would silently defeat the
        # engine's opt_states buffer donation)
        with obs.span("round/dispatch", round=r):
            out = self._round_engine(
                server, edge_idx, batches, valid, rngs, w_row, ctx=ctx,
                opt_states=(store_tree(tree_gather(self._opt_stack, sel_arr),
                                       "device")
                            if self.persistent_opt else None),
                w_late=w_late,
                err=(store_tree(tree_gather(self._err_stack, sel_arr),
                                "device")
                     if self.quant != "none" else None),
                masked=padded, per_client_opt=self.persistent_opt)
        # NO host sync here: the (C,) loss array stays a device future
        # until _finish_round — under the pipelined run() the next
        # round's host data prep + H2D overlap this round's compute
        losses = out["losses"]
        if rep.any():
            agg = jax.tree.map(lambda leaf: leaf[0], out["agg"])
        else:
            # a zero w_row makes the einsum a zero tree: keep params
            agg = _split_shared(params, cfg)[0] \
                if method == "feddiffuse" else params
        if self.aggregation == "staleness":
            buf, self._late_buf = self._late_buf, None
            if buf is not None:         # merge last round's stragglers
                agg = apply_late(agg, buf, self.fault.staleness
                                 if self.fault else 0.0)
            if w_late is not None:
                self._late_buf = jax.tree.map(lambda leaf: leaf[0],
                                              out["late"])
        comp_rel = np.flatnonzero(comp)

        if self.quant != "none":
            # only ON-TIME reporters shipped a quantized payload —
            # their lanes (and only theirs) persist a new residual
            rep_rel = np.flatnonzero(rep)
            if len(rep_rel):
                self._err_stack = tree_scatter(
                    self._err_stack, sel_arr[rep_rel],
                    tree_gather(out["err"], rep_rel))

        if self.persistent_opt and len(comp_rel):
            if faults is None:
                self._opt_stack = tree_scatter(self._opt_stack, sel_arr,
                                               out["opt"])
            else:   # only COMPLETED clients keep their updated moments
                self._opt_stack = tree_scatter(
                    self._opt_stack, sel_arr[comp_rel],
                    tree_gather(out["opt"], comp_rel))
        if method == "moon" and len(comp_rel):
            self._prev_stack = tree_scatter(
                self._prev_stack, sel_arr[comp_rel],
                tree_gather(out["trained"], comp_rel))
            self._seen[sel_arr[comp_rel]] = True
        if method == "feddiffuse":
            shared_g, local_g = _split_shared(params, cfg)
            if len(comp_rel):
                trained_local = {k: out["trained"][k] for k in local_g}
                self._local_stack = tree_scatter(
                    self._local_stack, sel_arr[comp_rel],
                    tree_gather(trained_local, comp_rel))
                self._seen[sel_arr[comp_rel]] = True
            # only the shared half of the fused aggregate is used; the
            # server keeps its own local subtree (never communicated)
            self.params = _merge({k: agg[k] for k in shared_g}, local_g)
        else:
            self.params = agg
        if method == "scaffold":
            if faults is None:
                self._c_local_stack = tree_scatter(
                    self._c_local_stack, sel_arr, out["c_new"])
                frac = len(sel) / len(self.clients)
                self.c_global = jax.tree.map(lambda c, d: c + frac * d,
                                             self.c_global, out["dc_mean"])
            elif len(comp_rel):
                # the engine's dc_mean averages every lane uniformly —
                # under faults recompute it over completed lanes only
                self._c_local_stack = tree_scatter(
                    self._c_local_stack, sel_arr[comp_rel],
                    tree_gather(out["c_new"], comp_rel))
                dc = jax.tree.map(lambda a, b: a - b, out["c_new"],
                                  ctx["c_local"])
                w_dc = comp.astype(np.float64) / len(comp_rel)
                mean_dc = weighted_average_stacked(dc, w_dc)
                frac = len(comp_rel) / len(self.clients)
                self.c_global = jax.tree.map(lambda c, d: c + frac * d,
                                             self.c_global, mean_dc)
        return losses

    # -- one round -----------------------------------------------------------
    def _wire_bytes(self):
        """Per-transfer volumes ``(up_quantized, up_full, down)`` in
        bytes-on-wire (repro.fl.compress).  Only the model subtree a
        method actually communicates is counted: FedDiffuse ships the
        shared half, SCAFFOLD adds its fp32 control variates (never
        quantized) in both directions."""
        comm_tree = _split_shared(self.params, self.cfg)[0] \
            if self.method == "feddiffuse" else self.params
        up_q = uplink_bytes(comm_tree, self.quant)
        up_f = uplink_bytes(comm_tree, "none")
        down = downlink_bytes(comm_tree, self.cfg.precision)
        if self.method == "scaffold":
            up_q += uplink_bytes(self.params, "none")
            up_f += uplink_bytes(self.params, "none")
            down += downlink_bytes(self.params, "fp32")
        return up_q, up_f, down

    def run_round(self, r: int) -> RoundRecord:
        return self._finish_round(self._start_round(r))

    def _start_round(self, r: int) -> Dict:
        """Dispatch one round — selection, RNG folding, the round
        program, method-state scatter, aggregation — everything except
        blocking on the device losses.  Returns the pending-round dict
        ``_finish_round`` turns into a RoundRecord (the FedPhD
        ``_start_round``/``_finish_round`` split, on the flat topology).

        On the vectorized engine nothing here forces a host sync, so
        ``run()`` double-buffers rounds: round r+1's ``stack_round``
        shuffle/stack and H2D copy run while round r's program is still
        executing.
        """
        fl, method = self.fl, self.method
        if self._faults is not None:
            # churn first (its own RNG stream), then sample participants
            # from the online pool only — with churn=0 the np_rng
            # consumption is identical to the fault-free path
            online = self._faults.begin_round()
            pool = np.flatnonzero(online)
            C = min(max(1, round(fl.participation * len(self.clients))),
                    len(pool))
            sel = pool[self.np_rng.choice(len(pool), size=C, replace=False)]
        else:
            C = max(1, round(fl.participation * len(self.clients)))
            sel = self.np_rng.choice(len(self.clients), size=C,
                                     replace=False)
        # identical RNG folding on both paths: one split per selected
        # client, in selection order
        subs = []
        for _ in range(C):
            self.rng, sub = jax.random.split(self.rng)
            subs.append(sub)

        faults = None
        if self._faults is not None:
            steps = [fl.local_epochs * self.clients[int(c)].data.steps_per_epoch
                     for c in sel]
            faults = self._faults.draw_round(
                sel, steps, self.aggregation == "staleness")
            if self._obs.enabled:
                self._obs.event("fault/draw", round=r,
                                **faults.summary())

        if self._use_vectorized([self.clients[int(c)] for c in sel]):
            losses = self._round_vectorized(sel, subs, faults,
                                            r=r)               # dev future
        else:
            # the reference loop syncs per batch: host prep, compute and
            # aggregation interleave, so it gets one dispatch span
            with self._obs.span("round/dispatch", round=r):
                losses = self._round_sequential(sel, subs,
                                                faults)        # host floats

        up_q, up_f, down = self._wire_bytes()
        if faults is None:
            up_bytes = len(sel) * self.comm.edge_cloud(up_q)
            down_bytes = len(sel) * self.comm.edge_cloud(down)
        else:
            # downloads to every arrived client, uploads only from the
            # clients that finished (dropped clients = zero uplink);
            # only on-time reporters shipped the quantized payload
            n_arr = int(np.sum(faults.arrived))
            n_rep = sum(1 for c in sel if faults.completed_of(int(c))
                        and faults.reporting_of(int(c)))
            n_full = int(np.sum(faults.completed)) - n_rep
            up_bytes = n_rep * self.comm.edge_cloud(up_q) \
                + n_full * self.comm.edge_cloud(up_f)
            down_bytes = n_arr * self.comm.edge_cloud(down)
        # snapshot end-of-round state the record needs: the params the
        # eval hook sees must not leak mutations from a round
        # dispatched before this one is finalized
        return {
            "round": r, "losses": losses, "sel_ids": sel,
            "up_bytes": up_bytes, "down_bytes": down_bytes,
            "params_m": sum(x.size
                            for x in jax.tree.leaves(self.params)) / 1e6,
            "params": self.params, "cfg": self.cfg,
            "loss_mask": ([faults.budget_of(int(c)) > 0 for c in sel]
                          if faults else None),
            "availability": faults.availability() if faults else None,
        }

    def _finish_round(self, pend: Dict) -> RoundRecord:
        """Sync the pending round's losses and append its RoundRecord."""
        losses = pend["losses"]
        if not isinstance(losses, list):          # device future -> host
            with self._obs.span("round/loss_sync", round=pend["round"]):
                losses = [float(x) for x in np.asarray(losses)]
        r = pend["round"]
        mask = pend.get("loss_mask")
        if mask is not None:        # faults: average over executed clients
            losses = [l for l, m in zip(losses, mask) if m]
        rec = RoundRecord(
            round=r,
            loss=float(np.mean(losses)) if losses else 0.0,
            # totals as the sum of the ROUNDED up/down fields, so
            # comm_gb == comm_up_gb + comm_down_gb holds exactly (the
            # real value is the same; fault-free flat comm stays
            # bitwise-equal to the legacy 2n*edge_cloud(v)/1e9 because
            # rounding commutes with the exact power-of-2 doubling)
            comm_gb=pend["up_bytes"] / 1e9 + pend["down_bytes"] / 1e9,
            comm_up_gb=pend["up_bytes"] / 1e9,
            comm_down_gb=pend["down_bytes"] / 1e9,
            params_m=pend["params_m"],
            selected=[int(c) for c in pend["sel_ids"]],
            availability=pend.get("availability"),
        )
        # append BEFORE the eval hook: the round executed (trainer state
        # and RNG streams advanced), so a raising eval_fn must lose the
        # eval, not the round — otherwise a later run()/resume would
        # re-run an already-applied round and diverge
        self.history.append(rec)
        if self._obs_compile is not None:
            # compiles triggered by this round's dispatch/sync are in
            # the caches by now; growth beyond the allowance = a
            # shape/dtype leaked into a trace
            self._obs_compile.check(round=r)
        if self.eval_fn and self.eval_every and r % self.eval_every == 0:
            rec.eval = self.eval_fn(pend["params"], pend["cfg"], r)
        return rec

    def run(self, rounds: Optional[int] = None, *,
            eval_every: Optional[int] = None) -> RunResult:
        """Run rounds ``len(history)+1 .. rounds`` (continues after a
        restore) — the same ``Trainer`` contract as ``FedPhD.run``.

        Rounds are double-buffered exactly like ``FedPhD.run``: round
        r+1 is dispatched (``_start_round``) before round r's losses are
        synced (``_finish_round``); records finalize in round order and
        the numerics are identical to stepping ``run_round`` — only the
        sync point moves."""
        rounds = rounds or self.fl.rounds
        if eval_every is not None:
            self.eval_every = eval_every
        pend = None
        try:
            for r in range(len(self.history) + 1, rounds + 1):
                cur = self._start_round(r)
                # hand cur to the guard BEFORE finishing prev: if
                # _finish_round(prev) raises (eval hook), prev is
                # already in history (append-before-eval) and the
                # finally still finalizes the dispatched cur — no
                # executed round is ever orphaned
                prev, pend = pend, cur
                if prev is not None:
                    self._finish_round(prev)
        finally:
            # a raising _start_round (e.g. strict-vectorized hitting a
            # ragged selection) must not orphan the already-executed
            # previous round: finalize it so history matches the
            # advanced trainer state.  Finalize only when it extends
            # history contiguously — if prev's own finalize died before
            # its append, recording cur would leave a round-number gap
            if pend is not None and len(self.history) == pend["round"] - 1:
                self._finish_round(pend)
        return RunResult(self.history, evals_of(self.history))

    # -- checkpoint state (repro.experiment resume contract) -----------------
    def state(self):
        """``(arrays, meta)`` mirroring ``FedPhD.state``: the stacked
        per-client method buffers (SCAFFOLD variates, MOON prev models,
        FedDiffuse local subtrees, persistent Adam), global params, and
        every RNG stream the trajectory consumes."""
        arrays = {
            "params": self.params,
            "rng": self.rng,
            "opt_stack": self._opt_stack,
            "c_global": self.c_global,
            "c_local_stack": self._c_local_stack,
            "prev_stack": self._prev_stack,
            "local_stack": self._local_stack,
            "seen": self._seen,
            "late_buf": self._late_buf,
            "err_stack": self._err_stack,
        }
        meta = {
            "trainer": "flat",
            "method": self.method,
            "np_rng": self.np_rng.bit_generator.state,
            "client_rngs": [cl.data.rng_state() for cl in self.clients],
            "history": [rec.to_dict() for rec in self.history],
            "fault": self._faults.state() if self._faults else None,
        }
        return arrays, meta

    def restore(self, arrays, meta) -> None:
        """Inverse of ``state()`` on a trainer built with the same
        constructor arguments."""
        if meta.get("method", self.method) != self.method:
            raise ValueError(f"checkpoint is for method "
                             f"{meta['method']!r}, trainer is {self.method!r}")
        to_dev = lambda t: None if t is None \
            else jax.tree.map(jnp.asarray, t)
        # stacked (N,) buffers land wherever this trainer keeps them
        # (host numpy or device), non-stacked state always on device
        to_store = lambda t: store_tree(t, self._store)
        self.params = to_dev(arrays["params"])
        self.rng = jnp.asarray(arrays["rng"])
        self.c_global = to_dev(arrays["c_global"])
        self._c_local_stack = to_store(arrays["c_local_stack"])
        self._prev_stack = to_store(arrays["prev_stack"])
        self._local_stack = to_store(arrays["local_stack"])
        self._seen = np.asarray(arrays["seen"], bool).copy()
        self._late_buf = to_dev(arrays.get("late_buf"))
        if self.quant != "none" and arrays.get("err_stack") is not None:
            self._err_stack = to_store(arrays["err_stack"])
        if self.persistent_opt:
            self._opt_stack = adam_stack_from_tree(arrays["opt_stack"],
                                                   self._store)
        self.np_rng.bit_generator.state = meta["np_rng"]
        for cl, st in zip(self.clients, meta["client_rngs"]):
            cl.data.set_rng_state(st)
        if self._faults is not None and meta.get("fault"):
            self._faults.set_state(meta["fault"])
        self.history = [RoundRecord.from_dict(d) for d in meta["history"]]


def run_flat_fl(method: str, cfg: ModelConfig, fl: FLConfig,
                clients: List[Client], *, rounds: Optional[int] = None,
                lr: float = 2e-4, rng_seed: int = 0,
                eval_fn: Optional[Callable] = None,
                eval_every: int = 0, engine: Optional[str] = None,
                persistent_opt: bool = False) -> FlatFLResult:
    """Deprecated legacy front-end — use ``repro.experiment.run_spec``
    (declarative, resumable, traced) or construct :class:`FlatTrainer`
    directly; this wrapper will be removed after one release.

    method in {fedavg, fedprox, feddiffuse, moon, scaffold}.

    engine: "vectorized" | "sequential" | "auto" (None = $FEDPHD_ENGINE
    or auto); persistent_opt carries per-client Adam moments across
    rounds (off by default — the paper's baselines restart Adam each
    round).  ``eval_fn(params, cfg, round)`` results land in
    ``RoundRecord.eval`` (the unified hook contract).
    """
    warnings.warn(
        "run_flat_fl is deprecated; use repro.experiment.run_spec(...) "
        "or FlatTrainer(...) directly", DeprecationWarning, stacklevel=2)
    trainer = FlatTrainer(method, cfg, fl, clients, lr=lr,
                          rng_seed=rng_seed, engine=engine,
                          persistent_opt=persistent_opt,
                          eval_fn=eval_fn, eval_every=eval_every)
    trainer.run(rounds or fl.rounds)
    return FlatFLResult(history=trainer.history, params=trainer.params)


def run_centralized(cfg: ModelConfig, images: np.ndarray, *, steps: int,
                    batch_size: int, lr: float = 2e-4, rng_seed: int = 0,
                    use_ema: bool = True):
    """Centralized baseline (paper: 500K steps + EMA; scaled down here)."""
    from repro.optim import ema_init, ema_update
    rng = jax.random.PRNGKey(rng_seed)
    rng, sub = jax.random.split(rng)
    params = model.init(sub, cfg)
    opt_state = adam_init(params)
    ema = ema_init(params) if use_ema else None
    np_rng = np.random.default_rng(rng_seed)

    @jax.jit
    def step(params, opt_state, batch, rng):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss_fn(p, cfg, batch, rng))(params)
        params, opt_state = adam_update(grads, opt_state, params, lr=lr,
                                        grad_clip=1.0)
        return params, opt_state, loss

    losses = []
    for _ in range(steps):
        sel = np_rng.integers(0, len(images), size=batch_size)
        batch = {"images": jnp.asarray(images[sel])}
        rng, sub = jax.random.split(rng)
        params, opt_state, loss = step(params, opt_state, batch, sub)
        losses.append(float(loss))
        if use_ema:
            ema = ema_update(ema, params, 0.999)
    final = jax.tree.map(lambda e, p: e.astype(p.dtype), ema, params) \
        if use_ema else params
    return final, losses
