"""Quantized client->edge delta uplink with error feedback.

FedPhD cuts communication structurally (pruning shrinks the model that
ships); this module cuts it numerically, on the same uplink: each
on-time client uploads its round delta ``theta_i - start`` quantized to
int8 or fp8-e4m3 with ONE fp32 scale per parameter leaf, and keeps a
persistent fp32 *error-feedback* buffer so the quantization residual is
re-added to the next round's delta instead of being lost — FedDM's
compression direction (PAPERS.md), which preserves sample quality
because the error is fed back, not dropped.

Contract:

  * quantization applies to the ON-TIME reporting uplink only.  Late
    (staleness) deltas, SCAFFOLD control variates, and every download
    ship uncompressed; MOON/FedDiffuse client-local state is never a
    wire payload and stays exact.
  * the edge aggregates the *reconstructed* models ``start + deq`` —
    what it could actually decode from the wire — so the trained
    trajectory honestly includes the compression error.
  * error-feedback buffers are per-client fp32 pytrees congruent with
    the params.  They ride the stacked per-client state substrate of
    ``repro.fl.engine`` (host ``state_store`` aware), checkpoint in
    ``state()``/``restore()``, and reset at the prune boundary (the
    leaf shapes change under them).
  * scales are per leaf per client: ``maxabs / qmax``.  fp8-e4m3 does
    NOT saturate on overflow in XLA (out-of-range casts produce NaN),
    so values are clipped to +-448 before the cast.

Byte accounting (:func:`uplink_bytes` / :func:`downlink_bytes`) is
bytes-on-wire: quantized payloads count 1 byte per element plus a 4-byte
fp32 scale per leaf; unquantized uploads count the fp32 master deltas
aggregation consumes; downloads count the compute-dtype cast clients
actually consume (2 bytes/param under bf16).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

QUANTS = ("none", "int8", "fp8")

# fp8 is e4m3fn: max finite magnitude 448; int8 symmetric around 0
_QMAX = {"int8": 127.0, "fp8": 448.0}

_PRECISION_BYTES = {"": 4, "fp32": 4, "bf16": 2}


@dataclasses.dataclass(frozen=True)
class CommSpec:
    """Declarative comm-compression knobs (lives on
    ``ExperimentSpec.comm``, so sweeps can grid over ``comm.quant``)."""
    quant: str = "none"          # none | int8 | fp8 — uplink delta dtype

    def __post_init__(self):
        if self.quant not in QUANTS:
            raise ValueError(f"comm.quant={self.quant!r} not in {QUANTS}")

    @property
    def enabled(self) -> bool:
        return self.quant != "none"

    def replace(self, **kw) -> "CommSpec":
        return dataclasses.replace(self, **kw)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "CommSpec":
        known = {k: v for k, v in d.items()
                 if k in {f.name for f in dataclasses.fields(cls)}}
        return cls(**known)


# ---------------------------------------------------------------------------
# quantize / dequantize (jit-safe; `quant` is trace-time static)
# ---------------------------------------------------------------------------

def _quantize_leaf(v, quant: str, axes):
    """fp32 leaf -> (payload, fp32 scale) with maxabs/qmax scaling over
    ``axes`` (all axes for a single client, trailing axes for a stacked
    (C, ...) leaf so every client gets its own scale)."""
    qmax = _QMAX[quant]
    amax = jnp.max(jnp.abs(v), axis=axes, keepdims=True)
    scale = jnp.where(amax > 0, amax / qmax, 1.0).astype(jnp.float32)
    if quant == "int8":
        q = jnp.clip(jnp.round(v / scale), -qmax, qmax).astype(jnp.int8)
    else:
        # e4m3 overflow is NaN, not saturation — clip BEFORE the cast
        q = jnp.clip(v / scale, -qmax, qmax).astype(jnp.float8_e4m3fn)
    return q, scale


def ef_roundtrip(delta, err, quant: str):
    """Per-client error-feedback round trip over a params-congruent
    pytree: ``v = delta + err`` is quantized leaf-wise (one scale per
    leaf), and ``(dequantized, v - dequantized)`` trees come back.

    The caller aggregates ``start + dequantized`` and persists the new
    residual as the client's error buffer for the next round."""
    leaves, treedef = jax.tree.flatten(delta)
    errs = treedef.flatten_up_to(err)
    out = [_ef_leaf(d, e, quant, stacked=False) for d, e in zip(leaves, errs)]
    deq = treedef.unflatten([o[0] for o in out])
    new_err = treedef.unflatten([o[1] for o in out])
    return deq, new_err


# one compiled round trip per (treedef, quant) — the sequential paths
# of both trainers call this once per reporting client
ef_roundtrip_jit = jax.jit(ef_roundtrip, static_argnums=2)


def ef_roundtrip_stacked(delta, err, quant: str):
    """Vectorized-engine variant: every leaf carries a leading client
    axis ``(C, ...)``; scales are per client per leaf (reduced over the
    trailing axes), matching :func:`ef_roundtrip` client-for-client."""
    leaves, treedef = jax.tree.flatten(delta)
    errs = treedef.flatten_up_to(err)
    out = [_ef_leaf(d, e, quant, stacked=True) for d, e in zip(leaves, errs)]
    deq = treedef.unflatten([o[0] for o in out])
    new_err = treedef.unflatten([o[1] for o in out])
    return deq, new_err


def _ef_leaf(d, e, quant: str, *, stacked: bool):
    v = d.astype(jnp.float32) + e
    axes = tuple(range(1, v.ndim)) if stacked else None
    q, scale = _quantize_leaf(v, quant, axes)
    deq = q.astype(jnp.float32) * scale
    return deq, v - deq


# ---------------------------------------------------------------------------
# bytes-on-wire accounting (host-side, exact)
# ---------------------------------------------------------------------------

def tree_counts(tree):
    """(total elements, number of leaves) of a pytree — static shapes,
    so every engine computes identical byte totals."""
    leaves = jax.tree.leaves(tree)
    return int(sum(int(x.size) for x in leaves)), len(leaves)


def uplink_bytes(tree, quant: str, *, precision: str = "fp32") -> int:
    """One client->edge upload of ``tree``: quantized payloads ship one
    byte per element plus a 4-byte fp32 scale per leaf; ``none`` ships
    the fp32 master delta aggregation consumes (uploads do NOT shrink
    under bf16 compute — the trained result the server needs is the
    fp32 master)."""
    n, leaves = tree_counts(tree)
    if quant == "none":
        return n * 4
    return n * 1 + leaves * 4


def downlink_bytes(tree, precision: str) -> int:
    """One edge->client broadcast: clients compute in the resolved
    precision, so the wire carries the compute-dtype cast (2 bytes per
    param under bf16; see README for the fp32-master caveat)."""
    n, _ = tree_counts(tree)
    return n * _PRECISION_BYTES[precision]
