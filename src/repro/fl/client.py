"""Client-side local training (paper Alg. 2) + baseline variants.

``make_local_step`` builds one jitted SGD/Adam step whose loss is
composed from the DM loss (Eq. 6 via model.loss_fn) plus, depending on
the method:
  - FedPhD sparse rounds: + Omega(G, k) group-lasso (Eq. 16),
  - FedProx:              + mu/2 ||theta - theta_global||^2,
  - MOON:                 + contrastive term on model output features,
  - SCAFFOLD:             gradient correction g - c_i + c.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig, ModelConfig
from repro.core.pruning import depth_lambdas, omega
from repro.data.pipeline import ClientData
from repro.models import model
from repro.models.ops import cast_floats, compute_dtype
from repro.optim import adam_init, adam_update


def tree_sq_dist(a, b):
    return sum(jnp.sum(jnp.square(x.astype(jnp.float32)
                                  - y.astype(jnp.float32)))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def model_features(params, cfg: ModelConfig, batch, rng):
    """Representation for MOON's contrastive term.

    For the diffusion U-Net we use the pooled noise prediction at a fixed
    mid-schedule timestep — a function-space feature (MOON's penultimate-
    layer choice has no direct analogue for eps-predictors; DESIGN.md §8).
    """
    if cfg.arch_type == "unet":
        from repro.diffusion import linear_schedule, q_sample
        from repro.models.unet import apply_unet
        sched = linear_schedule(cfg.diffusion_steps)
        B = batch["images"].shape[0]
        t = jnp.full((B,), cfg.diffusion_steps // 2, jnp.int32)
        eps = jax.random.normal(rng, batch["images"].shape)
        x_t = q_sample(sched, batch["images"], t, eps)
        pred = apply_unet(params, cfg, x_t, t)
        return pred.reshape(B, -1)
    from repro.models.transformer import forward
    hidden, _ = forward(params, cfg, batch)
    return jnp.mean(hidden, axis=1)


def _cosine(a, b):
    num = jnp.sum(a * b, axis=-1)
    den = jnp.linalg.norm(a, axis=-1) * jnp.linalg.norm(b, axis=-1) + 1e-8
    return num / den


def make_loss_fn(cfg: ModelConfig, fl: FLConfig, *, method: str = "fedphd",
                 sparse: bool = False, groups=None, prune_masks=None):
    """Method-parameterized local loss (the SINGLE definition both the
    sequential per-batch step and the vectorized round engine close
    over, so the two paths are equivalent by construction).

    Returns ``loss_fn(params, batch, rng, ctx)``; ctx carries the
    method's anchors ("global_params", "prev_params", "c_local",
    "c_global", ... — static structure per jit).

    ``cfg.backend`` selects the compute backend for every tensor-core
    op inside (repro.models.ops); ``prune_masks`` switches the U-Net
    forward to the masked sparse-phase path (col/row-masked GEMMs
    instead of training on pre-zeroed weights).

    ``cfg.precision`` is the mixed-precision boundary: under bf16 the
    float params are cast to bfloat16 HERE, inside the loss closure —
    so both consumers (``make_local_step`` and the round engine's
    ``make_train_one``) compute forward+backward in bf16 while
    ``value_and_grad`` transposes the ``astype`` back to fp32 grads;
    the params the optimizer sees remain the fp32 master weights.
    """
    lambdas = depth_lambdas(groups, fl.lambda0) if (sparse and groups) else None
    dt = compute_dtype(cfg.precision)

    def loss_fn(params, batch, rng, ctx):
        if dt != jnp.float32:
            params = cast_floats(params, dt)
        loss = model.loss_fn(params, cfg, batch, rng, masks=prune_masks)
        if sparse and groups:
            loss = loss + omega(params, groups, lambdas, backend=cfg.backend)
        if method == "fedprox":
            loss = loss + 0.5 * fl.fedprox_mu * tree_sq_dist(
                params, ctx["global_params"])
        if method == "moon":
            rng_f = jax.random.fold_in(rng, 1)
            z = model_features(params, cfg, batch, rng_f)
            z_g = model_features(ctx["global_params"], cfg, batch, rng_f)
            z_p = model_features(ctx["prev_params"], cfg, batch, rng_f)
            sim_g = _cosine(z, z_g) / fl.moon_tau
            sim_p = _cosine(z, z_p) / fl.moon_tau
            con = -jnp.mean(sim_g - jnp.logaddexp(sim_g, sim_p))
            loss = loss + fl.moon_mu * con
        return loss

    return loss_fn


def scaffold_correction(grads, ctx):
    """SCAFFOLD variance-reduced gradient g - c_i + c (Karimireddy et al.)."""
    return jax.tree.map(lambda g, ci, c: g - ci + c, grads,
                        ctx["c_local"], ctx["c_global"])


def make_local_step(cfg: ModelConfig, fl: FLConfig, *, method: str = "fedphd",
                    sparse: bool = False, groups=None, lr: float = 2e-4,
                    prune_masks=None):
    """Returns jitted step(params, opt_state, batch, rng, ctx) -> (...)

    ctx: dict with optional "global_params", "prev_params", "c_local",
    "c_global" (present per method; static structure per jit).
    """
    loss_fn = make_loss_fn(cfg, fl, method=method, sparse=sparse,
                           groups=groups, prune_masks=prune_masks)

    @jax.jit
    def step(params, opt_state, batch, rng, ctx):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch, rng, ctx)
        if method == "scaffold":
            grads = scaffold_correction(grads, ctx)
        params, opt_state = adam_update(grads, opt_state, params, lr=lr,
                                        grad_clip=1.0)
        return params, opt_state, loss

    return step


@dataclasses.dataclass
class Client:
    """One federated client: local data + label distribution q_n."""
    cid: int
    data: ClientData
    num_classes: int

    def __post_init__(self):
        from repro.core.sh_score import label_distribution
        self.q_n = label_distribution(self.data.labels, self.num_classes)

    @property
    def n_samples(self) -> int:
        return len(self.data)


def run_local(step_fn, params, client: Client, *, epochs: int, rng,
              ctx: Optional[Dict[str, Any]] = None, opt_state=None,
              max_steps: Optional[int] = None):
    """Run E local epochs (Alg. 2).  Returns (params, opt_state, mean loss).

    ``max_steps`` caps the number of executed steps (fault injection:
    straggler budgets / mid-round dropout).  The epoch generators are
    still drained past the cap so the shuffle RNG advances exactly as
    in an untruncated round — keeping the sequential path in lockstep
    with the vectorized engine, whose ``stacked_epochs`` stacking
    always consumes whole epochs and truncates via the valid mask.
    """
    if opt_state is None:
        opt_state = adam_init(params)
    ctx = ctx or {}
    losses = []
    executed = 0
    for _ in range(epochs):
        for batch in client.data.epoch():
            if max_steps is not None and executed >= max_steps:
                continue                  # drain: shuffle RNG must advance
            rng, sub = jax.random.split(rng)
            jb = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt_state, loss = step_fn(params, opt_state, jb, sub, ctx)
            losses.append(float(loss))
            executed += 1
    return params, opt_state, float(np.mean(losses)) if losses else 0.0
