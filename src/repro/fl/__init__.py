from repro.fl.client import Client, make_local_step, run_local
from repro.fl.comm import CommModel
from repro.fl.baselines import run_flat_fl, run_centralized, FlatFLResult
from repro.fl.engine import (make_round_engine, stack_clients,
                             uniform_batch_shape)

__all__ = ["Client", "make_local_step", "run_local", "CommModel",
           "run_flat_fl", "run_centralized", "FlatFLResult",
           "make_round_engine", "stack_clients", "uniform_batch_shape"]
