from repro.fl.client import (Client, make_local_step, make_loss_fn,
                             run_local, scaffold_correction)
from repro.fl.comm import CommModel
from repro.fl.baselines import (FlatFLResult, FlatTrainer, run_centralized,
                                run_flat_fl, shared_fraction)
from repro.fl.engine import (CTX_AXES, ENGINES, make_round_engine,
                             make_train_one, resolve_engine, route_engine,
                             stack_trees, stacked_adam_init, tree_gather,
                             tree_scatter, uniform_batch_shape, unstack_tree)
from repro.fl.record import RoundRecord, RunResult, evals_of

__all__ = ["Client", "make_local_step", "make_loss_fn", "run_local",
           "scaffold_correction", "CommModel", "run_flat_fl",
           "run_centralized", "FlatFLResult", "FlatTrainer",
           "shared_fraction", "CTX_AXES", "ENGINES", "make_round_engine",
           "make_train_one", "resolve_engine", "route_engine", "stack_trees",
           "stacked_adam_init", "tree_gather", "tree_scatter",
           "uniform_batch_shape", "unstack_tree", "RoundRecord", "RunResult",
           "evals_of"]
