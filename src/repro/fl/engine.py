"""Device-resident vectorized round engine, method-parameterized.

The sequential reference paths (fl/client.py:run_local driven by
core/hfl.py and fl/baselines.py) dispatch one jitted step per batch
with a host sync per loss and aggregate pytrees leaf-by-leaf in
Python.  This module compiles ONE program per round shape that does
all of it on device, for FedPhD's hierarchical loop AND the flat
baselines (FedAvg / FedProx / FedDiffuse / MOON / SCAFFOLD):

    clients  -> jax.vmap  over a stacked leading client axis
    batches  -> jax.lax.scan over a shape-static step axis
                (ClientData.stacked_epochs pads ragged clients; padded
                steps are masked no-ops)
    ctx      -> stacked per-client context pytree: FedProx/MOON anchor
                params, SCAFFOLD control variates, FedDiffuse local
                (non-communicated) parameter subtrees.  CTX_AXES maps
                each entry to a vmap axis (0 = per-client (C, ...)
                stack, None = broadcast to every lane).
    edge agg -> fused (E, C) weight-matrix einsum per leaf (the flat
                baselines are the E=1 special case)
    scaffold -> c_i+ update and control-delta mean fused on device

Per-round losses come back as a single (C,) device array — one host
sync per round instead of one per batch.  Numerical equivalence with
the sequential paths is preserved by closing over the SAME loss
(fl/client.py:make_loss_fn), folding the per-client RNG exactly as
run_local does (split once per step, carry the first key), and
masking padded steps out of both the params update and the loss mean;
tests/test_round_engine.py and tests/test_baseline_engines.py assert
it per method.

Per-client optimizer state can persist across rounds: pass stacked
Adam moments (``stacked_adam_init`` + ``tree_gather``/``tree_scatter``
keyed by the round's participation selection) and the engine threads
them through the scan and returns the updated stack.

The stacked client axis is also the parallelism axis: lay it over the
device mesh with repro.launch.federated.shard_clients and jit's
partitioner splits the vmapped program across devices.
"""
from __future__ import annotations

import warnings
from functools import lru_cache, partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig, ModelConfig
from repro.core.aggregation import combine_leaf
# the single $FEDPHD_* precedence code path; resolve_engine is re-exported
# here for back-compat (see repro.experiment.resolve for the contract)
from repro.experiment.resolve import ENGINES, resolve_engine
from repro.fl.client import make_loss_fn, scaffold_correction
from repro.fl.compress import ef_roundtrip_stacked
from repro.optim import AdamState, adam_init, adam_update

# vmap axes for each method's stacked ctx pytree: 0 = per-client
# leading (C, ...) axis, None = one copy broadcast to every lane.
CTX_AXES = {
    "fedphd": {},
    "fedavg": {},
    "fedprox": {"global_params": None},
    "feddiffuse": {"local_params": 0},
    "moon": {"global_params": None, "prev_params": 0},
    "scaffold": {"c_local": 0, "c_global": None, "scale": 0},
}


# ---------------------------------------------------------------------------
# Stacked-pytree utilities (the "ctx stacking" substrate).
# ---------------------------------------------------------------------------

def stack_trees(trees):
    """Stack a list of congruent pytrees onto a leading member axis."""
    return jax.tree.map(lambda *leaves: jnp.stack(leaves), *trees)


def unstack_tree(stacked, n: int):
    """Inverse of stack_trees: n per-member pytrees."""
    return [jax.tree.map(lambda leaf, _i=i: leaf[_i], stacked)
            for i in range(n)]


def tree_gather(stacked, idx):
    """Rows ``idx`` of every leaf's leading axis (scalar idx drops it).

    Polymorphic over the stack's store: numpy leaves (the host store —
    see ``resolve_store``) fancy-index on the host, so only the gathered
    participant rows ever move to device; jax leaves gather on device.
    """
    np_idx = np.asarray(idx)

    def take(leaf):
        if isinstance(leaf, np.ndarray):
            return leaf[np_idx]
        return leaf[jnp.asarray(np_idx)]
    return jax.tree.map(take, stacked)


def tree_scatter(stacked, idx, rows):
    """Write ``rows`` back into every leaf at ``idx`` on the leading axis.

    With ``idx`` a permutation-free index set (participation selections
    are drawn without replacement) this is the exact inverse of
    ``tree_gather``: rows outside ``idx`` are untouched and the result
    is invariant to permuting ``(idx, rows)`` in lockstep.

    Numpy leaves (host store) are updated IN PLACE — the whole point of
    the host store is never materializing a second (N, ...) copy — and
    the device rows sync D2H here; jax leaves use the functional
    ``.at[].set``.
    """
    np_idx = np.asarray(idx)

    def put(leaf, r):
        if isinstance(leaf, np.ndarray):
            leaf[np_idx] = np.asarray(r)
            return leaf
        return leaf.at[jnp.asarray(np_idx)].set(r)
    return jax.tree.map(put, stacked, rows)


STORES = ("auto", "device", "host")


def resolve_store(store: str, n_clients: int,
                  n_participants: Optional[int] = None) -> str:
    """Resolve a stacked-state store choice to "device" or "host".

    Persistent per-client state (Adam moments, SCAFFOLD variates, MOON
    prev models, FedDiffuse local subtrees) lives in stacks with a
    leading (N,) client axis.  On device that is fine while N is small,
    but a population run (10k clients at 1% participation) must not
    materialize N full model copies in device memory when each round
    only touches C of them — the host store keeps the stacks as numpy
    and ``tree_gather``/``tree_scatter`` move just the participating
    slice per round.

    "auto" picks host when the population is large AND mostly idle per
    round (N >= 8*C and N >= 256); explicit "device"/"host" always win.
    """
    if store not in STORES:
        raise ValueError(f"unknown state store {store!r}; expected one "
                         f"of {STORES}")
    if store != "auto":
        return store
    c = max(int(n_participants or n_clients), 1)
    return "host" if (n_clients >= 8 * c and n_clients >= 256) else "device"


def stacked_zeros(tree, n: int, *, dtype=None, host: bool = False):
    """A (n, ...) zero stack congruent with ``tree`` in the given store
    (host = numpy leaves; device = jnp).  ``dtype`` overrides the leaf
    dtypes (e.g. float32 control variates over bf16 params)."""
    if host:
        return jax.tree.map(
            lambda p: np.zeros((n,) + p.shape, dtype or p.dtype), tree)
    return jax.tree.map(
        lambda p: jnp.zeros((n,) + p.shape, dtype or p.dtype), tree)


def store_tree(tree, store: str):
    """Move a stacked-state pytree into ``store`` ("host" -> numpy
    leaves, anything else -> device).  Checkpoint restore uses this so
    a host-store trainer doesn't round-trip its (N, ...) stacks through
    device memory."""
    if tree is None:
        return None
    conv = np.asarray if store == "host" else jnp.asarray
    return jax.tree.map(conv, tree)


def stacked_adam_init(params, n: int, *, host: bool = False) -> AdamState:
    """Adam state for ``n`` persistent clients: every moment leaf gains
    a leading (n,) axis and the step counter becomes an (n,) vector.
    Gather rows with ``tree_gather`` for the round's participants and
    scatter the engine's updated rows back with ``tree_scatter``.
    ``host=True`` keeps the stack as numpy (see ``resolve_store``)."""
    xp = np if host else jnp
    zeros = lambda p: xp.zeros((n,) + p.shape, xp.float32)
    return AdamState(step=xp.zeros((n,), xp.int32),
                     mu=jax.tree.map(zeros, params),
                     nu=jax.tree.map(zeros, params),
                     master=None)


def adam_stack_from_tree(t, store: str = "device") -> Optional[AdamState]:
    """Checkpoint-loading counterpart of ``stacked_adam_init``: rebuild
    the stacked AdamState in ``store`` (checkpoint arrays arrive as
    numpy, so the host store is a zero-copy rewrap)."""
    if t is None:
        return None
    if store != "host":
        from repro.optim import adam_from_tree
        return adam_from_tree(t)
    if isinstance(t, AdamState):
        step, mu, nu, master = t.step, t.mu, t.nu, t.master
    else:
        step, mu, nu, *rest = tuple(t)
        master = rest[0] if rest else None
    to_np = lambda x: jax.tree.map(np.asarray, x)
    return AdamState(step=np.asarray(step), mu=to_np(mu), nu=to_np(nu),
                     master=None if master is None else to_np(master))


# ---------------------------------------------------------------------------
# The engine.
# ---------------------------------------------------------------------------

def make_train_one(loss_fn, *, method: str = "fedphd", lr: float = 2e-4,
                   unroll: int = 8):
    """One client's local round as a masked scan over stacked batches.

    ``train_one(params, opt_state, batches, valid, rng, ctx, masked)``
    -> ``(params, opt_state, mean_loss)``.  Used under vmap by
    ``make_round_engine`` and directly (with toy loss_fns) by the
    property tests that pin the padding-mask invariant.
    """
    def train_one(params, opt_state, batches, valid, rng, ctx, masked):
        def body(carry, xs):
            p, o, r = carry
            batch, v = xs
            r, sub = jax.random.split(r)
            loss, grads = jax.value_and_grad(loss_fn)(p, batch, sub, ctx)
            if method == "scaffold":
                grads = scaffold_correction(grads, ctx)
            new_p, new_o = adam_update(grads, o, p, lr=lr, grad_clip=1.0)
            if masked:
                # ragged clients: padded steps must be no-ops
                keep = lambda new, old: jnp.where(v, new, old)
                new_p = jax.tree.map(keep, new_p, p)
                new_o = jax.tree.map(keep, new_o, o)
                loss = jnp.where(v, loss, 0.0)
            return (new_p, new_o, r), loss
        # unroll: XLA:CPU runs conv/dot thunks inside a while-loop body
        # without the runtime thread pool; block-unrolling a few steps
        # amortizes that penalty at modest compile-time cost (full
        # unroll explodes compile time for long rounds)
        (params, opt_state, _), losses = jax.lax.scan(
            body, (params, opt_state, rng), (batches, valid),
            unroll=min(unroll, valid.shape[0]))
        n_valid = jnp.maximum(jnp.sum(valid), 1) if masked \
            else valid.shape[0]
        return params, opt_state, jnp.sum(losses) / n_valid

    return train_one


def make_round_engine(cfg: ModelConfig, fl: FLConfig, *,
                      method: str = "fedphd", sparse: bool = False,
                      groups=None, lr: float = 2e-4, unroll: int = 8,
                      prune_masks=None, mesh=None,
                      client_axis: str = "data", quant: str = "none"):
    """Build the jitted vectorized round program for ``method``.

    Plain (non-sparse) engines are memoized on the hashable
    ``(cfg, fl, method, lr, unroll, mesh, client_axis, quant)`` key: every
    trainer built with the same configs shares one engine function and
    therefore one XLA compile cache — constructing several trainers
    (equivalence tests, benches, sweeps) no longer recompiles the round
    program.

    ``mesh`` puts the stacked client axis on the device mesh: every
    client-leading input (batches, valid, rngs, edge_idx, the gathered
    Adam rows, per-client ctx entries) is laid over ``client_axis`` via
    ``repro.launch.federated.shard_clients`` before dispatch, so jit's
    partitioner runs each device's client slice locally and the fused
    (E, C) aggregation einsum lowers to a cross-device all-reduce.
    The engine's numerics stay atol-1e-5 equivalent to the unsharded
    program (reduction order inside the einsum may reassociate).

    ``cfg.backend`` selects the compute backend (repro.models.ops:
    xla | pallas | ref) for every tensor-core op the program traces —
    it is part of the frozen config, so it participates in both the
    memoization key and jit's own cache.  ``prune_masks`` (PruneGroup
    name -> 0/1 row) switches the forward to the masked sparse-phase
    path (block-masked GEMMs instead of pre-zeroed weights); masked
    engines are never memoized.

    ``quant`` (repro.fl.compress: "none" | "int8" | "fp8") enables the
    quantized-uplink path: the engine takes gathered per-client
    error-feedback rows via ``err=``, runs the delta quantize->
    dequantize round trip on device, aggregates the RECONSTRUCTED
    models ``start + deq`` (what the edge could decode from the wire),
    and returns the new residual rows as ``"err"``.  Late (staleness)
    deltas and SCAFFOLD control variates stay fp32 — quantization is
    the on-time reporting uplink only.

    Returns ``engine(edge_params, edge_idx, batches, valid, rngs, w_mat,
    ctx=None, opt_states=None, w_late=None, err=None, masked=True,
    per_client_opt=False)`` where

      edge_params: pytree, leaves (E, ...) — one model per edge server
                   (flat baselines: E = 1, the cloud model)
      edge_idx:    (C,) int32 — which edge each client starts from
      batches:     pytree, leaves (C, S, B, ...) — stacked_epochs output
      valid:       (C, S) bool — padded-step mask
      rngs:        (C, 2) uint32 — per-client fold of the round RNG
      w_mat:       (E, C) fp32 — normalized per-edge aggregation rows
      ctx:         method ctx pytree, stacked per CTX_AXES[method]
      opt_states:  stacked per-client Adam rows (with per_client_opt)
      w_late:      optional (E, C) fp32 — staleness-aggregation rows
                   over LATE clients' deltas (unnormalized shares)
      err:         stacked (C, ...) fp32 error-feedback rows (iff the
                   engine was built with ``quant != "none"``)

    and the result is a dict:

      "agg":    pytree of edge-aggregated models, leading (E,) axis
      "losses": (C,) per-client mean local loss
      "late":   per-edge weighted late-delta sums (iff w_late given)
      "opt":    updated stacked Adam rows        (iff per_client_opt)
      "err":    (C, ...) updated error-feedback rows (iff quantizing;
                the caller scatters back ONLY the on-time reporters)
      "trained": (C, ...) per-client trained params   (moon/feddiffuse,
                 which persist per-client state between rounds)
      "c_new", "dc_mean": SCAFFOLD c_i+ stack and mean control delta
    """
    if not sparse and groups is None and prune_masks is None:
        # jax meshes hash and compare by (devices, axis names), so the
        # memo key stays sound across trainers sharing one mesh object
        return _plain_round_engine(cfg, fl, method, lr, unroll, mesh,
                                   client_axis, quant)
    return _build_round_engine(cfg, fl, method=method, sparse=sparse,
                               groups=groups, lr=lr, unroll=unroll,
                               prune_masks=prune_masks, mesh=mesh,
                               client_axis=client_axis, quant=quant)


@lru_cache(maxsize=64)
def _plain_round_engine(cfg, fl, method, lr, unroll, mesh, client_axis,
                        quant):
    return _build_round_engine(cfg, fl, method=method, sparse=False,
                               groups=None, lr=lr, unroll=unroll,
                               mesh=mesh, client_axis=client_axis,
                               quant=quant)


def _make_sharded_engine(engine, mesh, client_axis: str, ctx_axes):
    """Wrap a jitted round engine so every client-leading operand is
    laid over ``client_axis`` before dispatch.  Inputs whose leading
    dim doesn't divide the axis (shard_clients warns once) and the
    small replicated operands (edge stack, (E, C) weight rows) pass
    through — jit partitions the program from the sharded operands."""
    from repro.launch.federated import shard_clients

    def sharded(edge_params, edge_idx, batches, valid, rngs, w_mat,
                ctx=None, opt_states=None, w_late=None, err=None,
                masked=True, per_client_opt=False):
        put = lambda t: shard_clients(t, mesh, client_axis)
        edge_idx, batches, valid, rngs = (
            put(t) for t in (edge_idx, batches, valid, rngs))
        if opt_states is not None:
            opt_states = put(opt_states)
        if err is not None:
            err = put(err)
        if ctx:
            ctx = {k: put(v) if ctx_axes.get(k) == 0 else v
                   for k, v in ctx.items()}
        return engine(edge_params, edge_idx, batches, valid, rngs, w_mat,
                      ctx=ctx, opt_states=opt_states, w_late=w_late,
                      err=err, masked=masked, per_client_opt=per_client_opt)
    return sharded


def _build_round_engine(cfg: ModelConfig, fl: FLConfig, *, method: str,
                        sparse: bool, groups, lr: float, unroll: int,
                        prune_masks=None, mesh=None,
                        client_axis: str = "data", quant: str = "none"):
    loss_fn = make_loss_fn(cfg, fl, method=method, sparse=sparse,
                           groups=groups, prune_masks=prune_masks)
    train_one = make_train_one(loss_fn, method=method, lr=lr, unroll=unroll)
    ctx_axes = CTX_AXES[method]
    return_trained = method in ("moon", "feddiffuse")

    # Donation (ROADMAP leftover from PR 1): the (E, ...) edge-model
    # stack and the gathered persistent-Adam rows are freshly
    # materialized by the callers every round, never reused after the
    # call, and alias the "agg" / "opt" outputs shape-for-shape — so
    # XLA writes the round's results in place instead of holding both
    # copies live.  (The stacked_epochs batch buffer has no matching
    # output to alias, so donating it would be a no-op plus a warning.)
    @partial(jax.jit, static_argnames=("masked", "per_client_opt"),
             donate_argnums=(0,), donate_argnames=("opt_states", "err"))
    def engine(edge_params, edge_idx, batches, valid, rngs, w_mat,
               ctx=None, opt_states=None, w_late=None, err=None,
               masked: bool = True, per_client_opt: bool = False):
        ctx = {} if ctx is None else ctx
        start = jax.tree.map(lambda leaf: leaf[edge_idx], edge_params)
        if method == "feddiffuse":
            # per-client local (never-communicated) subtrees override
            # the gathered start rows; the loss itself is plain FedAvg
            start = {**start, **ctx["local_params"]}
        if per_client_opt:
            opt0, opt_axes = opt_states, 0
        else:
            # one zero-tree, shared across all vmapped clients
            opt0 = adam_init(jax.tree.map(lambda leaf: leaf[0], edge_params))
            opt_axes = None
        trained, opt_out, losses = jax.vmap(
            lambda p, o, b, v, r, c: train_one(p, o, b, v, r, c, masked),
            in_axes=(0, opt_axes, 0, 0, 0, ctx_axes))(
                start, opt0, batches, valid, rngs, ctx)
        if quant != "none" and err is not None:
            # quantized uplink: the edge can only decode start + deq
            # from the wire, so THAT is what aggregates; the residual
            # rows go back to the caller for the next round's feedback
            up = jax.tree.map(lambda t, s: t.astype(jnp.float32)
                              - s.astype(jnp.float32), trained, start)
            deq, new_err = ef_roundtrip_stacked(up, err, quant)
            recon = jax.tree.map(lambda s, d: s.astype(jnp.float32) + d,
                                 start, deq)
            agg_src, err_out = recon, new_err
        else:
            agg_src, err_out = trained, None
        out = {"agg": jax.tree.map(lambda leaf: combine_leaf(leaf, w_mat),
                                   agg_src),
               "losses": losses}
        if err_out is not None:
            out["err"] = err_out
        if w_late is not None:
            # staleness aggregation: fused (E, C) einsum over the late
            # clients' deltas (their w_mat entries are zero, so they are
            # excluded from "agg"; the buffered delta sum merges into
            # the NEXT aggregate as base + gamma * late)
            delta = jax.tree.map(lambda t, s: t.astype(jnp.float32)
                                 - s.astype(jnp.float32), trained, start)
            out["late"] = jax.tree.map(lambda d: combine_leaf(d, w_late),
                                       delta)
        if per_client_opt:
            out["opt"] = opt_out
        if return_trained:
            out["trained"] = trained
        if method == "scaffold":
            # c_i+ = c_i - c + (x - y_i) / (K_i * lr), fused over the
            # stack; ctx["scale"] carries per-client 1 / (K_i * lr)
            def ci_new(ci, c, x, y):
                s = ctx["scale"].reshape((-1,) + (1,) * (x.ndim - 1))
                return ci - c + s * (x.astype(jnp.float32)
                                     - y.astype(jnp.float32))
            c_new = jax.tree.map(ci_new, ctx["c_local"], ctx["c_global"],
                                 start, trained)
            delta = jax.tree.map(lambda a, b: a - b, c_new, ctx["c_local"])
            uni = jnp.full((valid.shape[0],), 1.0 / valid.shape[0],
                           jnp.float32)
            out["c_new"] = c_new
            out["dc_mean"] = jax.tree.map(lambda d: combine_leaf(d, uni),
                                          delta)
        return out

    if mesh is not None:
        return _make_sharded_engine(engine, mesh, client_axis, ctx_axes)
    return engine


def uniform_batch_shape(clients) -> Optional[tuple]:
    """Common (B, H, W, C) batch shape across clients, or None if ragged.

    The vectorized engine needs a shape-static client axis; clients whose
    batch size differs (len(data) < batch_size somewhere) fall back to
    the sequential path.
    """
    shapes = {(c.data.batch_size,) + c.data.images.shape[1:]
              for c in clients}
    return shapes.pop() if len(shapes) == 1 else None


def route_engine(engine: str, strict: bool, round_clients, warned: bool,
                 trainer: str, method: str = "") -> Tuple[bool, bool]:
    """Shared auto/strict engine routing for one round.

    Returns ``(use_vectorized, warned)``.  Ragged clients fall back to
    the sequential path; a strict (explicitly requested) "vectorized"
    raises instead, and the fallback warns exactly once per trainer —
    FedPhD and FlatTrainer must not diverge on this contract.

    The warning text embeds ``(method, engine)``: Python's warnings
    registry dedupes on the message, so without them a second trainer
    hitting the same fallback in one process (e.g. two different flat
    baselines) would be silently suppressed even though its own
    ``warned`` flag was fresh.
    """
    if engine == "sequential":
        return False, warned
    uniform = uniform_batch_shape(round_clients) is not None
    if not uniform:
        if engine == "vectorized" and strict:
            raise ValueError("vectorized engine needs a uniform client "
                             "batch shape; use engine='auto' or "
                             "'sequential' for ragged clients")
        if not warned:
            warnings.warn(f"ragged client batch shapes: {trainer} "
                          f"(method={method or trainer}, engine={engine}) "
                          "falling back to the sequential round engine",
                          RuntimeWarning)
            warned = True
    return uniform, warned
