"""Device-resident vectorized round engine.

The sequential reference path (fl/client.py:run_local) dispatches one
jitted step per batch with a host sync per loss and aggregates pytrees
leaf-by-leaf in Python.  This module compiles ONE program per round
shape that does all of it on device:

    clients  -> jax.vmap  over a stacked leading client axis
    batches  -> jax.lax.scan over a shape-static step axis
                (ClientData.stacked_epochs pads ragged clients; padded
                steps are masked no-ops)
    edge agg -> fused (E, C) weight-matrix einsum per leaf

Per-round losses come back as a single (C,) device array — one host
sync per round instead of one per batch.  Numerical equivalence with
the sequential path is preserved by folding the per-client RNG exactly
as run_local does (split once per step, carry the first key) and by
masking padded steps out of both the params update and the loss mean;
tests/test_round_engine.py asserts it.

The stacked client axis is also the parallelism axis: lay it over the
device mesh with repro.launch.federated.shard_clients and jit's
partitioner splits the vmapped program across devices.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig, ModelConfig
from repro.core.aggregation import combine_leaf
from repro.core.pruning import depth_lambdas, omega
from repro.models import model
from repro.optim import adam_init, adam_update


def make_round_engine(cfg: ModelConfig, fl: FLConfig, *, sparse: bool = False,
                      groups=None, lr: float = 2e-4, unroll: int = 8):
    """Build the jitted vectorized round program.

    Returns ``engine(edge_params, edge_idx, batches, valid, rngs, w_mat)
    -> (agg_stack, losses)`` where

      edge_params: pytree, leaves (E, ...) — one model per edge server
      edge_idx:    (C,) int32 — which edge each client starts from
      batches:     pytree, leaves (C, S, B, ...) — stacked_epochs output
      valid:       (C, S) bool — padded-step mask
      rngs:        (C, 2) uint32 — per-client fold of the round RNG
      w_mat:       (E, C) fp32 — normalized per-edge aggregation rows

    and ``agg_stack`` is the pytree of edge-aggregated models with a
    leading (E,) axis, ``losses`` the (C,) per-client mean local loss.
    """
    lambdas = depth_lambdas(groups, fl.lambda0) if (sparse and groups) else None

    def loss_fn(params, batch, rng):
        loss = model.loss_fn(params, cfg, batch, rng)
        if sparse and groups:
            loss = loss + omega(params, groups, lambdas)
        return loss

    def train_one(params, opt_state, batches, valid, rng, masked):
        def body(carry, xs):
            p, o, r = carry
            batch, v = xs
            r, sub = jax.random.split(r)
            loss, grads = jax.value_and_grad(loss_fn)(p, batch, sub)
            new_p, new_o = adam_update(grads, o, p, lr=lr, grad_clip=1.0)
            if masked:
                # ragged clients: padded steps must be no-ops
                keep = lambda new, old: jnp.where(v, new, old)
                new_p = jax.tree.map(keep, new_p, p)
                new_o = jax.tree.map(keep, new_o, o)
                loss = jnp.where(v, loss, 0.0)
            return (new_p, new_o, r), loss
        # unroll: XLA:CPU runs conv/dot thunks inside a while-loop body
        # without the runtime thread pool; block-unrolling a few steps
        # amortizes that penalty at modest compile-time cost (full
        # unroll explodes compile time for long rounds)
        (params, _, _), losses = jax.lax.scan(
            body, (params, opt_state, rng), (batches, valid),
            unroll=min(unroll, valid.shape[0]))
        n_valid = jnp.maximum(jnp.sum(valid), 1) if masked \
            else valid.shape[0]
        return params, jnp.sum(losses) / n_valid

    @partial(jax.jit, static_argnames=("masked",))
    def engine(edge_params, edge_idx, batches, valid, rngs, w_mat,
               masked: bool = True):
        start = jax.tree.map(lambda leaf: leaf[edge_idx], edge_params)
        # one zero-tree, shared across all vmapped clients (in_axes=None)
        opt_zero = adam_init(jax.tree.map(lambda leaf: leaf[0], edge_params))
        trained, losses = jax.vmap(
            lambda p, o, b, v, r: train_one(p, o, b, v, r, masked),
            in_axes=(0, None, 0, 0, 0))(
                start, opt_zero, batches, valid, rngs)
        agg = jax.tree.map(lambda leaf: combine_leaf(leaf, w_mat), trained)
        return agg, losses

    return engine


def stack_clients(per_client_batches, per_client_valid):
    """Host-side stack of stacked_epochs outputs onto a client axis."""
    keys = per_client_batches[0].keys()
    batches = {k: jnp.asarray(np.stack([b[k] for b in per_client_batches]))
               for k in keys}
    valid = jnp.asarray(np.stack(per_client_valid))
    return batches, valid


def uniform_batch_shape(clients) -> Optional[tuple]:
    """Common (B, H, W, C) batch shape across clients, or None if ragged.

    The vectorized engine needs a shape-static client axis; clients whose
    batch size differs (len(data) < batch_size somewhere) fall back to
    the sequential path.
    """
    shapes = {(c.data.batch_size,) + c.data.images.shape[1:]
              for c in clients}
    return shapes.pop() if len(shapes) == 1 else None
