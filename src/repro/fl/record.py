"""The ONE round-history schema shared by every trainer.

Before the experiment-API unification, FedPhD kept a dataclass history
while the flat baselines appended raw dicts (``h["comm_gb"]`` vs
``h.comm_gb``) and eval results lived in two different places.  Every
trainer now appends :class:`RoundRecord` to ``trainer.history``; the
record supports both attribute and ``rec["key"]`` access so pre-existing
callers of either style keep working.

``eval`` carries the unified eval-hook result: trainers call
``eval_fn(params, cfg, round)`` at their ``eval_every`` cadence and
store the return value here (it must be JSON-serializable for
checkpointed histories).
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, NamedTuple, Optional, Tuple


@dataclasses.dataclass
class RoundRecord:
    """One communication round, identical for flat and hierarchical runs.

    ``edge_sh`` is only populated by hierarchical trainers (per-edge SH
    scores); ``pruned`` marks the round whose cloud aggregation ran the
    structured-pruning compaction.
    """
    round: int
    loss: float
    comm_gb: float
    # bytes-on-wire split (comm_gb = comm_up_gb + comm_down_gb): the
    # uplink is what comm.quant compresses, so it is reported on its
    # own.  None on histories recorded before the split existed.
    comm_up_gb: Optional[float] = None
    comm_down_gb: Optional[float] = None
    params_m: float = 0.0
    selected: List[int] = dataclasses.field(default_factory=list)
    eval: Any = None
    edge_sh: Optional[List[float]] = None
    pruned: bool = False
    # realized per-round availability under an active FaultSpec (None
    # when faults are disabled): {"online": int, "arrived"/"dropped"/
    # "late": [cids], "budgets": [steps per selected client]} — see
    # repro.fl.faults.RoundFaults.availability
    availability: Optional[dict] = None

    # -- dict-style compatibility (legacy flat histories were dicts) --------
    def __getitem__(self, key: str):
        if key not in self.__dataclass_fields__:
            raise KeyError(key)
        return getattr(self, key)

    def get(self, key: str, default=None):
        return getattr(self, key, default)

    def keys(self):
        return self.__dataclass_fields__.keys()

    # -- (de)serialization ---------------------------------------------------
    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "RoundRecord":
        known = {k: v for k, v in d.items() if k in cls.__dataclass_fields__}
        return cls(**known)


class RunResult(NamedTuple):
    """Return value of ``Trainer.run``: unpacks as the legacy
    ``history, evals = trainer.run(...)`` tuple, where ``evals`` is the
    ``[(round, eval)]`` view of the records that carry an eval result.

    ``RoundRecord.eval is None`` means "no result recorded", so an
    eval_fn that returns None leaves no trace here (a deliberate
    narrowing of the legacy contract, which appended every hook call);
    side-effect-only hooks should return a marker value."""
    history: List[RoundRecord]
    evals: List[Tuple[int, Any]]


def evals_of(history: List[RoundRecord]) -> List[Tuple[int, Any]]:
    return [(r.round, r.eval) for r in history if r.eval is not None]
