"""Seeded client-availability and fault-injection model.

Real federated deployments — the setting FedPhD targets — are dominated
by unreliable clients: devices that never show up for a round, crash
mid-round, compute at half speed, or leave the population entirely.
This module is the single source of truth for that behaviour:

  :class:`FaultSpec`   — the declarative, JSON-round-trippable knob set
                         (lives on ``ExperimentSpec.fault``, so sweeps
                         can grid over ``fault.dropout`` etc.);
  :class:`FaultModel`  — the seeded realization: one dedicated numpy
                         Generator (independent of the selection RNG)
                         draws each round's arrivals / dropouts /
                         straggler budgets / churn flips;
  :class:`RoundFaults` — one round's realized schedule, queried by both
                         the sequential and the vectorized engine.

The realization is engine-agnostic BY CONSTRUCTION: every round draws a
fixed number of variates (one churn vector, three uniform vectors over
the selection) regardless of which faults are active, so the stream —
and therefore the schedule — is bitwise identical across engines,
across kill-and-resume (the Generator state checkpoints), and across
aggregation modes.

Faults act on the round engine as *data*, never as shape: a client's
step budget truncates the existing shape-static ``valid`` masks of
``fl/engine.py`` (vectorized) or caps ``run_local`` (sequential), so no
fault pattern ever recompiles the round program.

Staleness (``aggregation="staleness"``): a straggler that cannot finish
by the deadline keeps training to completion and reports one round
LATE.  Its weighted delta sum is buffered and merged into the *next*
aggregate as ``base + gamma * sum_j w_j * (theta_j - start)`` with
``w_j = n_j / sum(all participating n)`` — FedAsync-style decay, so
with zero stragglers the mode is exactly FedAvg.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import weighted_average_stacked


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Declarative fault model (all probabilities per round).

    arrival:        P(a selected client shows up at all).
    dropout:        P(an arrived client crashes mid-round).  A dropped
                    client completes a uniform prefix of its step budget
                    and never uploads (zero uplink).
    straggler_frac: fraction of the population running slow.
    slowdown:       slow clients' compute-time multiplier (>= 1).
    deadline:       round deadline in units of the nominal local-round
                    time; a client finishes ``floor(steps * deadline /
                    speed)`` steps by it.  1.0 = exactly the nominal
                    budget for full-speed clients.
    churn:          P(a client's membership flips between rounds) —
                    population churn; offline clients are not selectable.
    staleness:      gamma in [0, 1] weighting late deltas at the merge
                    round (only read under ``aggregation="staleness"``).
    seed:           fault-stream seed, combined with the experiment seed
                    so ``fault.seed`` is an independent sweep axis.
    """
    arrival: float = 1.0
    dropout: float = 0.0
    straggler_frac: float = 0.0
    slowdown: float = 2.0
    deadline: float = 1.0
    churn: float = 0.0
    staleness: float = 0.5
    seed: int = 0

    def __post_init__(self):
        for name in ("arrival", "dropout", "straggler_frac", "churn",
                     "staleness"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"fault.{name}={v} not in [0, 1]")
        if self.slowdown < 1.0:
            raise ValueError(f"fault.slowdown={self.slowdown} < 1")
        if not 0.0 < self.deadline <= 1.0:
            raise ValueError(f"fault.deadline={self.deadline} not in (0, 1]")

    @property
    def enabled(self) -> bool:
        """True iff any fault can actually fire.  Trainers treat a
        disabled spec exactly as ``fault=None`` — bitwise-identical to
        the fault-free code path."""
        return (self.arrival < 1.0 or self.dropout > 0.0
                or self.churn > 0.0 or self.deadline < 1.0
                or (self.straggler_frac > 0.0 and self.slowdown > 1.0))

    def replace(self, **kw) -> "FaultSpec":
        return dataclasses.replace(self, **kw)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "FaultSpec":
        known = {k: v for k, v in d.items()
                 if k in {f.name for f in dataclasses.fields(cls)}}
        return cls(**known)


@dataclasses.dataclass
class RoundFaults:
    """One round's realized schedule over the selected clients.

    All arrays are aligned with ``sel_ids`` (selection order).
    ``budget`` is the number of local steps each client executes;
    ``reporting`` marks clients whose model enters this round's
    aggregation; ``completed`` additionally includes late clients
    (arrived, finished, reporting next round) — client-local state
    (persistent Adam, MOON/FedDiffuse/SCAFFOLD buffers) updates for
    ``completed`` clients only.
    """
    sel_ids: np.ndarray
    arrived: np.ndarray
    dropped: np.ndarray
    late: np.ndarray
    budget: np.ndarray
    n_online: int

    def __post_init__(self):
        self._pos: Dict[int, int] = {int(c): i
                                     for i, c in enumerate(self.sel_ids)}

    @property
    def completed(self) -> np.ndarray:
        return self.arrived & ~self.dropped

    @property
    def reporting(self) -> np.ndarray:
        return self.completed & ~self.late

    # -- per-client queries (the sequential path iterates clients) ----------
    def arrived_of(self, cid: int) -> bool:
        return bool(self.arrived[self._pos[int(cid)]])

    def completed_of(self, cid: int) -> bool:
        return bool(self.completed[self._pos[int(cid)]])

    def reporting_of(self, cid: int) -> bool:
        return bool(self.reporting[self._pos[int(cid)]])

    def late_of(self, cid: int) -> bool:
        return bool(self.late[self._pos[int(cid)]])

    def budget_of(self, cid: int) -> int:
        return int(self.budget[self._pos[int(cid)]])

    def availability(self) -> dict:
        """The JSON record stored in ``RoundRecord.availability`` — the
        cross-engine bitwise determinism artifact."""
        ids = self.sel_ids
        return {
            "online": int(self.n_online),
            "arrived": [int(c) for c in ids[self.arrived]],
            "dropped": [int(c) for c in ids[self.dropped]],
            "late": [int(c) for c in ids[self.late]],
            "budgets": [int(b) for b in self.budget],
        }

    def summary(self) -> dict:
        """Compact counts for the obs ``fault/draw`` trace event (the
        full per-client record stays in ``availability()``)."""
        return {
            "online": int(self.n_online),
            "selected": int(len(self.sel_ids)),
            "arrived": int(self.arrived.sum()),
            "completed": int(self.completed.sum()),
            "dropped": int(self.dropped.sum()),
            "late": int(self.late.sum()),
        }


class FaultModel:
    """The seeded realization of a :class:`FaultSpec` over one client
    population.  Owns a dedicated RNG stream (independent of the
    selection ``np_rng``) whose state checkpoints with the trainer.
    """

    def __init__(self, spec: FaultSpec, num_clients: int, base_seed: int):
        self.spec = spec
        self.num_clients = num_clients
        self.rng = np.random.default_rng([base_seed, spec.seed])
        # compute-speed heterogeneity is a population property, drawn
        # once: straggler_frac of the clients run `slowdown` x slower
        n_slow = int(round(spec.straggler_frac * num_clients))
        perm = self.rng.permutation(num_clients)
        self.speed = np.ones(num_clients, np.float64)
        self.speed[perm[:n_slow]] = spec.slowdown
        self.online = np.ones(num_clients, bool)

    # -- per-round draws (FIXED count: engine/mode-independent stream) ------
    def begin_round(self) -> np.ndarray:
        """Advance population churn; returns the online mask the round's
        selection draws from.  Always consumes one (N,) uniform vector
        so the stream is identical for churn = 0."""
        flips = self.rng.random(self.num_clients) < self.spec.churn
        self.online ^= flips
        if not self.online.any():
            # an empty population would deadlock the round; force one
            # client back online (deterministic given the stream)
            self.online[int(self.rng.integers(self.num_clients))] = True
        return self.online.copy()

    def draw_round(self, sel_ids: np.ndarray, steps: Sequence[int],
                   staleness_mode: bool) -> RoundFaults:
        """Realize one round's schedule over the selected clients.

        Consumes exactly three (C,) uniform vectors regardless of which
        faults are active.  ``steps`` is each client's nominal step
        count (local_epochs * steps_per_epoch); ``staleness_mode``
        routes deadline-missing clients to a LATE full run instead of
        truncation.
        """
        sel_ids = np.asarray(sel_ids)
        steps = np.asarray(steps, np.int64)
        u_arrive = self.rng.random(len(sel_ids))
        u_drop = self.rng.random(len(sel_ids))
        u_prefix = self.rng.random(len(sel_ids))
        spec = self.spec

        arrived = u_arrive < spec.arrival
        dropped = arrived & (u_drop < spec.dropout)
        # deadline -> per-client step budget: a `speed`x slower client
        # finishes steps * deadline / speed of its nominal steps in time
        cap = np.minimum(steps, np.floor(
            steps * spec.deadline / self.speed[sel_ids]).astype(np.int64))
        late = (arrived & ~dropped & (cap < steps)) if staleness_mode \
            else np.zeros(len(sel_ids), bool)
        budget = np.where(late, steps, cap)
        # a dropped client crashes at a uniform prefix of its budget
        budget = np.where(dropped,
                          np.floor(u_prefix * cap).astype(np.int64), budget)
        budget = np.where(arrived, budget, 0)
        return RoundFaults(sel_ids=sel_ids, arrived=arrived, dropped=dropped,
                           late=late, budget=budget,
                           n_online=int(self.online.sum()))

    # -- checkpoint support --------------------------------------------------
    def state(self) -> dict:
        """JSON-serializable state (speed re-derives at construction —
        the init-time permutation draw is part of the seeded stream)."""
        return {"rng": self.rng.bit_generator.state,
                "online": [bool(b) for b in self.online]}

    def set_state(self, st: dict) -> None:
        self.rng.bit_generator.state = st["rng"]
        self.online = np.asarray(st["online"], bool).copy()


# ---------------------------------------------------------------------------
# Staleness-aggregation helpers (shared by both engines and topologies).
# ---------------------------------------------------------------------------

def apply_late(base, delta, gamma: float):
    """Merge a buffered late-delta sum: ``base + gamma * delta`` in fp32,
    cast back to the base dtypes."""
    return jax.tree.map(
        lambda b, d: (b.astype(jnp.float32)
                      + gamma * d.astype(jnp.float32)).astype(b.dtype),
        base, delta)


def late_delta(models: List, base, weights: Sequence[float]):
    """Weighted late-delta sum ``sum_j w_j * (theta_j - base)`` (fp32;
    weights are used AS GIVEN — they are the late clients' share of the
    round's total sample mass, deliberately not renormalized to 1).

    The sequential reference for the engine's fused ``w_late`` einsum.
    """
    deltas = [jax.tree.map(lambda a, b: a.astype(jnp.float32)
                           - b.astype(jnp.float32), m, base)
              for m in models]
    stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *deltas)
    return weighted_average_stacked(stacked, np.asarray(weights, np.float32))


def make_fault_model(fault: Optional[FaultSpec], num_clients: int,
                     base_seed: int) -> Optional[FaultModel]:
    """The one trainer-side gate: a missing or disabled spec yields no
    model, and every fault code path collapses to today's exactly."""
    if fault is None or not fault.enabled:
        return None
    return FaultModel(fault, num_clients, base_seed)
