"""Communication-cost model (paper §V-C, following ShapeFL [20]).

C_ne = 0.002 * d_e * V   (client <-> edge)
C_ce = 0.02  * d_c * V   (edge   <-> cloud),  d_c = 10 * d_e.

V is transmitted volume.  The paper reports "standardized communication
volume" per central-aggregation period; CommModel accumulates raw bytes
and exposes the same standardized cost.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class CommModel:
    d_e: float = 1.0
    d_c: float = 10.0
    k_edge: float = 0.002
    k_cloud: float = 0.02

    def client_edge(self, volume_bytes: float) -> float:
        return self.k_edge * self.d_e * volume_bytes

    def edge_cloud(self, volume_bytes: float) -> float:
        return self.k_cloud * self.d_c * volume_bytes

    def flat_fl_round(self, volume_bytes: float, num_clients: int) -> float:
        """FedAvg-style round: C clients upload + download to the cloud."""
        return 2 * num_clients * self.edge_cloud(volume_bytes)

    def hfl_round(self, volume_bytes: float, num_clients: int,
                  num_edges: int, cloud_round: bool) -> float:
        c = 2 * num_clients * self.client_edge(volume_bytes)
        if cloud_round:
            c += 2 * num_edges * self.edge_cloud(volume_bytes)
        return c
