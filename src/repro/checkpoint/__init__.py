from repro.checkpoint.ckpt import save, load

__all__ = ["save", "load"]
