"""Checkpointing: pytree <-> flat npz with structure manifest.

Handles model params, optimizer state, EMA, and FL orchestrator state
(edge distributions, round counter).  No external deps (orbax absent).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import numpy as np


def _flatten(tree, prefix: str = "") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}/{k}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}/[{i}]"))
    elif tree is None:
        out[prefix + "/__none__"] = np.zeros((0,))
    else:
        out[prefix] = np.asarray(tree)
    return out


def _structure(tree) -> Any:
    if isinstance(tree, dict):
        return {k: _structure(v) for k, v in tree.items()}
    if isinstance(tree, tuple):
        return {"__tuple__": [_structure(v) for v in tree]}
    if isinstance(tree, list):
        return {"__list__": [_structure(v) for v in tree]}
    if tree is None:
        return "__none__"
    return "__leaf__"


def save(path: str, tree, metadata: Dict[str, Any] | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez_compressed(path, **{k: v for k, v in flat.items()})
    manifest = {"structure": _structure(tree), "metadata": metadata or {}}
    with open(path + ".manifest.json", "w") as f:
        json.dump(manifest, f)


def _rebuild(struct, flat: Dict[str, np.ndarray], prefix: str = ""):
    if struct == "__leaf__":
        return flat[prefix]
    if struct == "__none__":
        return None
    if isinstance(struct, dict):
        if "__tuple__" in struct:
            return tuple(_rebuild(s, flat, f"{prefix}/[{i}]")
                         for i, s in enumerate(struct["__tuple__"]))
        if "__list__" in struct:
            return [_rebuild(s, flat, f"{prefix}/[{i}]")
                    for i, s in enumerate(struct["__list__"])]
        return {k: _rebuild(v, flat, f"{prefix}/{k}")
                for k, v in struct.items()}
    raise ValueError(f"bad manifest node {struct!r}")


def load(path: str) -> Tuple[Any, Dict[str, Any]]:
    with open(path + ".manifest.json") as f:
        manifest = json.load(f)
    if not path.endswith(".npz"):
        path = path + ".npz" if os.path.exists(path + ".npz") else path
    data = dict(np.load(path, allow_pickle=False))
    tree = _rebuild(manifest["structure"], data)
    return tree, manifest["metadata"]
