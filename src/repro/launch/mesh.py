"""Production mesh construction.

A FUNCTION (not module-level constant) so importing never touches jax
device state.  The dry-run entrypoint sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 BEFORE any jax
import; smoke tests and benchmarks see the real single CPU device.

TPU-topology mapping (DESIGN.md §3.3): the "pod" axis is the DCN tier
(FedPhD's cloud aggregation), "data" x "model" the ICI tiers within a
16x16 v5e pod (edge aggregation / tensor sharding).
"""
from __future__ import annotations

import math
from typing import Mapping, Optional

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Mesh over whatever devices exist (CPU smoke: 1 device).

    ``model_axis`` must divide the device count exactly: integer
    division would silently build a mesh over fewer devices than the
    host has, and every collective after that would be wrong about who
    its peers are.
    """
    n = len(jax.devices())
    if model_axis < 1 or n % model_axis != 0:
        raise ValueError(
            f"model_axis={model_axis} does not divide the {n} available "
            f"device(s); an uneven split would silently drop "
            f"{n % model_axis if model_axis >= 1 else n} of them")
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


def make_spec_mesh(axes: Optional[Mapping[str, int]]):
    """Build a mesh from ``ExperimentSpec.mesh`` ({axis name -> size}).

    The JSON-round-trippable spec form of a mesh: insertion order is the
    axis order, size-1 axes are kept (named but trivial, so specs like
    ``{"data": 8, "model": 1}`` document the intended layout).  Uses the
    first prod(sizes) devices — an explicit error, not silent truncation,
    when the host has fewer.  None/empty means "no mesh" (the unsharded
    single-device path).
    """
    if not axes:
        return None
    names = tuple(axes)
    sizes = tuple(int(axes[k]) for k in names)
    if any(s < 1 for s in sizes):
        raise ValueError(f"spec.mesh sizes must be >= 1: {dict(axes)}")
    need = math.prod(sizes)
    devs = jax.devices()
    if need > len(devs):
        raise ValueError(
            f"spec.mesh {dict(axes)} needs {need} device(s) but only "
            f"{len(devs)} are available (hint: repro.launch.env.apply("
            f"devices={need}) before the first jax import)")
    return jax.sharding.Mesh(
        np.array(devs[:need]).reshape(sizes), names)


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
