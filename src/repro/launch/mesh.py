"""Production mesh construction.

A FUNCTION (not module-level constant) so importing never touches jax
device state.  The dry-run entrypoint sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 BEFORE any jax
import; smoke tests and benchmarks see the real single CPU device.

TPU-topology mapping (DESIGN.md §3.3): the "pod" axis is the DCN tier
(FedPhD's cloud aggregation), "data" x "model" the ICI tiers within a
16x16 v5e pod (edge aggregation / tensor sharding).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Mesh over whatever devices exist (CPU smoke: 1 device)."""
    n = len(jax.devices())
    data = n // model_axis
    return jax.make_mesh((data, model_axis), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
