"""Sharding rules: Megatron-style tensor parallelism + ZeRO-1 optimizer.

Layout (DESIGN.md §6, revised after the §Perf FSDP experiment — see
EXPERIMENTS.md "hypothesis: FSDP contraction sharding"):

  * activations:  batch over ("pod","data") — enforced by explicit
                  constraints in the model code (act_batch_axes);
  * weights:      bf16, column-parallel (output dim over "model") for
                  up-projections, row-parallel (contracting dim over
                  "model") for down-projections -> the canonical Megatron
                  all-reduce of (B,S,d) activations, twice per layer;
  * experts:      expert dim over the widest divisible axis tuple
                  (("model","data") puts one DeepSeek expert per chip on a
                  16x16 pod); per-expert hidden dim additionally over
                  "data" when free (qwen3);
  * optimizer:    fp32 master + moments, sharded like the weights PLUS
                  "data"/"pod" on the largest free dim (ZeRO-1: XLA
                  reduce-scatters grads into the update and all-gathers
                  fresh bf16 params once per step).

Every rule is divisibility-guarded so the same rules serve the 2B dense
model, the 671B MoE, and 1-device smoke meshes.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig, ShardingRules


def _axes_prod(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = 1
    for a in axes:
        out *= sizes.get(a, 1)
    return out


def _present(mesh: Mesh, axes) -> Tuple[str, ...]:
    if axes is None:
        return ()
    if isinstance(axes, str):
        axes = (axes,)
    return tuple(a for a in axes if a in mesh.axis_names)


def _fit(mesh: Mesh, dim: int, axes) -> Any:
    """Largest prefix of ``axes`` whose product divides ``dim``."""
    axes = _present(mesh, axes)
    while axes and (dim % _axes_prod(mesh, axes) != 0
                    or _axes_prod(mesh, axes) > dim):
        axes = axes[:-1]
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def _first_fit(mesh: Mesh, dim: int, candidates) -> Any:
    """First candidate axis-tuple that divides ``dim`` exactly."""
    for cand in candidates:
        cand = _present(mesh, cand)
        if cand and dim % _axes_prod(mesh, cand) == 0:
            return cand if len(cand) > 1 else cand[0]
    return None


def _spec_axes(entry) -> Tuple[str, ...]:
    if entry is None:
        return ()
    if isinstance(entry, str):
        return (entry,)
    return tuple(entry)


# ---------------------------------------------------------------------------
# Parameter rules (Megatron TP)
# ---------------------------------------------------------------------------
_COL_PARALLEL = {"wq", "wk", "wv", "w_in", "w_gate", "w_r", "w_k", "w_v",
                 "w_g", "w_x", "w_y", "wq_b", "wkv_b", "decay_b", "w_a",
                 "w_i"}
_ROW_PARALLEL = {"wo", "w_out", "w_o"}
_REPLICATED_2D = {"wq_a", "wkv_a", "decay_a", "router"}
_MODEL_1D = {"log_lambda", "conv_b", "b_a", "b_i", "w0", "ln_scale", "b_in",
             "bq", "bk", "bv"}


def _param_spec(path: str, shape: Tuple[int, ...], mesh: Mesh,
                rules: ShardingRules) -> P:
    model = rules.heads
    d = len(shape)
    leaf = path.rsplit("/", 1)[-1]
    stacked = path.startswith(("cycles/", "encoder/"))
    off = 1 if (stacked and d > 0) else 0   # leading n_cycles axis unsharded

    def spec(*entries):
        full = [None] * d
        for i, ax in enumerate(entries):
            full[off + i] = ax
        return P(*full)

    def fit(i, axes):
        return _fit(mesh, shape[off + i], axes)

    if leaf == "embed":
        return P(_fit(mesh, shape[0], rules.vocab), None)
    if leaf == "lm_head":
        return P(None, _fit(mesh, shape[1], rules.vocab))

    # --- MoE experts ----------------------------------------------------------
    if "/moe/" in path and leaf in ("w_gate", "w_in", "w_out") and d - off == 3:
        E = shape[off]
        # ("data","model") ordering: the flat-token sharding used by the
        # EP shard_map is then a refinement of the batch sharding (no
        # device-order transpose at the boundary — see EXPERIMENTS §Perf)
        e_ax = _first_fit(mesh, E, [("data", "model"), ("pod", "model"),
                                    ("model",), ("data",)])
        used = set(_spec_axes(e_ax))
        de_cands = [] if rules.moe_ep \
            else [a for a in ("data", "pod") if a not in used]
        if leaf in ("w_gate", "w_in"):
            de_ax = _fit(mesh, shape[off + 2], tuple(de_cands))
            return spec(e_ax, None, de_ax)
        de_ax = _fit(mesh, shape[off + 1], tuple(de_cands))
        return spec(e_ax, de_ax, None)

    if d - off == 2:
        if leaf in _REPLICATED_2D:
            return spec(None, None)
        if leaf in _COL_PARALLEL:
            return spec(None, fit(1, model))
        if leaf in _ROW_PARALLEL:
            return spec(fit(0, model), None)
        if leaf == "u":                      # rwkv bonus (H, hd)
            return spec(fit(0, model), None)
        if leaf == "conv_w":                 # rglru temporal conv (cw, W)
            return spec(None, fit(1, model))
        if leaf == "w":                      # unet dense (small) — replicate
            return spec(None, None)
        return spec(None, None)

    if d - off == 3 and leaf in ("lora_a", "lora_b", "mu"):
        return spec(None, None, None)

    if d - off == 4:                         # unet conv HWIO — replicate
        return spec(None, None, None, None)

    if d - off == 1:
        if leaf in _MODEL_1D:
            return spec(fit(0, model))
        return spec(None)

    return P(*([None] * d))


def _path_str(kp) -> str:
    parts = []
    for k in kp:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_shardings(abstract_params, mesh: Mesh, rules: ShardingRules):
    """NamedSharding pytree matching an abstract (eval_shape) params tree."""
    def one(kp, leaf):
        spec = _param_spec(_path_str(kp), leaf.shape, mesh, rules)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, abstract_params)


def _zero1_extend(spec: P, shape: Tuple[int, ...], mesh: Mesh,
                  rules: ShardingRules) -> P:
    """Add fsdp axes to the largest free dim — optimizer-state sharding."""
    used = set()
    for e in spec:
        used |= set(_spec_axes(e))
    free_axes = [a for a in rules.fsdp_axes if a in mesh.axis_names
                 and a not in used]
    if not free_axes:
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    for i in order:
        if entries[i] is not None:
            continue
        ax = _fit(mesh, shape[i], tuple(free_axes))
        if ax is not None:
            entries[i] = ax
            break
    return P(*entries)


def opt_state_shardings(abstract_opt_state, abstract_params, mesh: Mesh,
                        rules: ShardingRules):
    """ZeRO-1: master/mu/nu shard like params + fsdp axes; step replicated."""
    def one(kp, leaf):
        spec = _param_spec(_path_str(kp), leaf.shape, mesh, rules)
        return NamedSharding(mesh, _zero1_extend(spec, leaf.shape, mesh,
                                                 rules))
    state_sh = jax.tree_util.tree_map_with_path(one, abstract_params)
    step_sh = NamedSharding(mesh, P())
    master_sh = state_sh if abstract_opt_state.master is not None else None
    return type(abstract_opt_state)(step=step_sh, mu=state_sh, nu=state_sh,
                                    master=master_sh)


# ---------------------------------------------------------------------------
# Batch / cache shardings
# ---------------------------------------------------------------------------
def batch_shardings(specs: Dict[str, jax.ShapeDtypeStruct], mesh: Mesh,
                    rules: ShardingRules):
    out = {}
    for k, s in specs.items():
        bdim = s.shape[0]
        ax = _fit(mesh, bdim, rules.batch)
        spec = [ax] + [None] * (len(s.shape) - 1)
        if ax is None and len(s.shape) >= 2:
            # can't shard batch (e.g. B=1): shard sequence instead
            spec[1] = _fit(mesh, s.shape[1], rules.batch)
        out[k] = NamedSharding(mesh, P(*spec))
    return out


def cache_shardings(abstract_cache, mesh: Mesh, rules: ShardingRules,
                    batch: int):
    """Decode-cache shardings: context parallelism.

    KV tensors (B, S, ...) shard batch over ("pod","data") and SEQUENCE
    over "model" (plus the data axes when B=1 — long_500k).  Sequence
    sharding sidesteps every head-divisibility problem: decode logits are
    local per KV shard and the softmax/PV reductions cross shards as
    tiny (B, H, 1, 1)-sized collectives.  Recurrent states shard their
    lane/head dims over "model"; rwkv states shard heads.
    """
    batch_ax = _fit(mesh, batch, rules.batch)
    seq_axes = ("model",) if batch_ax is not None \
        else ("model", "pod", "data")

    def one(kp, leaf):
        path = _path_str(kp)
        shape = leaf.shape
        d = len(shape)
        spec = [None] * d
        stacked = path.startswith("cycles/")
        off = 1 if stacked else 0
        leaf_name = path.rsplit("/", 1)[-1]
        if leaf_name == "pos" or d - off == 0:
            return NamedSharding(mesh, P())
        if d - off >= 1 and shape[off] == batch and batch_ax is not None:
            spec[off] = batch_ax
        if leaf_name in ("k", "v", "c", "kr", "kv_pos"):
            spec[off + 1] = _fit(mesh, shape[off + 1], seq_axes)
        elif leaf_name == "S" and d - off == 4:       # rwkv (B,H,K,V)
            spec[off + 1] = _fit(mesh, shape[off + 1], rules.heads)
        elif leaf_name in ("h", "conv"):              # rglru states
            spec[d - 1] = _fit(mesh, shape[-1], rules.heads)
        elif leaf_name in ("shift_t", "shift_c"):     # rwkv shifts (B, d)
            spec[d - 1] = _fit(mesh, shape[-1], rules.heads)
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, abstract_cache)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
