"""FedPhD's hierarchy mapped onto TPU topology (DESIGN.md §3.3).

On a multi-pod machine the paper's two aggregation tiers ARE the two
bandwidth tiers: edge aggregation = intra-pod all-reduce over ICI
(cheap, every r_e steps), cloud aggregation = inter-pod all-reduce over
DCN (expensive, every r_g steps).  Each data-parallel group plays one
client; a pod plays one edge server.

``hierarchical_aggregate`` is the shard_map realization of Eqs. 21-24:
SH-weighted within the pod, then SH-weighted across pods, with the
ReLU(n + a*mu + b) weights computed from per-client sample counts and SH
scores that ride along as tiny scalars.
"""
from __future__ import annotations

import warnings
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

if hasattr(jax, "shard_map"):           # public API, jax >= 0.6
    _shard_map = jax.shard_map
else:                                   # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map


def _sh_weight(n, mu, a: float, b: float):
    return jnp.maximum(n + a * mu + b, 0.0)


def shard_clients(tree, mesh, axis: str = "data"):
    """Lay the leading client axis of every leaf over one mesh axis.

    This is the bridge between the vectorized round engine
    (repro/fl/engine.py) and the TPU topology: the engine's stacked
    client axis is placed over ``axis`` so jit's partitioner runs each
    device's client slice locally — the vmapped local training becomes
    data parallelism for free, and the fused (E, C) aggregation einsum
    lowers to the ICI all-reduce of ``hierarchical_aggregate``.

    Leaves whose leading dim does not divide the axis size (or a None
    mesh) are returned unsharded — with a once-per-process warning, so
    a participation count that silently defeats the mesh is visible.
    (Scalar leaves have no client axis and skip quietly; the CPU
    1-device path shards trivially and never warns.)
    """
    if mesh is None or axis not in mesh.axis_names:
        return tree
    n_dev = mesh.shape[axis]

    def put(leaf):
        global _WARNED_INDIVISIBLE
        if leaf.ndim == 0:
            return leaf
        if leaf.shape[0] % n_dev != 0:
            if not _WARNED_INDIVISIBLE:
                warnings.warn(
                    f"shard_clients: a leaf's leading dim "
                    f"({leaf.shape[0]}) does not divide mesh axis "
                    f"{axis!r} (size {n_dev}); leaving it UNSHARDED. "
                    "Pick a participant count divisible by the data-axis "
                    "size to keep the round on the mesh. (warning once "
                    "per process)", RuntimeWarning)
                _WARNED_INDIVISIBLE = True
            return leaf
        spec = P(*((axis,) + (None,) * (leaf.ndim - 1)))
        return jax.device_put(leaf, NamedSharding(mesh, spec))
    return jax.tree.map(put, tree)


_WARNED_INDIVISIBLE = False


def hierarchical_aggregate(params, n_samples, sh_score, *, mesh,
                           edge_axis: str = "data", cloud_axis: str = "pod",
                           a: float = 0.0, b: float = 0.0,
                           cloud_round: bool = True):
    """Two-tier homogeneity-aware aggregation.

    params:    pytree whose leaves are per-client replicas laid out over
               ``edge_axis`` (and ``cloud_axis``) — i.e. each (pod, data)
               slice holds one client's model.
    n_samples: () float32 per client (same layout).
    sh_score:  () float32 per client (Eq. 18).
    Returns the aggregated pytree: edge-level every call; cloud-level
    (across pods) additionally when ``cloud_round``.
    """
    axes = [a_ for a_ in (edge_axis, cloud_axis) if a_ in mesh.axis_names]
    edge_only = axes[:1]

    def local(p_leaves, n, mu):
        w = _sh_weight(n, mu, a, b)
        # --- edge tier: ICI all-reduce over the data axis (Eq. 23/24)
        wsum_e = jax.lax.psum(w, edge_only[0])
        agg = [jax.lax.psum(leaf * (w / wsum_e).astype(leaf.dtype),
                            edge_only[0]) for leaf in p_leaves]
        if cloud_round and cloud_axis in mesh.axis_names:
            # --- cloud tier: DCN all-reduce over the pod axis (Eq. 21/22)
            n_e = wsum_e                       # edge "sample mass"
            mu_e = jax.lax.psum(mu * w, edge_only[0]) / wsum_e
            w_c = _sh_weight(n_e, mu_e, a, b)
            wsum_c = jax.lax.psum(w_c, cloud_axis)
            agg = [jax.lax.psum(leaf * (w_c / wsum_c).astype(leaf.dtype),
                                cloud_axis) for leaf in agg]
        return tuple(agg)

    leaves, treedef = jax.tree.flatten(params)
    spec_axes = tuple(axes) if len(axes) > 1 else axes[0]
    leaf_specs = tuple(
        P(*((spec_axes,) + (None,) * (leaf.ndim - 1))) if leaf.ndim else P()
        for leaf in leaves)
    # client replicas are stacked on a leading axis sharded over the tiers
    out = _shard_map(
        local, mesh=mesh,
        in_specs=(leaf_specs, P(spec_axes), P(spec_axes)),
        out_specs=leaf_specs,
    )(tuple(leaves), n_samples, sh_score)
    return jax.tree.unflatten(treedef, list(out))


def federated_round_cost(model_bytes: int, *, n_pods: int = 2,
                         clients_per_pod: int = 256,
                         cloud_round: bool) -> dict:
    """Analytic per-round traffic of the TPU-mapped hierarchy — the
    ShapeFL cost model's ICI/DCN analogue (EXPERIMENTS.md)."""
    from repro.roofline import hw
    ici = 2 * model_bytes * (clients_per_pod - 1) / clients_per_pod
    dcn = 2 * model_bytes * (n_pods - 1) / n_pods if cloud_round else 0.0
    return {"ici_bytes": ici, "dcn_bytes": dcn,
            "ici_s": ici / hw.ICI_BW, "dcn_s": dcn / hw.DCN_BW}
