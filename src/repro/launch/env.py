"""Launcher environment hygiene for multi-device and cluster runs.

The production launch scripts this repo reproduces preload tcmalloc
(glibc malloc fragments badly under XLA's large transient allocations),
raise the tcmalloc large-alloc report threshold so multi-GB parameter
stacks don't spam stderr, silence TF's C++ logging, and size the fake
host platform with ``--xla_force_host_platform_device_count=N`` so a
single CPU process presents N devices to jax.

All of these are READ AT PROCESS START (LD_PRELOAD by the dynamic
linker, XLA_FLAGS at backend initialization), which is why this module
deliberately never imports jax: it must be importable — and
``apply()``-able — before the first jax import.  Three entry points:

``host_env``    — build the env-var overlay (pure; no side effects).
``apply``       — install the overlay into ``os.environ`` for THIS
                  process; call before importing jax.
``child_env``   — a minimal sanitized environment for a subprocess
                  (the tests' 8-fake-device pattern) or a rendered
                  cluster Job container.

CI's mesh-smoke job and tests/test_mesh_engine.py drive the sharded
round engine through ``child_env(devices=8)`` + an in-child ``apply()``.
"""
from __future__ import annotations

import os
import re
import sys
import warnings
from typing import Dict, Optional, Union

# Debian/Ubuntu path first (the CI and container image), then the
# common fallbacks.  find_tcmalloc() probes in order.
TCMALLOC_CANDIDATES = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/libtcmalloc.so.4",
    "/usr/lib64/libtcmalloc.so.4",
)

# 60 GB: parameter stacks of a few GB must not trip tcmalloc's
# large-alloc stderr report on every round
TCMALLOC_REPORT_THRESHOLD = "60000000000"

_DEVCOUNT_FLAG = re.compile(r"--xla_force_host_platform_device_count=\d+")


def find_tcmalloc() -> Optional[str]:
    """First existing tcmalloc shared object, or None."""
    for path in TCMALLOC_CANDIDATES:
        if os.path.exists(path):
            return path
    return None


def xla_host_devices_flag(n: int) -> str:
    return f"--xla_force_host_platform_device_count={int(n)}"


def merge_xla_flags(new: str, existing: str = "") -> str:
    """Append ``new`` to an XLA_FLAGS string, dropping any prior
    device-count flag it supersedes."""
    kept = _DEVCOUNT_FLAG.sub("", existing or "").split()
    return " ".join(kept + [new]) if new else " ".join(kept)


def host_env(devices: Optional[int] = None, *,
             tcmalloc: Union[bool, str] = "auto",
             platform: Optional[str] = None,
             base: Optional[Dict[str, str]] = None) -> Dict[str, str]:
    """Env-var overlay for a launched process (pure — no side effects).

    devices:  present this many fake host devices (XLA_FLAGS
              ``--xla_force_host_platform_device_count``); None leaves
              the device count alone.
    tcmalloc: "auto" probes the local filesystem and preloads tcmalloc
              when found; True forces the Debian path (for rendering a
              container env on a host that doesn't have the lib);
              False omits LD_PRELOAD.
    platform: set JAX_PLATFORMS (e.g. "cpu" — load-bearing on non-TPU
              boxes where libtpu's GCP-metadata probes would hang).
    base:     start from these vars instead of an empty dict.
    """
    env = dict(base or {})
    env.setdefault("TF_CPP_MIN_LOG_LEVEL", "4")
    env.setdefault("TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD",
                   TCMALLOC_REPORT_THRESHOLD)
    lib = (find_tcmalloc() if tcmalloc == "auto"
           else TCMALLOC_CANDIDATES[0] if tcmalloc is True else None)
    if lib:
        env.setdefault("LD_PRELOAD", lib)
    if platform:
        env["JAX_PLATFORMS"] = platform
    if devices is not None:
        env["XLA_FLAGS"] = merge_xla_flags(xla_host_devices_flag(devices),
                                           env.get("XLA_FLAGS", ""))
    return env


def _jax_backend_live() -> bool:
    """Has a jax backend already initialized (and thus consumed
    XLA_FLAGS)?  Merely having imported jax is fine — flags are read at
    the first device/compile call, not at import."""
    mod = sys.modules.get("jax")
    if mod is None:
        return False
    try:
        return bool(mod._src.xla_bridge._backends)
    except AttributeError:      # private layout moved: be conservative
        return True


def apply(devices: Optional[int] = None, *,
          platform: Optional[str] = None,
          tcmalloc: Union[bool, str] = False) -> Dict[str, str]:
    """Install the launcher overlay into THIS process's environment.

    Must run before the first jax import: XLA reads XLA_FLAGS at
    backend initialization and never again.  LD_PRELOAD cannot take
    effect in-process (the dynamic linker already ran), so tcmalloc
    defaults to False here — it only matters for ``host_env``/
    ``child_env`` consumers that exec a fresh process.

    Returns the applied overlay.
    """
    if _jax_backend_live():
        warnings.warn("repro.launch.env.apply() called after the jax "
                      "backend initialized: XLA_FLAGS were already read "
                      "and will be ignored", RuntimeWarning)
    env = host_env(devices, tcmalloc=tcmalloc, platform=platform,
                   base={"XLA_FLAGS": os.environ["XLA_FLAGS"]}
                   if "XLA_FLAGS" in os.environ else None)
    os.environ.update(env)
    return env


def child_env(devices: Optional[int] = None, *,
              platform: str = "cpu", pythonpath: str = "src",
              tcmalloc: Union[bool, str] = False) -> Dict[str, str]:
    """Minimal sanitized environment for a subprocess that must see
    ``devices`` fake host devices — the subprocess-test pattern: a bare
    PATH/HOME/PYTHONPATH plus the launcher overlay, nothing inherited
    that could re-route the jax backend."""
    base = {
        "PYTHONPATH": pythonpath,
        "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
        "HOME": os.environ.get("HOME", "/root"),
    }
    return host_env(devices, tcmalloc=tcmalloc, platform=platform,
                    base=base)
