"""Step builders: train_step / prefill_step / serve_step.

Each builder closes over a static ModelConfig + ApplyOptions and returns
a pure function suitable for ``jax.jit(..., in_shardings=...,
out_shardings=...)`` — used identically by the smoke tests (1 CPU
device), the FL drivers, and the 512-device dry-run.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model
from repro.models.common import ApplyOptions, DEFAULT_OPTS
from repro.optim import adam_init, adam_update


def build_train_step(cfg: ModelConfig, opts: ApplyOptions = DEFAULT_OPTS, *,
                     lr: float = 3e-4, state_dtype: str = "float32"):
    """train_step(params, opt_state, batch, seed) -> (params, opt_state, loss).

    ``state_dtype="bfloat16"`` stores Adam moments in bf16 — used for the
    >=100B models where fp32 moments exceed per-chip HBM (EXPERIMENTS.md).
    """
    def train_step(params, opt_state, batch, seed):
        rng = jax.random.PRNGKey(seed)
        loss, grads = jax.value_and_grad(
            lambda p: model.loss_fn(p, cfg, batch, rng, opts))(params)
        new_params, new_opt = adam_update(grads, opt_state, params, lr=lr)
        if state_dtype == "bfloat16":
            new_opt = new_opt._replace(
                mu=jax.tree.map(lambda x: x.astype(jnp.bfloat16), new_opt.mu),
                nu=jax.tree.map(lambda x: x.astype(jnp.bfloat16), new_opt.nu))
        return new_params, new_opt, loss
    return train_step


def build_opt_init(cfg: ModelConfig, state_dtype: str = "float32"):
    use_master = cfg.param_dtype == "bfloat16"

    def opt_init(params):
        st = adam_init(params, use_master=use_master)
        if state_dtype == "bfloat16":
            st = st._replace(
                mu=jax.tree.map(lambda x: x.astype(jnp.bfloat16), st.mu),
                nu=jax.tree.map(lambda x: x.astype(jnp.bfloat16), st.nu))
        return st
    return opt_init


def build_prefill_step(cfg: ModelConfig, opts: ApplyOptions = DEFAULT_OPTS):
    """prefill_step(params, batch) -> last-token logits (B, V)."""
    def prefill_step(params, batch):
        return model.prefill(params, cfg, batch, opts)
    return prefill_step


def build_serve_step(cfg: ModelConfig, opts: ApplyOptions = DEFAULT_OPTS):
    """serve_step(params, cache, tokens) -> (next_tokens, logits?, cache).

    ONE new token against a KV cache of seq_len (decode_32k / long_500k).
    Greedy sampling keeps the output small.
    """
    def serve_step(params, cache, tokens):
        logits, new_cache = model.decode(params, cache, cfg, tokens, opts)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tokens, new_cache
    return serve_step
