"""Expert-parallel MoE dispatch via shard_map + all_to_all.

§Perf hillclimb #1 (deepseek-v3-671b x train_4k).  The pjit baseline
(repro/models/moe.py) materializes a (T*k, d) repeated-token tensor and
scatter-adds it into an expert-sharded (E, C, d) buffer; XLA resolves the
token-shard -> expert-shard mismatch by all-gathering/all-reducing the
240 GB repeated tensor per layer (~42 TB/device/step observed in the
baseline HLO — the dominant roofline term).

Here the dispatch is explicit: tokens are sharded over the EP axis
group, each device builds a per-destination send buffer sized by a local
capacity, one ``lax.all_to_all`` moves tokens to their experts, the
expert FFN runs fully locally (one or a few experts per device, weights
resident), and a reverse all_to_all returns outputs.  Per-device traffic
drops to ~2 x T_loc*k*cf*d bytes per layer — the information-theoretic
all-to-all volume of expert parallelism.

Differentiable end-to-end (all_to_all/scatter/gather have transposes);
used inside the scanned layer body under jax.checkpoint.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import MoEConfig

if hasattr(jax, "shard_map"):           # public API, jax >= 0.6
    _shard_map = jax.shard_map
else:                                   # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
from repro.models.common import activation_fn
from repro.models.ffn import apply_ffn
from repro.models.moe import load_balance_loss, router_topk


def _round8(x: int) -> int:
    return max(8, -(-x // 8) * 8)


def apply_moe_ep(p, x, moe: MoEConfig, *, mesh, ep_axes: Tuple[str, ...],
                 token_axes: Tuple[str, ...], activation: str,
                 capacity_mult: float = 2.0):
    """Expert-parallel MoE FFN.  x: (B, S, d) -> (out, aux_loss).

    ep_axes:    mesh axes the EXPERT dim is sharded over (must divide E;
                the all_to_all runs over this axis group).
    token_axes: mesh axes the flat token dim is sharded over inside the
                shard_map (superset of ep_axes, e.g. +"pod").
    Expert weights must be sharded P(ep_axes, None, None) — enforced by
    sharding_rules() when EP is enabled.
    """
    B, S, d = x.shape
    T = B * S
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_ep = math.prod(sizes[a] for a in ep_axes)
    n_tok = math.prod(sizes[a] for a in token_axes)
    E, k = moe.num_experts, moe.experts_per_token
    assert E % n_ep == 0, (E, n_ep)
    E_loc = E // n_ep
    assert T % n_tok == 0
    T_loc = T // n_tok
    # per-(src, dst) send capacity
    cap_s = _round8(int(T_loc * k * capacity_mult / n_ep))
    n_recv = n_ep * cap_s
    cap_e = _round8(int(n_recv * capacity_mult / E_loc)) if E_loc > 1 else 0

    xt = x.reshape(T, d)
    # router runs in the pjit world (small tensors)
    logits = xt.astype(jnp.float32) @ p["router"]
    weights, ids, probs = router_topk(logits, k)
    aux = load_balance_loss(probs, ids, E) * moe.router_aux_loss

    ep_spec = ep_axes if len(ep_axes) > 1 else ep_axes[0]
    tok_spec = token_axes if len(token_axes) > 1 else token_axes[0]

    def local(xt_l, ids_l, w_l, wg, wi, wo):
        # xt_l: (T_loc, d); ids_l/w_l: (T_loc, k); wg/wi: (E_loc, d, de)
        dest = ids_l // E_loc                              # target device
        eloc = ids_l % E_loc
        flat_dest = dest.reshape(-1)                       # (T_loc*k,)
        oh = jax.nn.one_hot(flat_dest, n_ep, dtype=jnp.int32)
        incl = jnp.cumsum(oh, axis=0)
        pos = jnp.take_along_axis(incl - oh, flat_dest[:, None], axis=1)[:, 0]
        keep = pos < cap_s
        posc = jnp.where(keep, pos, cap_s - 1)
        contrib = jnp.repeat(xt_l, k, axis=0) * keep[:, None].astype(xt_l.dtype)
        send = jnp.zeros((n_ep, cap_s, d), xt_l.dtype
                         ).at[flat_dest, posc].add(contrib)
        send_el = jnp.zeros((n_ep, cap_s), jnp.int32
                            ).at[flat_dest, posc].max(
            jnp.where(keep, eloc.reshape(-1) + 1, 0))

        recv = jax.lax.all_to_all(send, ep_axes, 0, 0)     # (n_ep, cap_s, d)
        recv_el = jax.lax.all_to_all(send_el[..., None], ep_axes, 0, 0)[..., 0]
        toks = recv.reshape(n_recv, d)
        el = recv_el.reshape(n_recv)                       # 0 = empty slot

        act = activation_fn(activation)
        if E_loc == 1:
            h = act(toks @ wg[0]) * (toks @ wi[0])
            out = (h @ wo[0]) * (el > 0)[:, None].astype(toks.dtype)
        else:
            # inner local dispatch to E_loc experts
            e_idx = jnp.maximum(el - 1, 0)
            oh2 = jax.nn.one_hot(e_idx, E_loc, dtype=jnp.int32) \
                * (el > 0)[:, None]
            incl2 = jnp.cumsum(oh2, axis=0)
            pos2 = jnp.take_along_axis(incl2 - oh2, e_idx[:, None],
                                       axis=1)[:, 0]
            keep2 = (pos2 < cap_e) & (el > 0)
            pos2c = jnp.where(keep2, pos2, cap_e - 1)
            buf = jnp.zeros((E_loc, cap_e, d), toks.dtype
                            ).at[e_idx, pos2c].add(
                toks * keep2[:, None].astype(toks.dtype))
            h = act(jnp.einsum("ecd,edf->ecf", buf, wg)) \
                * jnp.einsum("ecd,edf->ecf", buf, wi)
            obuf = jnp.einsum("ecf,efd->ecd", h, wo)
            out = obuf[e_idx, pos2c] * keep2[:, None].astype(toks.dtype)

        back = jax.lax.all_to_all(out.reshape(n_ep, cap_s, d), ep_axes, 0, 0)
        gathered = back[flat_dest, posc] \
            * (keep[:, None] & True).astype(xt_l.dtype) \
            * w_l.reshape(-1, 1).astype(xt_l.dtype)
        return jnp.sum(gathered.reshape(T_loc, k, d), axis=1)

    out = _shard_map(
        local, mesh=mesh,
        in_specs=(P(tok_spec, None), P(tok_spec, None), P(tok_spec, None),
                  P(ep_spec, None, None), P(ep_spec, None, None),
                  P(ep_spec, None, None)),
        out_specs=P(tok_spec, None),
    )(xt, ids, weights.astype(xt.dtype), p["w_gate"], p["w_in"], p["w_out"])

    if moe.num_shared_experts > 0:
        # stay in the flat token-sharded world: one boundary reshard total
        xt_c = jax.lax.with_sharding_constraint(xt, P(tok_spec, None))
        sh = apply_ffn(p["shared"], xt_c, activation=activation, glu=True)
        out = out + sh
    out = out.reshape(B, S, d)
    return out, aux
