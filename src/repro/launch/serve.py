"""Serving launcher: continuous-batching-style decode loop.

Maintains a batch of independent request slots with a shared jitted
serve_step; finished requests (EOS or max tokens) are refilled from a
queue — the event-level skeleton of a production server, runnable at
smoke scale on CPU and lowered at full scale by the dry-run.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import list_archs, smoke_variant
from repro.launch.steps import build_serve_step
from repro.models import model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b", choices=list_archs())
    ap.add_argument("--batch", type=int, default=4, help="serving slots")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-tokens", type=int, default=24)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_variant(args.arch)
    rng = jax.random.PRNGKey(args.seed)
    params = model.init(rng, cfg)
    serve = jax.jit(build_serve_step(cfg))

    cache = model.init_cache(params, cfg, args.batch, args.cache_len)
    np_rng = np.random.default_rng(args.seed)
    toks = jnp.asarray(np_rng.integers(0, cfg.vocab_size,
                                       (args.batch, 1)), jnp.int32)
    slot_req = list(range(args.batch))            # request id per slot
    slot_len = [0] * args.batch
    next_req = args.batch
    done = 0
    outputs = {i: [] for i in range(args.requests)}

    t0 = time.perf_counter()
    generated = 0
    while done < args.requests:
        toks, cache = serve(params, cache, toks)
        generated += args.batch
        host = np.asarray(toks)[:, 0]
        for s in range(args.batch):
            rid = slot_req[s]
            if rid is None or rid >= args.requests:
                continue
            outputs[rid].append(int(host[s]))
            slot_len[s] += 1
            if slot_len[s] >= args.max_tokens:
                done += 1
                slot_req[s] = next_req if next_req < args.requests else None
                next_req += 1
                slot_len[s] = 0
    dt = time.perf_counter() - t0
    print(f"arch={cfg.name}  {args.requests} requests x "
          f"{args.max_tokens} tokens, {args.batch} slots: {dt:.1f}s "
          f"({generated/dt:.0f} tok/s incl. refills)")
    for rid in range(min(args.requests, 4)):
        print(f"  req{rid}: {outputs[rid][:12]}...")


if __name__ == "__main__":
    main()
