"""Serving launcher: continuous-batching-style decode loop.

Maintains a batch of independent request slots with a shared jitted
serve_step; finished requests (EOS or max tokens) are refilled from a
queue — the event-level skeleton of a production server, runnable at
smoke scale on CPU and lowered at full scale by the dry-run.  The
diffusion counterpart (per-slot denoising instead of per-slot decoding)
is :mod:`repro.serve`.

Refill hygiene: each request's seed token is a deterministic function of
its request id, and a refilled slot's KV-cache rows are blended back to
fresh state (``model.reset_cache_slots``) before its first step — so a
request's output is identical whichever slot serves it and whatever ran
in that slot before.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b --requests 8
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import list_archs, smoke_variant
from repro.launch.steps import build_serve_step
from repro.models import model


def seed_token(cfg, seed: int, rid: int) -> int:
    """Deterministic per-request seed token — a function of the request
    id only (not the slot it lands in or the slot's history)."""
    return int(np.random.default_rng((seed, rid)).integers(0, cfg.vocab_size))


def serve_requests(params, cfg, *, slots: int, requests: int,
                   max_tokens: int, cache_len: int,
                   seed: int = 0) -> Dict[str, object]:
    """Run ``requests`` generation requests through ``slots`` continuous-
    batching slots; returns per-request token lists + throughput stats.
    """
    serve = jax.jit(build_serve_step(cfg))
    reset_fn = jax.jit(model.reset_cache_slots)
    fresh = model.init_cache(params, cfg, slots, cache_len)
    cache = fresh

    slot_req: List = [r if r < requests else None for r in range(slots)]
    slot_len = [0] * slots
    toks_host = [seed_token(cfg, seed, r) for r in range(slots)]
    toks = jnp.asarray(toks_host, jnp.int32)[:, None]
    next_req = min(slots, requests)
    done = 0
    outputs: Dict[int, List[int]] = {i: [] for i in range(requests)}

    t0 = time.perf_counter()
    generated = 0
    while done < requests:
        toks, cache = serve(params, cache, toks)
        generated += slots
        host = np.asarray(toks)
        reset = np.zeros((slots,), bool)
        new_toks = host[:, 0].copy()
        for s in range(slots):
            rid = slot_req[s]
            if rid is None:
                continue
            outputs[rid].append(int(host[s, 0]))
            slot_len[s] += 1
            if slot_len[s] >= max_tokens:
                done += 1
                nxt = next_req if next_req < requests else None
                next_req += 1
                slot_req[s] = nxt
                slot_len[s] = 0
                # refill: fresh cache rows + the NEW request's seed token
                # (the old code kept both, leaking state across requests)
                reset[s] = True
                new_toks[s] = seed_token(cfg, seed, nxt) if nxt is not None \
                    else 0
        if reset.any():
            cache = reset_fn(cache, fresh, jnp.asarray(reset))
            toks = jnp.asarray(new_toks, jnp.int32)[:, None]
    dt = time.perf_counter() - t0
    return {"outputs": outputs, "seconds": dt, "generated": generated,
            "tok_per_s": generated / dt if dt > 0 else float("inf")}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b", choices=list_archs())
    ap.add_argument("--batch", type=int, default=4, help="serving slots")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-tokens", type=int, default=24)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_variant(args.arch)
    rng = jax.random.PRNGKey(args.seed)
    params = model.init(rng, cfg)
    res = serve_requests(params, cfg, slots=args.batch,
                         requests=args.requests, max_tokens=args.max_tokens,
                         cache_len=args.cache_len, seed=args.seed)
    print(f"arch={cfg.name}  {args.requests} requests x "
          f"{args.max_tokens} tokens, {args.batch} slots: "
          f"{res['seconds']:.1f}s ({res['tok_per_s']:.0f} tok/s incl. "
          f"refills)")
    for rid in range(min(args.requests, 4)):
        print(f"  req{rid}: {res['outputs'][rid][:12]}...")


if __name__ == "__main__":
    main()
