"""Training launcher: run real train steps for any assigned architecture.

On CPU this runs the reduced (smoke) variant by default; on a TPU fleet
the same code path takes --full and the production mesh.  The FedPhD
federated drivers live in examples/fedphd_train.py; this launcher is the
dense/MoE pretraining path the dry-run lowers.

  PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --steps 10
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (get_config, list_archs, sharding_rules,
                           smoke_variant)
from repro.configs.base import InputShape
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.sharding import (batch_shardings, opt_state_shardings,
                                   param_shardings, replicated)
from repro.launch.steps import build_opt_init, build_train_step
from repro.models import model
from repro.models.common import ApplyOptions
from repro import checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b", choices=list_archs())
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--full", action="store_true",
                    help="full-size config on the production mesh (TPU)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt", default=None, help="save final params here")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.full:
        cfg = get_config(args.arch)
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        opts = ApplyOptions(
            attn_chunk=1024, remat=True,
            act_batch_axes=("pod", "data") if args.multi_pod else ("data",),
            act_model_axes=("model",),
            mesh_axis_sizes=tuple(zip(mesh.axis_names, mesh.devices.shape)))
    else:
        cfg = smoke_variant(args.arch)
        mesh = make_host_mesh()
        opts = ApplyOptions(attn_chunk=0, remat=False)

    shape = InputShape("cli", args.seq, args.batch, "train")
    rules = sharding_rules(cfg)
    rng = jax.random.PRNGKey(args.seed)

    print(f"arch={cfg.name}  mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")
    params = model.init(rng, cfg)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"params: {n/1e6:.2f}M")

    step_fn = build_train_step(cfg, opts, lr=args.lr)
    opt_init = build_opt_init(cfg)
    with mesh:
        p_sh = param_shardings(jax.eval_shape(lambda: params), mesh, rules)
        params = jax.device_put(params, p_sh)
        opt = opt_init(params)
        o_sh = opt_state_shardings(jax.eval_shape(lambda: opt), params, mesh,
                                   rules)
        opt = jax.device_put(opt, o_sh)
        specs = model.input_specs(cfg, shape)
        b_sh = batch_shardings(specs, mesh, rules)
        jitted = jax.jit(step_fn, in_shardings=(p_sh, o_sh, b_sh,
                                                replicated(mesh)),
                         out_shardings=(p_sh, o_sh, replicated(mesh)))

        batch = model.make_inputs(rng, cfg, shape)
        losses = []
        t0 = time.perf_counter()
        for i in range(args.steps):
            params, opt, loss = jitted(params, opt, batch, i)
            losses.append(float(loss))
            if i % max(args.steps // 10, 1) == 0 or i == args.steps - 1:
                print(f"step {i:4d}  loss {losses[-1]:.4f}")
        jax.block_until_ready(params)
        dt = time.perf_counter() - t0
    tok = args.batch * args.seq * args.steps
    print(f"{args.steps} steps in {dt:.1f}s ({tok/dt:.0f} tok/s); "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    if args.ckpt:
        checkpoint.save(args.ckpt, jax.device_get(params),
                        {"arch": cfg.name, "steps": args.steps,
                         "final_loss": losses[-1]})
        print(f"saved {args.ckpt}")


if __name__ == "__main__":
    main()
