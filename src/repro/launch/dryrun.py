import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

Proves the distribution config is coherent without hardware:

  with mesh:
      lowered  = jax.jit(step, in_shardings=..., out_shardings=...).lower(*specs)
      compiled = lowered.compile()
      compiled.memory_analysis()     # per-device bytes -> fits / doesn't
      compiled.cost_analysis()       # raw XLA numbers (recorded as-is)
      analyze_hlo(compiled.as_text())  # roofline terms w/ scan trip counts

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out f.json]
"""
import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs import (ARCHS, INPUT_SHAPES, adapt_for_shape, get_config,
                           get_shape, sharding_rules)
from repro.configs.base import InputShape, ModelConfig
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import (batch_shardings, cache_shardings,
                                   opt_state_shardings, param_shardings,
                                   replicated)
from repro.launch.steps import (build_opt_init, build_serve_step,
                                build_train_step, build_prefill_step)
from repro.models import model
from repro.models.common import ApplyOptions
from repro.metrics.flops import active_params, count_params_analytic, model_flops
from repro.roofline import analyze_hlo, hw
from repro.optim import adam_init

_BF16_OPT_STATE = {"deepseek-v3-671b", "qwen3-moe-235b-a22b", "internvl2-76b"}


def _opts_for(cfg: ModelConfig, shape: InputShape,
              overrides: Dict[str, Any] | None = None, *,
              multi_pod: bool = False) -> ApplyOptions:
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    sizes = (("pod", 2), ("data", 16), ("model", 16)) if multi_pod \
        else (("data", 16), ("model", 16))
    kw = dict(attn_chunk=1024 if shape.seq_len > 2048 else 0,
              remat=shape.mode == "train", deterministic=True,
              act_batch_axes=batch_axes, act_model_axes=("model",),
              mesh_axis_sizes=sizes)
    if overrides:
        kw.update(overrides)
    return ApplyOptions(**kw)


def _analytic_memory(cfg: ModelConfig, shape: InputShape, n_chips: int,
                     *, opt_bf16: bool) -> int:
    """Per-chip HBM estimate for the fits-verdict.

    params (bf16, fully sharded) + optimizer (fp32 master + moments,
    ZeRO-1 sharded over all chips) + remat-saved layer carries + decode
    KV cache + a 1 GiB workspace.  CPU-backend memory_analysis() is
    recorded alongside but stages bf16 math through fp32 temporaries that
    a TPU build fuses, so it systematically overestimates.
    """
    n_params = count_params_analytic(cfg)
    bytes_per_param_opt = (4 + 2 + 2) if opt_bf16 else (4 + 4 + 4)
    mem = 2 * n_params / min(n_chips, 256)        # bf16 params, TP+EP sharded
    if shape.mode == "train":
        mem += bytes_per_param_opt * n_params / n_chips   # ZeRO-1
        mem += 2 * n_params / min(n_chips, 256)           # bf16 grads
        # remat carries: num_layers x (B, S, d) bf16, batch-sharded
        mem += (cfg.num_layers * shape.global_batch * shape.seq_len
                * cfg.d_model * 2) / n_chips * (16 / min(n_chips, 256))
        # working set: one layer's activations (batch-sharded)
        mem += (shape.global_batch * shape.seq_len * cfg.d_model * 2 * 8
                ) / (n_chips // 16 if n_chips >= 16 else 1)
    elif shape.mode == "prefill":
        mem += (shape.global_batch * shape.seq_len * cfg.d_model * 2 * 8
                ) / (n_chips // 16 if n_chips >= 16 else 1)
    else:  # decode: KV cache dominates
        from repro.configs.base import ATTN_GLOBAL, ATTN_LOCAL
        kv_bytes = 0
        for kind in cfg.layer_kinds():
            if kind == ATTN_GLOBAL:
                size = shape.seq_len
            elif kind == ATTN_LOCAL:
                size = min(cfg.sliding_window, shape.seq_len)
            else:
                continue
            if cfg.mla is not None:
                kv_bytes += (shape.global_batch * size
                             * (cfg.mla.kv_lora_rank
                                + cfg.mla.qk_rope_head_dim) * 2)
            else:
                kv_bytes += (2 * shape.global_batch * size
                             * cfg.num_kv_heads * cfg.head_dim * 2)
        mem += kv_bytes / min(n_chips, 256)       # batch x seq sharded
    return int(mem + (1 << 30))                   # + workspace


def lower_one(arch: str, shape_name: str, *, multi_pod: bool = False,
              opt_overrides: Dict[str, Any] | None = None,
              verbose: bool = True) -> Dict[str, Any]:
    """Lower + compile one (arch, shape, mesh); return the roofline record."""
    shape = get_shape(shape_name)
    cfg = adapt_for_shape(get_config(arch), shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    pod_size = 256
    rules = sharding_rules(cfg)
    opts = _opts_for(cfg, shape, opt_overrides, multi_pod=multi_pod)
    if opts.moe_ep and cfg.moe is not None:
        import dataclasses as _dc
        ep_axes = ("data", "model") \
            if cfg.moe.num_experts % 256 == 0 else ("model",)
        tok_axes = (("pod",) + ep_axes) if multi_pod else ep_axes
        opts = _dc.replace(opts, ep_mesh=mesh, ep_axes=ep_axes,
                           ep_token_axes=tok_axes)
        rules = _dc.replace(rules, moe_ep=True)

    rng = jax.random.PRNGKey(0)
    abstract_params = jax.eval_shape(lambda r: model.init(r, cfg), rng)
    p_sh = param_shardings(abstract_params, mesh, rules)
    specs = model.input_specs(cfg, shape)
    b_sh = batch_shardings(specs, mesh, rules)

    t0 = time.time()
    with mesh:
        if shape.mode == "train":
            state_dtype = ("bfloat16" if arch in _BF16_OPT_STATE else "float32")
            step = build_train_step(cfg, opts, state_dtype=state_dtype)
            opt_init = build_opt_init(cfg, state_dtype)
            abstract_opt = jax.eval_shape(opt_init, abstract_params)
            o_sh = opt_state_shardings(abstract_opt, abstract_params, mesh,
                                       rules)
            seed_spec = jax.ShapeDtypeStruct((), jnp.int32)
            jitted = jax.jit(step,
                             in_shardings=(p_sh, o_sh, b_sh, replicated(mesh)),
                             out_shardings=(p_sh, o_sh, replicated(mesh)))
            lowered = jitted.lower(abstract_params, abstract_opt, specs,
                                   seed_spec)
        elif shape.mode == "prefill":
            step = build_prefill_step(cfg, opts)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh),
                             out_shardings=replicated(mesh))
            lowered = jitted.lower(abstract_params, specs)
        else:  # decode
            step = build_serve_step(cfg, opts)
            abstract_cache = jax.eval_shape(
                lambda p: model.init_cache(p, cfg, shape.global_batch,
                                           shape.seq_len, opts=opts),
                abstract_params)
            c_sh = cache_shardings(abstract_cache, mesh, rules,
                                   shape.global_batch)
            tok_sh = b_sh["tokens"]
            jitted = jax.jit(step, in_shardings=(p_sh, c_sh, tok_sh),
                             out_shardings=(replicated(mesh), c_sh))
            lowered = jitted.lower(abstract_params, abstract_cache,
                                   specs["tokens"])
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    terms = analyze_hlo(hlo, pod_size=pod_size)
    analytic_mem = _analytic_memory(cfg, shape, n_chips,
                                    opt_bf16=arch in _BF16_OPT_STATE)

    mflops = model_flops(cfg, shape)
    flops_total = terms.flops * n_chips
    rec = {
        "arch": arch,
        "config_name": cfg.name,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": n_chips,
        "mode": shape.mode,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "params_total": count_params_analytic(cfg),
        "params_active": active_params(cfg),
        "memory": {
            "argument_bytes_per_device": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes_per_device": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes_per_device": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes_per_device": (
                getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "temp_size_in_bytes", 0)
                + getattr(mem, "output_size_in_bytes", 0)),
            # CPU-backend buffer assignment stages bf16 math through fp32
            # temporaries a TPU build fuses — the analytic model below is
            # the fits-verdict (EXPERIMENTS.md caveats).
            "analytic_bytes_per_device": analytic_mem,
            "hbm_limit": hw.HBM_BYTES,
        },
        "xla_cost_analysis": {k: cost.get(k) for k in
                              ("flops", "bytes accessed", "transcendentals")
                              if cost and k in cost},
        "roofline": terms.to_dict(),
        "model_flops": mflops,
        "useful_flops_ratio": (mflops / flops_total) if flops_total else None,
    }
    if verbose:
        mem_gb = analytic_mem / 2**30
        xla_gb = rec["memory"]["peak_bytes_per_device"] / 2**30 \
            if rec["memory"]["peak_bytes_per_device"] else float("nan")
        fits = "FITS" if mem_gb < hw.HBM_BYTES / 2**30 else "OVER-HBM"
        print(f"[{arch} x {shape_name} x {rec['mesh']}] "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s | "
              f"analytic {mem_gb:.2f} GiB/chip ({fits}; xla-cpu {xla_gb:.1f}) | "
              f"compute {terms.compute_s*1e3:.2f}ms "
              f"memory {terms.memory_s*1e3:.2f}ms "
              f"collective {terms.collective_s*1e3:.2f}ms "
              f"-> {terms.dominant()}-bound")
        sys.stdout.flush()
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="architecture id")
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape)")
    ap.add_argument("--multi-pod", action="store_true",
                    help="2x16x16 (pod,data,model) mesh instead of 16x16")
    ap.add_argument("--out", default=None, help="JSON output path")
    args = ap.parse_args()

    records = []
    if args.all:
        combos = [(a, s) for a in sorted(ARCHS) for s in INPUT_SHAPES]
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        combos = [(args.arch, args.shape)]

    failures = []
    for arch, shape in combos:
        try:
            records.append(lower_one(arch, shape, multi_pod=args.multi_pod))
        except Exception as e:  # noqa: BLE001 — report every failure at end
            failures.append((arch, shape, repr(e)))
            traceback.print_exc()
    if args.out:
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {len(records)} records to {args.out}")
    if failures:
        print("FAILURES:")
        for f in failures:
            print(" ", f)
        sys.exit(1)
    print(f"dry-run OK: {len(records)} combination(s) lowered + compiled")


if __name__ == "__main__":
    main()
