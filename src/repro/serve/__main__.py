"""CLI: serve sampling requests from a training checkpoint.

  PYTHONPATH=src python -m repro.serve --ckpt out/ckpt --requests 8 \
      --slots 4 --steps 10 --prune-ratio 0.44 --out samples/

Loads any ``repro.checkpoint`` artifact (e.g. the experiment runner's
``ckpt.npz``), optionally derives serving masks at ``--prune-ratio``,
and runs the continuous-batching server over ``--requests`` requests.
Prints requests/s + p50/p99 per-step latency and the dense-vs-masked
analytic MACs; ``--metrics`` dumps them as JSON for CI.
"""
from __future__ import annotations

import argparse
import os

import numpy as np

from repro.experiment.cli import (add_compute_flags, add_metrics_flag,
                                  add_obs_flags, make_cli_tracer,
                                  write_metrics)
from repro.metrics.flops import unet_macs
from repro.serve.artifact import load_serving_artifact, masks_for_ratio
from repro.serve.server import DiffusionServer, Request


def main():
    ap = argparse.ArgumentParser(prog="python -m repro.serve")
    ap.add_argument("--ckpt", required=True,
                    help="checkpoint path (runner's <out>/ckpt)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--steps", type=int, default=10, help="DDIM steps")
    ap.add_argument("--eta", type=float, default=0.0,
                    help="0 = deterministic DDIM; 1 ~ DDPM ancestral")
    ap.add_argument("--prune-ratio", type=float, default=0.0,
                    help="serve through masks at this ratio (0 = dense)")
    ap.add_argument("--criterion", default="l2", choices=("l2", "random"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help="directory for req<rid>.npy images")
    # shared surface with repro.experiment.runner:
    # --backend/--precision/--trace/--metrics (repro.experiment.cli)
    add_compute_flags(ap)
    add_obs_flags(ap)
    add_metrics_flag(ap)
    args = ap.parse_args()

    params, cfg, meta = load_serving_artifact(args.ckpt,
                                              backend=args.backend)
    masks = None
    if args.prune_ratio > 0:
        masks = masks_for_ratio(params, cfg, args.prune_ratio,
                                criterion=args.criterion)
    dense_macs = unet_macs(params, cfg.image_size)
    macs = unet_macs(params, cfg.image_size, masks=masks)
    # --trace > $FEDPHD_OBS > off; default path next to the checkpoint
    tracer = make_cli_tracer(args.trace,
                             default_path=args.ckpt + ".serve.trace.jsonl")
    server = DiffusionServer(params, cfg, slots=args.slots,
                             num_steps=args.steps, eta=args.eta, masks=masks,
                             precision=args.precision or "",
                             tracer=tracer if tracer.enabled else None)
    reqs = [Request(rid=r, seed=args.seed + r) for r in range(args.requests)]
    res = server.run(reqs)

    p50 = res.latency_percentile(50) * 1e3
    p99 = res.latency_percentile(99) * 1e3
    print(f"model={cfg.name} backend={cfg.backend} "
          f"precision={server.precision} "
          f"prune_ratio={args.prune_ratio} steps={args.steps} "
          f"slots={args.slots}")
    print(f"MACs/forward: {macs / 1e6:.1f}M"
          + (f" (dense {dense_macs / 1e6:.1f}M, "
             f"{macs / dense_macs:.2f}x)" if masks is not None else ""))
    print(f"{len(res.images)}/{args.requests} images in {res.seconds:.2f}s "
          f"({res.requests_per_s:.2f} req/s); per-step latency "
          f"p50={p50:.1f}ms p99={p99:.1f}ms; compiles={server.compile_count()}")
    for f in res.faults:
        print(f"fault: {f}")

    if args.out:
        os.makedirs(args.out, exist_ok=True)
        for rid, img in res.images.items():
            np.save(os.path.join(args.out, f"req{rid}.npy"), img)
        print(f"wrote {len(res.images)} images to {args.out}")
    if tracer.enabled:
        tracer.close()
        print(f"trace -> {tracer.path}")
    if args.metrics:
        write_metrics(args.metrics, "serve", {
            "requests": args.requests,
            "images": len(res.images),
            "requests_per_s": res.requests_per_s,
            "p50_step_ms": p50,
            "p99_step_ms": p99,
            "compiles": server.compile_count(),
            "precision": server.precision,
            "macs_per_forward": macs,
            "dense_macs_per_forward": dense_macs,
            "faults": res.faults,
        })
        print(f"wrote metrics to {args.metrics}")
    if len(res.images) != args.requests:
        raise SystemExit(f"served {len(res.images)}/{args.requests} requests")


if __name__ == "__main__":
    main()
