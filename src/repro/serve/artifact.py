"""Checkpoint -> servable artifact: params + post-training ModelConfig.

Any ``repro.checkpoint`` artifact works — ``Experiment.save`` output
(the runner's ``ckpt.npz``) or a raw trainer ``state()`` dump.  The
model config is recovered from the trainer metadata when present
(FedPhD trainers store the *post-prune* cfg there) and otherwise from
the spec's model name; the serving backend can be overridden per
deployment without touching the checkpoint.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint
from repro.configs import get_config
from repro.configs.base import ModelConfig, config_from_dict
from repro.models.ops import resolve_backend


def load_serving_artifact(path: str, *, backend: Optional[str] = None
                          ) -> Tuple[Any, ModelConfig, Dict]:
    """Load ``(params, cfg, meta)`` ready for :class:`DiffusionServer`.

    ``backend`` overrides the checkpoint's compute backend (serving
    hardware need not match training hardware); ``None`` keeps it.
    """
    arrays, meta = checkpoint.load(path)
    if "params" not in arrays:
        raise ValueError(f"checkpoint at {path!r} has no 'params' entry — "
                         f"not a trainer/experiment artifact")
    params = jax.tree.map(jnp.asarray, arrays["params"])
    if meta.get("cfg"):
        cfg = config_from_dict(meta["cfg"])
    elif meta.get("spec", {}).get("model"):
        cfg = get_config(meta["spec"]["model"])
        if meta["spec"].get("backend"):
            cfg = cfg.replace(backend=meta["spec"]["backend"])
    else:
        raise ValueError(f"checkpoint at {path!r} carries neither a model "
                         f"cfg nor a spec to derive one from")
    if cfg.arch_type != "unet":
        raise ValueError(f"repro.serve samples diffusion U-Nets; checkpoint "
                         f"is arch_type={cfg.arch_type!r} (use "
                         f"repro.launch.serve for token models)")
    cfg = cfg.replace(backend=resolve_backend(backend or cfg.backend))
    return params, cfg, meta


def masks_for_ratio(params, cfg: ModelConfig, ratio: float,
                    *, criterion: str = "l2") -> Dict[str, np.ndarray]:
    """Serving masks at ``ratio`` as HOST numpy arrays — the type that
    triggers ops' static sparsity specialization (trace-time channel
    gathers) instead of the training-time multiply-by-zero path."""
    from repro.core.pruning.criteria import l2_scores, random_scores
    from repro.core.pruning.groups import build_groups
    from repro.core.pruning.masks import make_masks
    groups = build_groups(cfg, params)
    if criterion == "l2":
        scores = l2_scores(params, groups, backend=cfg.backend)
    elif criterion == "random":
        scores = random_scores(jax.random.PRNGKey(0), groups)
    else:
        raise ValueError(f"unknown criterion {criterion!r}")
    masks = make_masks(scores, groups, ratio)
    return {k: np.asarray(v) for k, v in masks.items()}
