"""Pruned-diffusion sampling service (ROADMAP item 2).

Continuous-batching DDIM/DDPM sampler: a fixed pool of request slots
advances through ONE jitted denoising tick per step — per-slot step
counters are data, so requests at different denoising depths coexist in
a batch and refills never recompile.  Host masks (``np.ndarray``) route
the forward through :mod:`repro.models.ops`' static sparsity
specialization, so the 44%-pruned sparse-phase model is genuinely
cheaper to serve.

  PYTHONPATH=src python -m repro.serve --ckpt out/ckpt --requests 8
"""
from repro.serve.artifact import load_serving_artifact, masks_for_ratio
from repro.serve.server import DiffusionServer, Request, ServeResult

__all__ = ["DiffusionServer", "Request", "ServeResult",
           "load_serving_artifact", "masks_for_ratio"]
