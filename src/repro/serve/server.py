"""Continuous-batching diffusion sampler server.

The server owns a ``(slots, H, W, C)`` batch of denoising states plus a
per-slot step counter and RNG key — all *data*, so every tick runs the
same compiled program regardless of which requests occupy which slots or
how deep each one is.  A finished slot emits its image and refills from
the request source; a faulting or timing-out source degrades gracefully
(the fault is recorded and serving continues with whatever slots are
live).

Per-request determinism: a request's prior draw and per-step noise
stream are functions of its ``seed`` alone, following
:func:`repro.diffusion.ddim.ddim_sample`'s exact split sequence — so the
served output for a request equals a standalone ``ddim_sample`` run and
is identical whichever slot serves it and whatever ran there before.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, Iterator, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.diffusion.ddim import ddim_step, ddim_timesteps
from repro.diffusion.schedule import linear_schedule
from repro.obs.compile_tracker import CompileTracker, cache_size
from repro.obs.trace import NULL_TRACER


@dataclass(frozen=True)
class Request:
    """One image to sample.  ``seed`` fully determines the output."""
    rid: int
    seed: int = 0


@dataclass
class ServeResult:
    images: Dict[int, np.ndarray] = field(default_factory=dict)
    step_latencies_s: List[float] = field(default_factory=list)
    request_latencies_s: Dict[int, float] = field(default_factory=dict)
    faults: List[str] = field(default_factory=list)
    seconds: float = 0.0

    def latency_percentile(self, q: float) -> float:
        """Per-step latency percentile in seconds (q in [0, 100])."""
        if not self.step_latencies_s:
            return float("nan")
        return float(np.percentile(np.asarray(self.step_latencies_s), q))

    @property
    def requests_per_s(self) -> float:
        n = len(self.images)
        return n / self.seconds if self.seconds > 0 else float("inf")


RequestSource = Union[Iterable, Iterator, Callable[[], Optional[Request]]]


class DiffusionServer:
    """Slot-based continuous-batching DDIM (eta=0) / DDPM-like (eta>0)
    sampler over a trained (optionally mask-pruned) U-Net.

    ``masks``: pass **host** numpy masks (``masks_for_ratio``) to serve
    the pruned model through ops' static sparsity specialization;
    ``None`` serves dense.
    """

    def __init__(self, params, cfg: ModelConfig, *, slots: int = 4,
                 num_steps: int = 10, eta: float = 0.0, masks=None,
                 precision: str = "", tracer=None):
        from repro.models.unet import apply_unet
        from repro.models.ops import (cast_floats, compute_dtype,
                                      resolve_precision)
        # serving is inference-only: under bf16 the weights themselves
        # are cast once at construction (no fp32 master needed) and the
        # ops layer casts activations at each GEMM boundary; the
        # denoising state x and the DDIM schedule stay fp32
        self.precision = resolve_precision(precision or cfg.precision)
        self.cfg = cfg = cfg.replace(precision=self.precision)
        self.params = jax.tree.map(jnp.asarray, params)
        dt = compute_dtype(self.precision)
        if dt != jnp.float32:
            self.params = cast_floats(self.params, dt)
        self.slots = slots
        self.num_steps = num_steps
        self.eta = eta
        self.masks = masks
        sched = linear_schedule(cfg.diffusion_steps)
        ts = ddim_timesteps(cfg.diffusion_steps, num_steps)
        ts_prev = jnp.concatenate([ts[1:], jnp.full((1,), -1, ts.dtype)])
        shape = (slots, cfg.image_size, cfg.image_size, cfg.in_channels)

        def tick(params, x, sidx, active, keys):
            idx = jnp.minimum(sidx, num_steps - 1)
            t, tp = ts[idx], ts_prev[idx]
            eps = apply_unet(params, cfg, x, t, masks=masks)
            if eta == 0.0:
                x_new = ddim_step(x, t, tp, eps, sched, eta=0.0)
                new_keys = keys
            else:
                sp = jax.vmap(jax.random.split)(keys)      # (slots, 2, kdim)
                new_keys = sp[:, 0]
                z = jax.vmap(lambda k: jax.random.normal(
                    k, shape[1:], jnp.float32))(sp[:, 1])
                x_new = ddim_step(x, t, tp, eps, sched, eta=eta, z=z)
            guard = active.reshape((-1,) + (1,) * (x.ndim - 1))
            x = jnp.where(guard, x_new, x)
            sidx = jnp.where(active, sidx + 1, sidx)
            keys = jnp.where(active.reshape((-1,) + (1,) * (keys.ndim - 1)),
                             new_keys, keys)
            return x, sidx, keys

        self._tick = jax.jit(tick)
        self.x = jnp.zeros(shape, jnp.float32)
        self.sidx = jnp.zeros((slots,), jnp.int32)
        key0 = jax.random.PRNGKey(0)
        self.keys = jnp.broadcast_to(key0, (slots,) + key0.shape)
        self._slot_req: List[Optional[Request]] = [None] * slots
        self._admit_t = [0.0] * slots
        self.step_latencies_s: List[float] = []
        self.request_latencies_s: Dict[int, float] = {}
        # obs: NULL_TRACER default = zero-overhead no-op, same contract
        # as the trainers (repro.obs)
        self._obs = NULL_TRACER
        self._obs_compile = None
        if tracer is not None:
            self.bind_tracer(tracer)

    def bind_tracer(self, tracer) -> None:
        """Attach (or detach, with ``None``) an obs tracer; ticks emit
        ``serve/tick`` spans and the jitted tick program's cache is
        watched for unexpected recompiles (``compile/tick``)."""
        self._obs = tracer if tracer is not None else NULL_TRACER
        self._obs_compile = CompileTracker(self._obs) \
            if (self._obs.enabled
                and getattr(self._obs, "compile_tracking", False)) else None
        if self._obs_compile is not None:
            self._obs_compile.watch("tick", self._tick)

    # -- request lifecycle ---------------------------------------------------
    def _seed_state(self, seed: int):
        """(carry_key, x_T) following ddim_sample's split sequence for a
        1-image shape — the served trajectory matches a standalone
        ``ddim_sample(..., PRNGKey(seed), (1, H, W, C))`` bitwise."""
        k = jax.random.split(jax.random.PRNGKey(seed))
        c = self.cfg
        x0 = jax.random.normal(k[1], (c.image_size, c.image_size,
                                      c.in_channels), jnp.float32)
        return k[0], x0

    def free_slots(self) -> List[int]:
        return [s for s, r in enumerate(self._slot_req) if r is None]

    def active_count(self) -> int:
        return self.slots - len(self.free_slots())

    def submit(self, req: Request) -> bool:
        """Admit a request into a free slot; False if the batch is full."""
        free = self.free_slots()
        if not free:
            return False
        s = free[0]
        carry, x0 = self._seed_state(req.seed)
        self.x = self.x.at[s].set(x0)
        self.sidx = self.sidx.at[s].set(0)
        self.keys = self.keys.at[s].set(carry)
        self._slot_req[s] = req
        self._admit_t[s] = time.perf_counter()
        return True

    def kill(self, rid: int) -> bool:
        """Drop an in-flight request without emitting (client went away).
        The slot is immediately refillable; isolation is the refill
        contract, not a cache wipe — new requests overwrite x/sidx/keys."""
        for s, r in enumerate(self._slot_req):
            if r is not None and r.rid == rid:
                self._slot_req[s] = None
                return True
        return False

    # -- the denoising tick --------------------------------------------------
    def step(self) -> List[Tuple[int, np.ndarray]]:
        """One jitted denoising tick over the slot batch; returns the
        ``(rid, image)`` pairs that completed this tick."""
        occupancy = [r is not None for r in self._slot_req]
        active = jnp.asarray(occupancy)
        t0 = time.perf_counter()
        self.x, self.sidx, self.keys = self._tick(
            self.params, self.x, self.sidx, active, self.keys)
        self.x.block_until_ready()
        now = time.perf_counter()
        self.step_latencies_s.append(now - t0)
        self._obs.record_span("serve/tick", t0, now,
                              active=sum(occupancy))
        if self._obs_compile is not None:
            self._obs_compile.check()
        completed = []
        sidx_host = np.asarray(self.sidx)
        for s, req in enumerate(self._slot_req):
            if req is not None and int(sidx_host[s]) >= self.num_steps:
                completed.append((req.rid, np.asarray(self.x[s])))
                self.request_latencies_s[req.rid] = now - self._admit_t[s]
                self._slot_req[s] = None
        return completed

    def compile_count(self) -> int:
        """Number of compiled tick programs (tests assert it stays 1 —
        slot occupancy/depth is data, not shape).  Reads jit's cache
        through the shared :func:`repro.obs.compile_tracker.cache_size`
        probe rather than the private ``_cache_size`` directly."""
        n = cache_size(self._tick)
        return 0 if n is None else n

    # -- serving loop --------------------------------------------------------
    def run(self, requests: RequestSource, *, idle_limit: int = 100,
            fault_limit: int = 100) -> ServeResult:
        """Serve until the source is exhausted and all slots drain.

        The source is an iterable of :class:`Request` or a callable; it
        may yield ``None`` ("no request right now" — a timeout) or raise
        (a fault).  Both degrade gracefully: serving continues with live
        slots, and ``idle_limit`` consecutive empty polls with an empty
        batch (or ``fault_limit`` consecutive faults) ends the run with
        the condition recorded in ``result.faults``.
        """
        res = ServeResult()
        pull = requests if callable(requests) else iter(requests).__next__
        exhausted = False
        idle = faults_in_a_row = 0
        n0_steps = len(self.step_latencies_s)
        t_start = time.perf_counter()
        while True:
            while not exhausted and self.free_slots():
                try:
                    req = pull()
                except StopIteration:
                    exhausted = True
                    break
                except Exception as e:          # queue fault
                    res.faults.append(f"request source fault: {e!r}")
                    self._obs.event("serve/fault", kind="source",
                                    detail=repr(e))
                    faults_in_a_row += 1
                    if faults_in_a_row >= fault_limit:
                        res.faults.append("fault limit reached; treating "
                                          "source as exhausted")
                        self._obs.event("serve/fault", kind="fault_limit")
                        exhausted = True
                    continue
                faults_in_a_row = 0
                if req is None:                 # timeout/empty poll
                    break
                self.submit(req)
            if self.active_count() == 0:
                if exhausted:
                    break
                idle += 1                       # source alive but empty
                if idle >= idle_limit:
                    res.faults.append("idle limit reached with empty "
                                      "source; stopping")
                    self._obs.event("serve/fault", kind="idle_limit")
                    break
                continue
            idle = 0
            for rid, img in self.step():
                res.images[rid] = img
        res.seconds = time.perf_counter() - t_start
        res.step_latencies_s = self.step_latencies_s[n0_steps:]
        res.request_latencies_s = dict(self.request_latencies_s)
        self._obs.flush()
        return res
