"""Pure-jnp oracle for per-group L2 norms."""
import jax.numpy as jnp


def group_l2_norms_ref(w, num_groups: int):
    K, N = w.shape
    chunk = N // num_groups
    wr = w.astype(jnp.float32).reshape(K, num_groups, chunk)
    return jnp.sum(jnp.square(wr), axis=(0, 2))
