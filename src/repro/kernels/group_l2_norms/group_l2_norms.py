"""Per-group L2 norms over channel-chunked weights — the pruning
criterion (Eq. 17/18) and the Omega regularizer's inner reduction.

A (K, G*C) weight is reduced to (G,) sums-of-squares: grid over groups,
each step loads a (K, C) slab into VMEM and reduces it on the VPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(w_ref, o_ref):
    w = w_ref[...].astype(jnp.float32)
    o_ref[0] = jnp.sum(w * w)


def group_l2_norms(w, num_groups: int, *, interpret: bool = False):
    """w: (K, G*C) -> (G,) per-group sum of squares along the column
    chunks (chunk = columns // num_groups)."""
    K, N = w.shape
    assert N % num_groups == 0
    chunk = N // num_groups
    return pl.pallas_call(
        _kernel,
        grid=(num_groups,),
        in_specs=[pl.BlockSpec((K, chunk), lambda g: (0, g))],
        out_specs=pl.BlockSpec((1,), lambda g: (g,)),
        out_shape=jax.ShapeDtypeStruct((num_groups,), jnp.float32),
        interpret=interpret,
    )(w)
