"""Jitted wrapper for the group-norm reduction kernel."""
from functools import partial

import jax

from repro.kernels.group_l2_norms.group_l2_norms import group_l2_norms
from repro.kernels.group_l2_norms.ref import group_l2_norms_ref


@partial(jax.jit, static_argnames=("num_groups", "interpret"))
def group_sq_norms_kernel(w, num_groups: int, *, interpret: bool = True):
    if w.shape[1] % num_groups:
        return group_l2_norms_ref(w, num_groups)
    return group_l2_norms(w, num_groups, interpret=interpret)
