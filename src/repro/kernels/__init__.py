"""Pallas TPU kernels (validated with interpret=True on CPU).

- block_masked_matmul: structured-pruning sparse-phase matmul
- flash_attention:     streaming-softmax attention, causal + window
- rglru_scan:          blocked linear recurrence (RG-LRU / SSM)
- group_l2_norms:      pruning-criterion group reductions
"""
