"""Pallas TPU kernels (validated with interpret=True on CPU).

- block_masked_matmul: structured-pruning sparse-phase matmul
- flash_attention:     streaming-softmax attention, causal + window
- rglru_scan:          blocked linear recurrence (RG-LRU / SSM)
- group_l2_norms:      pruning-criterion group reductions

Training code does NOT import these directly: the compute-backend
dispatch layer :mod:`repro.models.ops` is the front door —
``ops.masked_matmul`` / ``ops.matmul`` / ``ops.conv`` route to
``block_masked_matmul``, ``ops.attention`` to ``flash_attention``, and
``ops.group_sq_norms_2d`` (via ``repro.core.pruning.criteria``) to
``group_l2_norms``, each selected per-run by ``ModelConfig.backend``
(``xla`` | ``pallas`` | ``ref``, env default ``$FEDPHD_BACKEND``) and
wrapped in ``custom_vjp`` where the loss path needs gradients.  The
per-kernel ``ops.py`` wrappers here stay the tile-alignment gate: off-
spec shapes fall back to the ``ref.py`` oracles.  ``rglru_scan`` is
reachable through the RG-LRU layer stack (``repro.models.rglru``), not
the FedPhD U-Net path.
"""
