"""Jitted wrapper: masked dense layer for the sparse-training phase."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.block_masked_matmul.block_masked_matmul import (
    block_masked_matmul)
from repro.kernels.block_masked_matmul.ref import block_masked_matmul_ref


@partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def masked_matmul(x, w, col_mask, row_mask, *, bm: int = 128, bk: int = 128,
                  bn: int = 128, interpret: bool = True):
    """2-D or 3-D x against a channel-masked weight.

    Falls back to the jnp reference when shapes are not tile-aligned
    (smoke-scale models); the kernel path is the TPU target.
    """
    orig_shape = x.shape
    if x.ndim == 3:
        x = x.reshape(-1, x.shape[-1])
    M, K = x.shape
    N = w.shape[1]
    if M % bm or K % bk or N % bn:
        out = block_masked_matmul_ref(x, w, col_mask, row_mask)
    else:
        out = block_masked_matmul(x, w, col_mask, row_mask, bm=bm, bk=bk,
                                  bn=bn, interpret=interpret)
    if len(orig_shape) == 3:
        out = out.reshape(orig_shape[0], orig_shape[1], N)
    return out
