"""Pure-jnp oracle for the block-masked matmul."""
import jax.numpy as jnp


def block_masked_matmul_ref(x, w, col_mask, row_mask):
    wm = (w * col_mask[None, :].astype(w.dtype)
          * row_mask[:, None].astype(w.dtype))
    return jnp.dot(x, wm, preferred_element_type=jnp.float32).astype(x.dtype)
