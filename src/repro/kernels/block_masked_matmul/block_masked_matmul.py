"""Block-masked matmul — structured pruning's TPU-native compute kernel.

The sparse-training phase (paper Eq. 16) runs a model whose pruned
channels are zero but whose shapes are unchanged (DESIGN.md §3.1).  On
GPU, DepGraph physically slices; on TPU the idiom is: keep MXU-aligned
(bm, bk, bn) tiles and SKIP whole tiles whose channel-mask block is all
zero — `@pl.when` guards both the A-side (K blocks: pruned input
channels) and B-side (N blocks: pruned output channels), so a 44%-pruned
layer does ~44% fewer MXU passes without any reshaping.

y = x @ (w * colmask[None, :] * rowmask[:, None])
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(nmask_ref, kmask_ref, x_ref, w_ref, o_ref, acc_ref, *,
            n_kblocks: int):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    active = (nmask_ref[0] != 0) & (kmask_ref[0] != 0)

    @pl.when(active)
    def _compute():
        acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                                preferred_element_type=jnp.float32)

    @pl.when(kb == n_kblocks - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def block_masked_matmul(x, w, col_mask, row_mask, *, bm: int = 128,
                        bk: int = 128, bn: int = 128,
                        interpret: bool = False):
    """x: (M, K); w: (K, N); col_mask: (N,) 0/1; row_mask: (K,) 0/1.

    Masks are reduced to per-block "any nonzero" flags; tiles whose flag
    is 0 are skipped entirely (their VMEM tiles never reach the MXU).
    """
    M, K = x.shape
    K2, N = w.shape
    assert K == K2
    assert M % bm == 0 and K % bk == 0 and N % bn == 0, (M, K, N)
    nmb, nkb, nnb = M // bm, K // bk, N // bn

    # per-block activity flags (tiny host-side reduction)
    nflags = (col_mask.reshape(nnb, bn).max(axis=1) != 0).astype(jnp.int32)
    kflags = (row_mask.reshape(nkb, bk).max(axis=1) != 0).astype(jnp.int32)
    # fine-grained mask applied to w once (keeps partially-masked active
    # blocks exact)
    wm = (w * col_mask[None, :].astype(w.dtype)
          * row_mask[:, None].astype(w.dtype))

    kernel = functools.partial(_kernel, n_kblocks=nkb)
    return pl.pallas_call(
        kernel,
        grid=(nmb, nnb, nkb),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j, k: (j,)),          # nflags
            pl.BlockSpec((1,), lambda i, j, k: (k,)),          # kflags
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),    # x
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),    # w
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(nflags, kflags, x, wm)
