"""Jitted wrapper for the blocked linear-recurrence kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.rglru_scan.rglru_scan import rglru_scan
from repro.kernels.rglru_scan.ref import rglru_scan_ref


@partial(jax.jit, static_argnames=("bs", "interpret"))
def linear_recurrence(a, b, *, bs: int = 256, interpret: bool = True):
    """h_t = a_t h_{t-1} + b_t, blocked-VMEM kernel with jnp fallback."""
    B, S, W = a.shape
    if S % bs:
        return rglru_scan_ref(a, b)
    return rglru_scan(a, b, bs=bs, interpret=interpret)
