"""Blocked linear-recurrence scan: h_t = a_t * h_{t-1} + b_t.

The RG-LRU / SSM hot-spot.  The sequence is processed in (bs)-length
blocks; the running state h lives in a VMEM scratch buffer that persists
across sequential grid steps (TPU grids iterate the trailing axis in
order), so HBM sees each (a, b) element exactly once — the naive
``lax.scan`` round-trips the state through HBM every step, which is why
the rwkv6/recurrentgemma baselines are so memory-bound in the roofline
table.  Within a block the recurrence is unrolled with a fori_loop over
vectorized (width,)-lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, o_ref, h_ref, *, bs: int):
    sb = pl.program_id(1)

    @pl.when(sb == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0].astype(jnp.float32)       # (bs, W)
    b = b_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t] * h + b[t]
        o_ref[0, t, :] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, bs, step, h_ref[0])
    h_ref[0] = h


def rglru_scan(a, b, *, bs: int = 256, interpret: bool = False):
    """a, b: (B, S, W) -> h: (B, S, W) with h_t = a_t h_{t-1} + b_t."""
    B, S, W = a.shape
    assert S % bs == 0
    nsb = S // bs
    kernel = functools.partial(_kernel, bs=bs)
    return pl.pallas_call(
        kernel,
        grid=(B, nsb),
        in_specs=[
            pl.BlockSpec((1, bs, W), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bs, W), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bs, W), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, W), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, W), jnp.float32)],
        interpret=interpret,
    )(a, b)
