"""Pure-jnp oracle: associative-scan linear recurrence."""
import jax
import jax.numpy as jnp


def rglru_scan_ref(a, b):
    """h_t = a_t * h_{t-1} + b_t over axis 1; h_{-1} = 0."""
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br
    _, h = jax.lax.associative_scan(
        combine, (a.astype(jnp.float32), b.astype(jnp.float32)), axis=1)
    return h.astype(a.dtype)
