"""Pure-jnp oracle for flash attention."""
import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """q: (BH, Sq, hd); k, v: (BH, Skv, hd)."""
    BH, Sq, hd = q.shape
    Skv = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (hd ** -0.5)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    ok = jnp.ones((Sq, Skv), bool)
    if causal:
        ok &= kpos <= qpos
    if window > 0:
        ok &= (qpos - kpos) < window
    s = jnp.where(ok[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
