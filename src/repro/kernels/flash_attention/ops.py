"""Jitted wrapper for the flash-attention kernel.

Accepts the model-layout (B, S, H, hd) tensors (GQA pre-expanded by the
caller) and dispatches to the Pallas kernel; non-tile-aligned shapes fall
back to the oracle.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_bhsd
from repro.kernels.flash_attention.ref import flash_attention_ref


@partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                   "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 128, interpret: bool = True):
    """q: (B, Sq, Hq, hd); k, v: (B, Skv, Hkv, hd) -> (B, Sq, Hq, hd)."""
    B, Sq, Hq, hd = q.shape
    Hkv = k.shape[2]
    if Hq != Hkv:                          # expand GQA groups
        rep = Hq // Hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hq, -1, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hq, -1, hd)
    if Sq % bq or kf.shape[1] % bk:
        out = flash_attention_ref(qf, kf, vf, causal=causal, window=window)
    else:
        out = flash_attention_bhsd(qf, kf, vf, causal=causal, window=window,
                                   bq=bq, bk=bk, interpret=interpret)
    return out.reshape(B, Hq, Sq, hd).transpose(0, 2, 1, 3)
