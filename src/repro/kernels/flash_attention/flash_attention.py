"""Flash attention (causal + sliding window) — prefill/train hot-spot.

Streaming-softmax over KV blocks with fp32 (m, l, acc) accumulators in
VMEM; KV blocks entirely outside the causal/window range of a query
block are skipped with `@pl.when` (block-level sparsity — this is what
makes windowed prefill sub-quadratic on the MXU).

Layout: q (B, H, Sq, hd), k/v (B, H, Skv, hd) — heads flattened into the
grid's first axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bq: int, bk: int, n_kblocks: int, causal: bool, window: int,
            scale: float):
    qb = pl.program_id(1)
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qb * bq
    k_start = kb * bk
    # block-level causal/window reachability
    reachable = True
    if causal:
        reachable = k_start <= q_start + bq - 1
    if window > 0:
        reachable = reachable & (k_start + bk - 1 >= q_start - window + 1)

    @pl.when(reachable)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                     # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                     # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            ok &= kpos <= qpos
        if window > 0:
            ok &= (qpos - kpos) < window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kb == n_kblocks - 1)
    def _flush():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True, window: int = 0,
                         bq: int = 128, bk: int = 128,
                         interpret: bool = False):
    """q: (BH, Sq, hd); k, v: (BH, Skv, hd)."""
    BH, Sq, hd = q.shape
    Skv = k.shape[1]
    assert Sq % bq == 0 and Skv % bk == 0
    nq, nk = Sq // bq, Skv // bk
    scale = hd ** -0.5
    kernel = functools.partial(_kernel, bq=bq, bk=bk, n_kblocks=nk,
                               causal=causal, window=window, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max m
            pltpu.VMEM((bq, 1), jnp.float32),    # running denom l
            pltpu.VMEM((bq, hd), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
