"""command-r-35b — dense GQA decoder, no biases, parallel block.

[hf:CohereForAI/c4ai-command-r-v01] 40 layers, d_model=8192, 64 heads,
GQA kv=8 (per assignment), d_ff=22528, vocab 256000, parallel
attention+FFN block, tied embeddings, no bias anywhere.
"""
from repro.configs.base import ModelConfig, ATTN_GLOBAL

CONFIG = ModelConfig(
    name="command-r-35b",
    arch_type="decoder",
    source="hf:CohereForAI/c4ai-command-r-v01",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab_size=256000,
    layer_pattern=(ATTN_GLOBAL,),
    parallel_block=True,
    tie_embeddings=True,
    rope_theta=8e6,
    activation="silu",
    glu=True,
    norm_eps=1e-5,
    max_seq_len=131072,
)
