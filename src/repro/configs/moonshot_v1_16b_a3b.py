"""moonshot-v1-16b-a3b (Moonlight-16B-A3B) — fine-grained MoE decoder.

[hf:moonshotai/Moonlight-16B-A3B] DeepSeek-V2-lite-style: 48 layers (the
spec's "dense" tag notwithstanding — the config carries MoE 64e top-6),
d_model=2048, 16 heads MHA (kv=16), per-expert d_ff=1408, vocab 163840,
64 routed experts top-6 + 2 shared experts, first layer dense.
"""
from repro.configs.base import ModelConfig, MoEConfig, ATTN_GLOBAL

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    arch_type="decoder",
    source="hf:moonshotai/Moonlight-16B-A3B",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=11264,                  # dense layers' FFN (deepseek-v2-lite style)
    vocab_size=163840,
    layer_pattern=(ATTN_GLOBAL,),
    moe=MoEConfig(
        num_experts=64,
        experts_per_token=6,
        d_expert=1408,
        num_shared_experts=2,
        d_shared=2816,
        router_aux_loss=0.001,
        capacity_factor=1.25,
        first_dense_layers=1,
    ),
    rope_theta=5e4,
    activation="silu",
    glu=True,
    norm_eps=1e-5,
    max_seq_len=32768,
)
