"""internvl2-76b — VLM: InternViT frontend (STUB) + Llama-3-70B-class LM.

[arXiv:2404.16821] Language backbone: 80 layers, d_model=8192, 64 heads
GQA kv=8, d_ff=28672, vocab 128256.  The vision encoder (InternViT-6B) and
MLP projector are STUBS per the assignment — ``input_specs()`` provides
precomputed patch embeddings (batch, num_image_tokens, d_model) that are
prepended to the token embeddings.
"""
from repro.configs.base import ModelConfig, ATTN_GLOBAL

CONFIG = ModelConfig(
    name="internvl2-76b",
    arch_type="vlm",
    source="arXiv:2404.16821",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    num_image_tokens=256,
    layer_pattern=(ATTN_GLOBAL,),
    rope_theta=5e5,
    activation="silu",
    glu=True,
    norm_eps=1e-5,
    max_seq_len=32768,
)
