"""Configuration dataclasses for the repro framework.

Every model in the zoo (the paper's DDPM U-Net and the 10 assigned
architectures) is described by a frozen dataclass config.  Configs are pure
data: hashable, comparable, and serializable — they are used as static args
to jitted step builders.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Layer kinds for the unified decoder stack.
# ---------------------------------------------------------------------------
ATTN_GLOBAL = 0      # full causal attention
ATTN_LOCAL = 1       # sliding-window causal attention
RECURRENT = 2        # RG-LRU recurrent block (recurrentgemma)
RWKV = 3             # RWKV6 time-mix block

LAYER_KIND_NAMES = {
    ATTN_GLOBAL: "attn_global",
    ATTN_LOCAL: "attn_local",
    RECURRENT: "rglru",
    RWKV: "rwkv6",
}


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts sub-config."""
    num_experts: int
    experts_per_token: int
    d_expert: int                       # per-expert ffn hidden dim
    num_shared_experts: int = 0         # deepseek-style always-on shared expert(s)
    d_shared: int = 0                   # hidden dim of the shared expert
    router_aux_loss: float = 0.0        # load-balance aux loss coefficient
    capacity_factor: float = 1.25       # dense-dispatch capacity
    first_dense_layers: int = 0         # leading layers that use dense FFN (deepseek=3)


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 multi-head latent attention sub-config."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class ModelConfig:
    """Unified model configuration.

    ``arch_type`` selects the top-level model family:
      - "decoder":   causal decoder-only LM (dense / MoE / SSM / hybrid)
      - "encdec":    whisper-style encoder-decoder (audio frontend stubbed)
      - "vlm":       vision-language (ViT frontend stubbed, decoder LM backbone)
      - "unet":      DDPM U-Net (the paper's own model)
    """
    name: str
    arch_type: str                       # decoder | encdec | vlm | unet
    source: str = ""                     # citation (arXiv id / hf card)

    # --- transformer backbone ----------------------------------------------
    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0                    # 0 -> d_model // num_heads
    d_ff: int = 0
    vocab_size: int = 0
    max_seq_len: int = 8192
    # layer pattern: tuple of layer kinds, cycled over num_layers.
    layer_pattern: Tuple[int, ...] = (ATTN_GLOBAL,)
    sliding_window: int = 4096           # window for ATTN_LOCAL layers
    rope_theta: float = 10000.0
    use_qkv_bias: bool = False
    use_attn_out_bias: bool = False
    use_ffn_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    activation: str = "silu"             # silu (swiglu) | gelu (plain mlp)
    glu: bool = True                     # gated linear unit FFN
    logit_softcap: float = 0.0           # gemma2 final logit soft-capping
    attn_softcap: float = 0.0            # gemma2 attention logit soft-capping
    parallel_block: bool = False         # command-r parallel attn+ffn block
    # --- MoE / MLA -----------------------------------------------------------
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    # --- recurrent (RG-LRU / RWKV) ------------------------------------------
    lru_width: int = 0                   # RG-LRU recurrence width (0 -> d_model)
    conv1d_width: int = 4                # temporal conv in recurrent block
    # --- enc-dec (whisper) ---------------------------------------------------
    num_encoder_layers: int = 0
    encoder_seq_len: int = 1500          # whisper mel-frame count after conv stub
    # --- vlm -----------------------------------------------------------------
    num_image_tokens: int = 0            # patch-embedding count from the ViT stub
    # --- unet ----------------------------------------------------------------
    image_size: int = 32
    in_channels: int = 3
    base_channels: int = 128
    channel_mults: Tuple[int, ...] = (1, 2, 2, 2)
    num_res_blocks: int = 2
    attn_resolutions: Tuple[int, ...] = (16,)
    num_classes: int = 0                 # 0 = unconditional
    dropout: float = 0.1
    diffusion_steps: int = 1000

    # --- numerics ------------------------------------------------------------
    dtype: str = "bfloat16"              # activation dtype
    param_dtype: str = "bfloat16"        # parameter dtype (fp32 master in opt)
    # --- compute backend -----------------------------------------------------
    # repro.models.ops dispatch: "xla" | "pallas" | "ref"; "" resolves
    # via $FEDPHD_BACKEND (trainers bake the resolved name in at
    # construction, so jit caches and checkpoints pin a concrete
    # backend).  Part of the frozen config on purpose: the backend is a
    # static argument of every compiled step/round program.
    backend: str = ""
    # --- compute precision ---------------------------------------------------
    # repro.models.ops precision axis: "fp32" | "bf16"; "" resolves via
    # $FEDPHD_PRECISION (trainers bake the resolved name in, same as
    # backend).  bf16 casts float params inside the loss closure — the
    # master weights, Adam moments, and aggregation stay fp32.  Frozen
    # for the same reason as ``backend``: it is a static argument of
    # every compiled step/round program.
    precision: str = ""

    def __post_init__(self):
        if self.arch_type != "unet":
            if self.head_dim == 0 and self.num_heads:
                object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
            if self.num_kv_heads == 0:
                object.__setattr__(self, "num_kv_heads", self.num_heads)
            if self.lru_width == 0:
                object.__setattr__(self, "lru_width", self.d_model)

    # -- helpers --------------------------------------------------------------
    def layer_kinds(self) -> Tuple[int, ...]:
        """Per-layer kind, pattern cycled to num_layers."""
        pat = self.layer_pattern
        return tuple(pat[i % len(pat)] for i in range(self.num_layers))

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    @property
    def q_per_kv(self) -> int:
        return max(1, self.num_heads // max(1, self.num_kv_heads))

    def param_count(self) -> int:
        """Analytic parameter count (matches models.model.init shapes)."""
        from repro.metrics.flops import count_params_analytic
        return count_params_analytic(self)


@dataclass(frozen=True)
class InputShape:
    """One assigned (global) input shape."""
    name: str
    seq_len: int
    global_batch: int
    mode: str                            # "train" | "prefill" | "decode"


TRAIN_4K = InputShape("train_4k", 4096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32768, 128, "decode")
LONG_500K = InputShape("long_500k", 524288, 1, "decode")

INPUT_SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


@dataclass(frozen=True)
class ShardingRules:
    """Logical-axis -> mesh-axis mapping for one model.

    Each entry is a tuple of mesh axis names (or None) per logical axis.
    ``fsdp_axes`` lists mesh axes over which parameters are additionally
    sharded on their largest dimension (ZeRO-3 style).
    """
    batch: Tuple[str, ...] = ("pod", "data")
    heads: Tuple[str, ...] = ("model",)
    ffn: Tuple[str, ...] = ("model",)
    experts: Tuple[str, ...] = ("model",)
    vocab: Tuple[str, ...] = ("model",)
    fsdp_axes: Tuple[str, ...] = ("data",)
    shard_kv_cache_seq: bool = False     # shard the KV cache along sequence
    moe_ep: bool = False                 # shard_map expert parallelism:
                                         # experts over the EP axes, d_expert
                                         # unsharded (weights fully local)


# -- JSON (de)serialization --------------------------------------------------
# Configs are frozen dataclasses of scalars and tuples; JSON turns the
# tuples into lists, so round-tripping needs explicit coercion.  Used by
# repro.experiment (declarative specs) and checkpoint manifests (the
# post-prune ModelConfig differs from the one the run started with).

_MODEL_TUPLE_FIELDS = ("layer_pattern", "channel_mults", "attn_resolutions")


def config_to_dict(cfg: "ModelConfig") -> dict:
    return dataclasses.asdict(cfg)


def config_from_dict(d: dict) -> "ModelConfig":
    d = dict(d)
    if d.get("moe"):
        d["moe"] = MoEConfig(**d["moe"])
    if d.get("mla"):
        d["mla"] = MLAConfig(**d["mla"])
    for k in _MODEL_TUPLE_FIELDS:
        if d.get(k) is not None:
            d[k] = tuple(d[k])
    return ModelConfig(**d)


def fl_to_dict(fl: "FLConfig") -> dict:
    return dataclasses.asdict(fl)


def fl_from_dict(d: dict) -> "FLConfig":
    return FLConfig(**d)


@dataclass(frozen=True)
class FLConfig:
    """Federated-learning / FedPhD hyper-parameters (paper §V-A)."""
    num_clients: int = 20                # N
    num_edges: int = 2                   # N_e
    participation: float = 1.0           # kappa
    local_epochs: int = 1                # E
    edge_agg_every: int = 1              # r_e
    cloud_agg_every: int = 5             # r_g
    rounds: int = 100                    # R
    sparse_rounds: int = 20              # R_s
    # SH-score weighting (eqs 22/24/25)
    sh_a: float = 15000.0
    sh_b: float = 0.0
    # pruning
    prune_ratio: float = 0.44            # s_p
    prune_mode: str = "group_norm"       # "group_norm" | "oneshot_random" | "oneshot_l2"
    lambda0: float = 1e-4                # group-lasso base scale (eq 17)
    # baseline knobs
    fedprox_mu: float = 1.0
    moon_mu: float = 1.0
    moon_tau: float = 0.5
    seed: int = 0
