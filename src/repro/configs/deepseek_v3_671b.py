"""deepseek-v3-671b — MLA + fine-grained MoE (256 routed top-8 + 1 shared).

[arXiv:2412.19437] 61 layers, d_model=7168, 128 heads with Multi-head
Latent Attention (q_lora 1536, kv_lora 512, qk nope 128 + rope 64, v 128),
per-expert d_ff=2048, vocab 129280.  First 3 layers dense (d_ff 18432).
MTP (multi-token prediction) head available as an option in the model zoo.
"""
from repro.configs.base import ModelConfig, MoEConfig, MLAConfig, ATTN_GLOBAL

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    arch_type="decoder",
    source="arXiv:2412.19437",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,            # MLA: per-head KV reconstructed from latent
    head_dim=128,
    d_ff=18432,                  # dense layers' FFN
    vocab_size=129280,
    layer_pattern=(ATTN_GLOBAL,),
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        num_experts=256,
        experts_per_token=8,
        d_expert=2048,
        num_shared_experts=1,
        d_shared=2048,
        router_aux_loss=0.0001,
        capacity_factor=1.25,
        first_dense_layers=3,
    ),
    rope_theta=10000.0,
    activation="silu",
    glu=True,
    norm_eps=1e-6,
    max_seq_len=131072,
)
