"""internlm2-20b — dense GQA decoder.

[arXiv:2403.17297] 48 layers, d_model=6144, 48 heads, GQA kv=8,
d_ff=16384, vocab 92544, SwiGLU, RoPE theta 1e6.
"""
from repro.configs.base import ModelConfig, ATTN_GLOBAL

CONFIG = ModelConfig(
    name="internlm2-20b",
    arch_type="decoder",
    source="arXiv:2403.17297",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92544,
    layer_pattern=(ATTN_GLOBAL,),
    rope_theta=1e6,
    activation="silu",
    glu=True,
    norm_eps=1e-5,
    max_seq_len=32768,
)
