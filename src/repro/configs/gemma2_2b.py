"""gemma2-2b — dense GQA with alternating local/global attention + softcaps.

[arXiv:2408.00118] 26 layers, d_model=2304, 8 heads GQA kv=4, head_dim=256,
d_ff=9216, vocab 256000.  Alternates sliding-window (4096) and global
attention; logit softcap 30, attention softcap 50; GeGLU FFN.
"""
from repro.configs.base import ModelConfig, ATTN_LOCAL, ATTN_GLOBAL

CONFIG = ModelConfig(
    name="gemma2-2b",
    arch_type="decoder",
    source="arXiv:2408.00118",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    layer_pattern=(ATTN_LOCAL, ATTN_GLOBAL),
    sliding_window=4096,
    logit_softcap=30.0,
    attn_softcap=50.0,
    rope_theta=10000.0,
    activation="gelu",
    glu=True,
    tie_embeddings=True,
    norm_eps=1e-6,
    max_seq_len=1 << 20,
)
