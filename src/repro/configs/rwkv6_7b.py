"""rwkv6-7b (Finch) — attention-free RNN with data-dependent decay.

[arXiv:2404.05892] 32 layers, d_model=4096, head_size 64 (64 heads),
channel-mix d_ff=14336, vocab 65536.  Decode is O(1)-state; long_500k
runs natively.
"""
from repro.configs.base import ModelConfig, RWKV

CONFIG = ModelConfig(
    name="rwkv6-7b",
    arch_type="decoder",
    source="arXiv:2404.05892",
    num_layers=32,
    d_model=4096,
    num_heads=64,                # wkv heads: d_model / head_size(64)
    num_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    layer_pattern=(RWKV,),
    activation="relu",           # channel-mix uses squared ReLU
    glu=False,
    norm_eps=1e-5,
    max_seq_len=1 << 20,
)
