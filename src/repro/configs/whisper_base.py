"""whisper-base — audio encoder-decoder transformer backbone.

[arXiv:2212.04356] Whisper base: 6 encoder + 6 decoder layers, d_model=512,
8 heads (full MHA, kv=8), d_ff=2048, vocab 51865.  The mel-spectrogram +
conv frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings of shape (batch, 1500, 512).

Positional scheme adapted to RoPE (framework-uniform); whisper's learned
absolute embeddings are an equivalent-capacity substitute — recorded in
DESIGN.md §8.
"""
from repro.configs.base import ModelConfig, ATTN_GLOBAL

CONFIG = ModelConfig(
    name="whisper-base",
    arch_type="encdec",
    source="arXiv:2212.04356",
    num_layers=6,              # decoder layers
    num_encoder_layers=6,
    encoder_seq_len=1500,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    layer_pattern=(ATTN_GLOBAL,),
    activation="gelu",
    glu=False,                 # whisper uses plain GELU MLP
    use_qkv_bias=True,
    use_attn_out_bias=True,
    use_ffn_bias=True,
    norm_eps=1e-5,
    max_seq_len=32768,
)
