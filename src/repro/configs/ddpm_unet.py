"""The paper's own model: DDPM U-Net (Ho et al. 2020), 35.7M params.

Used with CIFAR-10-like 32x32 data and CelebA-like 64x64 data (§V-A:
"we employ the same U-Net architecture as in [1], where the dense model
comprises 35.7 million parameters").
"""
from repro.configs.base import ModelConfig

CIFAR10_UNET = ModelConfig(
    name="ddpm-unet-cifar10",
    arch_type="unet",
    source="arXiv:2006.11239 (Ho et al.); FedPhD §V-A",
    image_size=32,
    in_channels=3,
    base_channels=128,
    channel_mults=(1, 2, 2, 2),
    num_res_blocks=2,
    attn_resolutions=(16,),
    num_classes=0,               # unconditional; labels used only for FL partition
    dropout=0.1,
    diffusion_steps=1000,
    dtype="float32",
    param_dtype="float32",
)

CELEBA_UNET = CIFAR10_UNET.replace(
    name="ddpm-unet-celeba",
    image_size=64,               # same net, 2x input size -> 4x MACs (Table IV)
)

# Reduced variant for CPU smoke tests and the end-to-end example driver.
SMOKE_UNET = ModelConfig(
    name="ddpm-unet-smoke",
    arch_type="unet",
    source="reduced for CPU",
    image_size=16,
    in_channels=3,
    base_channels=32,
    channel_mults=(1, 2),
    num_res_blocks=1,
    attn_resolutions=(8,),
    num_classes=0,
    dropout=0.0,
    diffusion_steps=100,
    dtype="float32",
    param_dtype="float32",
)
