"""recurrentgemma-9b — hybrid RG-LRU + local attention, 1:2 ratio.

[arXiv:2402.19427] Griffin / RecurrentGemma: repeating block of
(recurrent, recurrent, local attention). 38 layers, d_model=4096,
16 heads with MQA (kv=1) on the attention layers, d_ff=12288,
vocab 256000, sliding window 2048.
"""
from repro.configs.base import ModelConfig, RECURRENT, ATTN_LOCAL

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    arch_type="decoder",
    source="arXiv:2402.19427",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    layer_pattern=(RECURRENT, RECURRENT, ATTN_LOCAL),
    sliding_window=2048,
    lru_width=4096,
    conv1d_width=4,
    rope_theta=10000.0,
    activation="gelu",
    glu=True,
    norm_eps=1e-6,
    max_seq_len=1 << 20,   # recurrence + window: unbounded context
)
