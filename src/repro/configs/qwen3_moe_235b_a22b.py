"""qwen3-moe-235b-a22b — MoE decoder, 128 experts top-8.

[hf:Qwen/Qwen3-30B-A3B family, scaled per assignment] 94 layers,
d_model=4096, 64 heads GQA kv=4, per-expert d_ff=1536, vocab 151936,
128 routed experts top-8, no shared expert, all layers MoE.
"""
from repro.configs.base import ModelConfig, MoEConfig, ATTN_GLOBAL

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    arch_type="decoder",
    source="hf:Qwen/Qwen3-30B-A3B",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,                   # unused (no dense layers); kept for reference
    vocab_size=151936,
    layer_pattern=(ATTN_GLOBAL,),
    moe=MoEConfig(
        num_experts=128,
        experts_per_token=8,
        d_expert=1536,
        num_shared_experts=0,
        d_shared=0,
        router_aux_loss=0.001,
        capacity_factor=1.25,
        first_dense_layers=0,
    ),
    rope_theta=1e6,
    activation="silu",
    glu=True,
    norm_eps=1e-6,
    max_seq_len=32768,
)
