"""Config registry: ``get_config(arch_id)`` + reduced smoke variants.

The 10 assigned architectures are selectable via ``--arch <id>`` in the
launchers; the paper's own DDPM U-Net configs live alongside them.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.configs.base import (
    ModelConfig, MoEConfig, MLAConfig, InputShape, ShardingRules, FLConfig,
    INPUT_SHAPES, TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K,
    ATTN_GLOBAL, ATTN_LOCAL, RECURRENT, RWKV,
)

from repro.configs.recurrentgemma_9b import CONFIG as _recurrentgemma_9b
from repro.configs.whisper_base import CONFIG as _whisper_base
from repro.configs.internlm2_20b import CONFIG as _internlm2_20b
from repro.configs.gemma2_2b import CONFIG as _gemma2_2b
from repro.configs.internvl2_76b import CONFIG as _internvl2_76b
from repro.configs.moonshot_v1_16b_a3b import CONFIG as _moonshot
from repro.configs.deepseek_v3_671b import CONFIG as _deepseek_v3
from repro.configs.qwen3_moe_235b_a22b import CONFIG as _qwen3_moe
from repro.configs.rwkv6_7b import CONFIG as _rwkv6_7b
from repro.configs.command_r_35b import CONFIG as _command_r
from repro.configs.ddpm_unet import CIFAR10_UNET, CELEBA_UNET, SMOKE_UNET

ARCHS: Dict[str, ModelConfig] = {
    "recurrentgemma-9b": _recurrentgemma_9b,
    "whisper-base": _whisper_base,
    "internlm2-20b": _internlm2_20b,
    "gemma2-2b": _gemma2_2b,
    "internvl2-76b": _internvl2_76b,
    "moonshot-v1-16b-a3b": _moonshot,
    "deepseek-v3-671b": _deepseek_v3,
    "qwen3-moe-235b-a22b": _qwen3_moe,
    "rwkv6-7b": _rwkv6_7b,
    "command-r-35b": _command_r,
}

UNETS: Dict[str, ModelConfig] = {
    "ddpm-unet-cifar10": CIFAR10_UNET,
    "ddpm-unet-celeba": CELEBA_UNET,
    "ddpm-unet-smoke": SMOKE_UNET,
}

ALL_CONFIGS: Dict[str, ModelConfig] = {**ARCHS, **UNETS}


def list_archs() -> List[str]:
    return sorted(ARCHS.keys())


def get_config(name: str) -> ModelConfig:
    if name not in ALL_CONFIGS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ALL_CONFIGS)}")
    return ALL_CONFIGS[name]


def register_config(name: str, cfg: ModelConfig, *,
                    overwrite: bool = False) -> None:
    """Add a model config to the registry (the extension point the
    declarative experiment specs resolve ``spec.model`` through)."""
    if name in ALL_CONFIGS and not overwrite:
        raise ValueError(f"config {name!r} already registered "
                         "(pass overwrite=True to replace)")
    ALL_CONFIGS[name] = cfg


def get_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]


# ---------------------------------------------------------------------------
# Shape-specific config adaptation (DESIGN.md §4 decode-shape policy).
# ---------------------------------------------------------------------------
def adapt_for_shape(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Adapt an architecture config for a given input shape.

    For ``long_500k`` decode, pure-full-attention architectures get an
    explicit sliding-window override (window=4096) so the KV cache and
    per-token cost stay sub-quadratic/bounded.  Architectures with native
    sub-quadratic structure (rwkv6, recurrentgemma, gemma2's local layers)
    are untouched.  The override is visible in the returned config's
    ``layer_pattern`` / ``name`` and recorded in EXPERIMENTS.md.
    """
    if shape.name != "long_500k" or cfg.arch_type == "unet":
        return cfg
    kinds = set(cfg.layer_kinds())
    if kinds <= {ATTN_LOCAL, RECURRENT, RWKV}:
        return cfg  # natively sub-quadratic
    if cfg.name == "gemma2-2b":
        # native alternating local/global: decode over 500k is linear per
        # token; keep as-is (global layers hold the full KV cache).
        return cfg
    # dense / MoE / enc-dec / vlm: switch all global attention to windowed.
    pattern = tuple(ATTN_LOCAL if k == ATTN_GLOBAL else k for k in cfg.layer_pattern)
    return cfg.replace(
        name=cfg.name + "+swa4096",
        layer_pattern=pattern,
        sliding_window=4096,
        max_seq_len=max(cfg.max_seq_len, shape.seq_len),
    )


# ---------------------------------------------------------------------------
# Reduced smoke variants: same family, ≤2 layers, d_model ≤ 512, ≤4 experts.
# ---------------------------------------------------------------------------
def smoke_variant(name: str) -> ModelConfig:
    cfg = get_config(name)
    if cfg.arch_type == "unet":
        return SMOKE_UNET
    d_model = min(cfg.d_model, 256)
    head_dim = 32
    num_heads = max(2, min(4, cfg.num_heads))
    num_kv = max(1, min(num_heads, cfg.num_kv_heads if cfg.num_kv_heads < cfg.num_heads else num_heads))
    # keep GQA ratio flavour: MQA stays MQA, MHA stays MHA
    if cfg.num_kv_heads == 1:
        num_kv = 1
    elif cfg.num_kv_heads == cfg.num_heads:
        num_kv = num_heads
    else:
        num_kv = max(1, num_heads // 2)
    pattern = cfg.layer_pattern
    num_layers = max(2, len(pattern))       # at least one full pattern cycle
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(
            cfg.moe,
            num_experts=4,
            experts_per_token=2,
            d_expert=64,
            num_shared_experts=min(1, cfg.moe.num_shared_experts),
            d_shared=64 if cfg.moe.num_shared_experts else 0,
            first_dense_layers=min(1, cfg.moe.first_dense_layers),
        )
    mla = None
    if cfg.mla is not None:
        mla = MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                        qk_nope_head_dim=head_dim, qk_rope_head_dim=16,
                        v_head_dim=head_dim)
    return cfg.replace(
        name=cfg.name + "-smoke",
        num_layers=num_layers,
        d_model=d_model,
        num_heads=num_heads,
        num_kv_heads=num_kv,
        head_dim=head_dim,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 512,
        vocab_size=min(cfg.vocab_size, 1024),
        moe=moe,
        mla=mla,
        lru_width=d_model,
        sliding_window=min(cfg.sliding_window, 64),
        num_encoder_layers=min(cfg.num_encoder_layers, 2),
        encoder_seq_len=min(cfg.encoder_seq_len, 32) if cfg.arch_type == "encdec" else cfg.encoder_seq_len,
        num_image_tokens=min(cfg.num_image_tokens, 8),
        max_seq_len=1024,
        dtype="float32",
        param_dtype="float32",
    )


# ---------------------------------------------------------------------------
# Per-arch sharding rules (DESIGN.md §6).
# ---------------------------------------------------------------------------
_BIG = {"internvl2-76b", "deepseek-v3-671b", "qwen3-moe-235b-a22b",
        "command-r-35b", "internlm2-20b", "recurrentgemma-9b"}


def sharding_rules(cfg: ModelConfig) -> ShardingRules:
    base_name = cfg.name.replace("-smoke", "").replace("+swa4096", "")
    fsdp = ("data", "pod") if base_name in _BIG else ("data",)
    return ShardingRules(
        batch=("pod", "data"),
        heads=("model",),
        ffn=("model",),
        experts=("model",),
        vocab=("model",),
        fsdp_axes=fsdp,
        shard_kv_cache_seq=False,
    )


__all__ = [
    "ModelConfig", "MoEConfig", "MLAConfig", "InputShape", "ShardingRules",
    "FLConfig", "INPUT_SHAPES", "TRAIN_4K", "PREFILL_32K", "DECODE_32K",
    "LONG_500K", "ARCHS", "UNETS", "ALL_CONFIGS", "list_archs", "get_config",
    "get_shape", "adapt_for_shape", "smoke_variant", "sharding_rules",
    "ATTN_GLOBAL", "ATTN_LOCAL", "RECURRENT", "RWKV",
    "CIFAR10_UNET", "CELEBA_UNET", "SMOKE_UNET",
]
