"""Declarative experiment specification.

An :class:`ExperimentSpec` is the single front door to every trainer in
the repo: FedPhD (hierarchical, with or without pruning), FedPhD-OS,
and the five flat Table-II baselines all resolve from one frozen,
JSON-round-trippable description — model config, FL hyper-parameters,
data partition, method, selection/aggregation ablations, round engine,
persistent-optimizer flag, eval cadence, and one seed that drives data
generation, partitioning, and both trainer RNG streams.

The paper's tables are grids over these specs: Table I is
``method in {fedphd, fedphd-os, fedavg, fedprox, moon, scaffold,
feddiffuse}`` with everything else held fixed; the selection/aggregation
ablations are ``selection="random"`` / ``aggregation="fedavg"`` on the
fedphd point.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional

from repro.configs.base import FLConfig, fl_from_dict
from repro.fl.compress import CommSpec
from repro.fl.faults import FaultSpec
from repro.obs.spec import ObsSpec

TOPOLOGIES = ("hierarchical", "flat")


@dataclasses.dataclass(frozen=True)
class DataSpec:
    """Client-data construction: synthetic dataset + non-IID partition."""
    dataset: str = "smoke"          # repro.experiment.data.DATASETS key
    partition: str = "shards"       # shards | iid | dirichlet
    classes_per_client: int = 1     # shards partition sharpness
    alpha: float = 0.5              # dirichlet concentration
    batch_size: int = 32


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """One experiment, declaratively.  ``method`` resolves through the
    trainer registry (:mod:`repro.experiment.registry`); ``topology``
    may be left "" to inherit the method's canonical topology, or set
    explicitly as a consistency assertion."""
    name: str = "experiment"
    method: str = "fedphd"
    model: str = "ddpm-unet-smoke"  # repro.configs.get_config key
    fl: FLConfig = FLConfig()
    data: DataSpec = DataSpec()
    topology: str = ""              # "" = derive from method
    selection: str = "sh"           # fedphd ablation: "sh" | "random"
    aggregation: str = "sh"         # fedphd ablation: "sh" | "fedavg"
    prune: bool = True              # fedphd only (flat methods ignore)
    engine: Optional[str] = None    # auto | vectorized | sequential
    backend: Optional[str] = None   # xla | pallas | ref compute backend
                                    # (None = $FEDPHD_BACKEND or xla);
                                    # threaded into ModelConfig.backend
    precision: Optional[str] = None  # fp32 | bf16 compute precision
                                    # (None = $FEDPHD_PRECISION or fp32);
                                    # threaded into ModelConfig.precision
    persistent_opt: bool = False
    state_store: str = "auto"       # stacked per-client state residency:
                                    # auto | device | host (host keeps
                                    # the (N,) buffers in numpy and
                                    # stages only participants per round)
    mesh: Optional[dict] = None     # {axis name -> size}, e.g.
                                    # {"data": 8, "model": 1}: lay the
                                    # round engine's client axis over
                                    # "data" (repro.launch.mesh.
                                    # make_spec_mesh); None = unsharded
    lr: float = 2e-4
    eval_every: int = 0             # 0 = never call the eval hook
    seed: int = 0
    fault: FaultSpec = FaultSpec()  # client availability / fault model
                                    # (default: disabled — bitwise
                                    # identical to the fault-free path);
                                    # sweepable as fault.* axes
    comm: CommSpec = CommSpec()     # uplink compression (repro.fl.
                                    # compress): sweepable as comm.quant
                                    # = none | int8 | fp8
    obs: ObsSpec = ObsSpec()        # tracing/metrics (repro.obs): default
                                    # disabled = bitwise no-op; enabled
                                    # resolves explicit > $FEDPHD_OBS >
                                    # off; sweepable as obs.* axes

    def replace(self, **kw) -> "ExperimentSpec":
        return dataclasses.replace(self, **kw)

    # -- JSON round-trip -----------------------------------------------------
    def to_dict(self) -> dict:
        # asdict recurses into the nested frozen FLConfig/DataSpec too
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ExperimentSpec":
        d = dict(d)
        if isinstance(d.get("fl"), dict):
            d["fl"] = fl_from_dict(d["fl"])
        if isinstance(d.get("data"), dict):
            d["data"] = DataSpec(**d["data"])
        if isinstance(d.get("fault"), dict):
            d["fault"] = FaultSpec.from_dict(d["fault"])
        if isinstance(d.get("comm"), dict):
            d["comm"] = CommSpec.from_dict(d["comm"])
        if isinstance(d.get("obs"), dict):
            d["obs"] = ObsSpec.from_dict(d["obs"])
        if isinstance(d.get("mesh"), dict):
            # JSON numbers may arrive as floats; axis sizes are ints
            d["mesh"] = {str(k): int(v) for k, v in d["mesh"].items()}
        known = {k: v for k, v in d.items()
                 if k in {f.name for f in dataclasses.fields(cls)}}
        return cls(**known)

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "ExperimentSpec":
        return cls.from_dict(json.loads(s))
