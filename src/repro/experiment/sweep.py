"""Spec-driven sweeps: axis grids over :class:`ExperimentSpec` with a
resumable on-disk manifest and an aggregation-ready result layout.

The paper's headline tables are *grids*, not single runs — Table I/II
compare methods across datasets and Dirichlet splits, and the reported
improvements are means over seeds.  A :class:`SweepSpec` declares those
grids once: a base spec plus ``axes`` mapping any (possibly nested,
dotted) ``ExperimentSpec`` field to a list of values —

    SweepSpec(name="table2",
              base=ExperimentSpec(model="ddpm-unet-smoke"),
              axes={"method": ["fedphd", "fedavg"],
                    "seed": [0, 1, 2],
                    "fl.participation": [0.5, 1.0],
                    "data.alpha": [0.1, 0.5]},
              exclude=[{"method": "fedavg", "fl.participation": 0.5}],
              include=[{"method": "fedphd", "backend": "pallas"}])

``expand()`` produces the cartesian product (plus explicit ``include``
points, minus ``exclude`` matches, deduplicated on the concrete spec)
with **stable run-ids** derived from the sorted overrides, e.g.
``fl.participation=0.5,method=fedphd,seed=2``.

``run_sweep()`` executes the grid through the existing
:func:`repro.experiment.run.run_spec` machinery and keeps a **sweep
manifest** (``sweep.json``) up to date on disk after every run.  Each
run checkpoints into its own ``runs/<run_id>/ckpt.npz`` at run_spec's
``save_every`` cadence, so a killed sweep resumes **mid-grid** (done
runs are skipped via the manifest) *and* **mid-run** (the partial
checkpoint is picked up via ``run_spec(resume=True)``, reusing the
bitwise kill-and-resume contract from the experiment API).

Execution is pluggable (:class:`Executor`): ``sequential`` (in-process,
supports a Python ``eval_fn``), ``process`` (a spawn-context process
pool for grid-level parallelism), or ``k8s``
(:class:`repro.experiment.cluster.K8sExecutor` — one containerized Job
per grid point over shared storage, testable in-memory via
``FakeCluster``).  ``run_sweep(executor=...)`` takes either a name or a
constructed executor instance.

Aggregation lives in :mod:`repro.experiment.report`; the CLI front end
is ``python -m repro.experiment.runner --sweep sweep.json``.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import os
import re
import time
import traceback
from typing import (Any, Dict, List, Mapping, NamedTuple, Optional, Sequence,
                    Tuple)

from repro.experiment.run import checkpoint_exists, run_spec
from repro.experiment.spec import ExperimentSpec

MANIFEST_FORMAT = 1
MANIFEST_NAME = "sweep.json"
EXECUTORS = ("sequential", "process", "k8s")
STATUSES = ("pending", "running", "done", "failed")


# ---------------------------------------------------------------------------
# Dotted spec paths: one namespace over ExperimentSpec and its nested
# frozen dataclasses (fl.*, data.*).
# ---------------------------------------------------------------------------

def spec_get(spec: Any, path: str) -> Any:
    """Read a (possibly dotted) field: ``spec_get(s, "fl.rounds")``.
    Works on ExperimentSpec objects and their ``to_dict()`` form."""
    obj = spec
    for part in path.split("."):
        if isinstance(obj, Mapping):
            if part not in obj:
                raise ValueError(f"unknown sweep axis {path!r}")
            obj = obj[part]
        else:
            if not hasattr(obj, part):
                raise ValueError(f"unknown sweep axis {path!r}")
            obj = getattr(obj, part)
    return obj


def spec_with(spec: ExperimentSpec,
              overrides: Mapping[str, Any]) -> ExperimentSpec:
    """Apply ``{dotted_path: value}`` overrides to a spec.  One level of
    nesting is all the spec has (``fl.*`` / ``data.*``); unknown fields
    raise ValueError naming the offending axis."""
    top: Dict[str, Any] = {}
    nested: Dict[str, Dict[str, Any]] = {}
    for path, v in overrides.items():
        head, _, rest = path.partition(".")
        if rest:
            if "." in rest:
                raise ValueError(f"sweep axis {path!r} nests too deep")
            nested.setdefault(head, {})[rest] = v
        else:
            top[head] = v
    for head, kw in nested.items():
        sub = getattr(spec, head, None)
        if not dataclasses.is_dataclass(sub):
            raise ValueError(f"unknown sweep axis {head!r} (not a nested "
                             "spec field)")
        try:
            top[head] = dataclasses.replace(sub, **kw)
        except TypeError:
            bad = sorted(set(kw) - {f.name for f in dataclasses.fields(sub)})
            raise ValueError(f"unknown sweep axis '{head}.{bad[0]}'")
    unknown = sorted(set(top) - {f.name for f in dataclasses.fields(spec)})
    if unknown:
        raise ValueError(f"unknown sweep axis {unknown[0]!r}")
    return spec.replace(**top)


# run-ids must be filesystem-safe (they name the per-run checkpoint
# directories) and stable across expansions: sorted axes, "k=v" pairs
_ID_KEEP = re.compile(r"[^A-Za-z0-9._=,+-]+")


def run_id_of(overrides: Mapping[str, Any]) -> str:
    """Stable, filesystem-safe id of one grid point (sorted overrides)."""
    if not overrides:
        return "base"
    parts = ",".join(f"{k}={overrides[k]}" for k in sorted(overrides))
    return _ID_KEEP.sub("-", parts)


class SweepRun(NamedTuple):
    """One expanded grid point: its stable id, the axis overrides that
    produced it, and the concrete spec."""
    run_id: str
    overrides: Dict[str, Any]
    spec: ExperimentSpec


# ---------------------------------------------------------------------------
# SweepSpec.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """A declarative grid over :class:`ExperimentSpec`.

    ``axes`` maps dotted spec paths to value lists; ``include`` appends
    explicit override points beyond the product; ``exclude`` drops any
    expanded point whose *effective* values (override or base) match all
    of an exclude entry's keys.  ``rounds`` optionally overrides the
    absolute target round of every run (default: each spec's
    ``fl.rounds``); ``group_by`` is the default report grouping
    (default: every non-seed axis — seeds are what mean±std runs over).
    """
    name: str = "sweep"
    base: ExperimentSpec = ExperimentSpec()
    axes: Mapping[str, Sequence[Any]] = \
        dataclasses.field(default_factory=dict)
    include: Tuple[Mapping[str, Any], ...] = ()
    exclude: Tuple[Mapping[str, Any], ...] = ()
    rounds: Optional[int] = None
    group_by: Tuple[str, ...] = ()

    def __post_init__(self):
        # canonicalize container types (lists are the natural JSON and
        # call-site form) so equality and round-trips are type-agnostic
        object.__setattr__(self, "axes",
                           {k: list(v) for k, v in self.axes.items()})
        object.__setattr__(self, "include",
                           tuple(dict(p) for p in self.include))
        object.__setattr__(self, "exclude",
                           tuple(dict(p) for p in self.exclude))
        object.__setattr__(self, "group_by", tuple(self.group_by))

    def replace(self, **kw) -> "SweepSpec":
        return dataclasses.replace(self, **kw)

    def default_group_by(self) -> Tuple[str, ...]:
        explicit = tuple(self.group_by)
        if explicit:
            return explicit
        axes = tuple(k for k in sorted(self.axes) if k != "seed")
        return axes or ("method",)

    # -- expansion -----------------------------------------------------------
    def expand(self) -> List[SweepRun]:
        """Concrete (run_id, overrides, spec) points: cartesian product
        over sorted axes, plus ``include``, minus ``exclude``, deduped
        on the concrete spec.  Deterministic order; id collisions
        between distinct specs are an error."""
        keys = sorted(self.axes)
        grid = [dict(zip(keys, combo))
                for combo in itertools.product(*(tuple(self.axes[k])
                                                 for k in keys))] \
            if keys else [{}]
        points = grid + [dict(inc) for inc in self.include]

        runs: List[SweepRun] = []
        seen_specs: Dict[str, str] = {}    # canonical spec json -> run_id
        by_id: Dict[str, str] = {}         # run_id -> canonical spec json
        for overrides in points:
            if any(self._matches(overrides, exc) for exc in self.exclude):
                continue
            spec = spec_with(self.base, overrides)
            canon = spec.to_json(indent=0)
            if canon in seen_specs:        # include duplicating a grid point
                continue
            rid = run_id_of(overrides)
            if rid in by_id:
                raise ValueError(f"run-id collision: {rid!r} maps to two "
                                 "distinct specs")
            seen_specs[canon] = rid
            by_id[rid] = canon
            runs.append(SweepRun(
                rid, dict(overrides),
                spec.replace(name=f"{self.name}/{rid}")))
        return runs

    def _matches(self, overrides: Mapping[str, Any],
                 exc: Mapping[str, Any]) -> bool:
        return all(overrides.get(k, spec_get(self.base, k)) == v
                   for k, v in exc.items())

    # -- JSON round-trip -----------------------------------------------------
    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "base": self.base.to_dict(),
            "axes": {k: list(v) for k, v in self.axes.items()},
            "include": [dict(p) for p in self.include],
            "exclude": [dict(p) for p in self.exclude],
            "rounds": self.rounds,
            "group_by": list(self.group_by),
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "SweepSpec":
        # strict: a typoed key ("axis", "excludes") must not silently
        # run a different grid than the file declares
        unknown = set(d) - {f.name for f in dataclasses.fields(cls)}
        if unknown:
            raise ValueError(f"unknown SweepSpec field(s): "
                             f"{sorted(unknown)}")
        return cls(
            name=d.get("name", "sweep"),
            base=ExperimentSpec.from_dict(d.get("base", {})),
            axes={k: list(v) for k, v in d.get("axes", {}).items()},
            include=tuple(dict(p) for p in d.get("include", ())),
            exclude=tuple(dict(p) for p in d.get("exclude", ())),
            rounds=d.get("rounds"),
            group_by=tuple(d.get("group_by", ())),
        )

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "SweepSpec":
        return cls.from_dict(json.loads(s))


# ---------------------------------------------------------------------------
# Manifest: the sweep's single source of truth on disk.
# ---------------------------------------------------------------------------

def manifest_path(out: str) -> str:
    return os.path.join(out, MANIFEST_NAME)


def load_manifest(out: str) -> Optional[dict]:
    path = manifest_path(out)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def write_manifest(out: str, man: dict) -> None:
    """Atomic write (tmp + rename): a kill mid-write must not corrupt
    the resume state."""
    path = manifest_path(out)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(man, f, indent=2, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)


def _run_ckpt(rid: str) -> str:
    # stored relative to the sweep dir so the whole tree is relocatable
    return os.path.join("runs", rid, "ckpt.npz")


def _trace_span(entry: dict, name: str, t0: float, t1: float,
                **attrs) -> None:
    """Append an executor-side span to the run's manifest trace.

    Same shape as the obs trace.jsonl span lines (repro.obs.trace) but
    with ``time.time()`` epoch stamps — manifest spans must stay
    comparable across executor invocations, since retries and
    kill-and-resume spread one run's attempts over several processes.
    Stored unconditionally: the executor path is cold (per attempt, not
    per round), so there is nothing to protect with an obs gate, and
    the queue/retry/backoff/preemption record survives in ``sweep.json``
    for ``repro.experiment.report`` to aggregate.

    Span names: ``sweep/queue`` (ready -> launch/submit),
    ``sweep/attempt`` (launch -> settle; ``attrs.outcome`` in done |
    error | timeout | worker-died | preempted | incomplete |
    submit-error), ``sweep/backoff`` (the scheduled retry delay).
    """
    entry.setdefault("trace", []).append(
        {"ev": "span", "name": name, "t0": t0, "t1": t1,
         "dur_s": t1 - t0, "attrs": attrs})


def init_manifest(sweep: SweepSpec, out: str) -> dict:
    """Create — or reconcile with — the on-disk manifest.

    An existing manifest's per-run statuses are kept for every run-id
    whose concrete spec is unchanged; runs whose spec changed (the sweep
    definition was edited) reset to pending, and run-ids no longer in
    the grid are dropped.  A fresh expansion therefore never loses
    completed work it can still trust.
    """
    runs = sweep.expand()
    prev = load_manifest(out) or {"runs": {}}
    man = {
        "format": MANIFEST_FORMAT,
        "sweep": sweep.to_dict(),
        "runs": {},
    }
    for run in runs:
        old = prev["runs"].get(run.run_id)
        spec_dict = run.spec.to_dict()
        if old is not None and old.get("spec") == spec_dict:
            man["runs"][run.run_id] = old
            # a run left "running" by a kill resumes from its checkpoint
            if old.get("status") == "running":
                old["status"] = "pending"
        else:
            man["runs"][run.run_id] = {
                "status": "pending",
                "overrides": run.overrides,
                "spec": spec_dict,
                "ckpt": _run_ckpt(run.run_id),
                "rounds_done": 0,
                "wall_s": 0.0,
                "history": [],
                "error": None,
                "attempts": 0,
                "trace": [],
            }
    os.makedirs(out, exist_ok=True)
    write_manifest(out, man)
    return man


def manifest_status(man: dict) -> Dict[str, int]:
    counts = {s: 0 for s in STATUSES}
    for entry in man["runs"].values():
        counts[entry["status"]] = counts.get(entry["status"], 0) + 1
    return counts


# ---------------------------------------------------------------------------
# Execution.
# ---------------------------------------------------------------------------

class SweepResult(NamedTuple):
    """``run_sweep``'s return: the final manifest (also on disk at
    ``manifest_path(out)``) and the sweep dir."""
    manifest: dict
    out: str

    @property
    def complete(self) -> bool:
        return all(e["status"] == "done" for e in self.manifest["runs"].values())


def _ckpt_spec_matches(ckpt: str, spec_dict: dict) -> bool:
    """Cheap pre-resume check: the per-run checkpoint manifest records
    the spec it trained under; a stale checkpoint left by an EDITED
    sweep (different spec at the same run-id path) must be rerun, not
    resumed — otherwise the manifest would silently record the old
    spec's trajectory as the new run."""
    try:
        with open(ckpt + ".manifest.json") as f:
            meta = json.load(f).get("metadata", {})
    except (OSError, ValueError):
        return False
    return meta.get("spec") == spec_dict


def _finish_entry(entry: dict, history: List[dict],
                  wall_s: float) -> None:
    entry["status"] = "done"
    entry["error"] = None
    entry["wall_s"] = float(entry.get("wall_s") or 0.0) + wall_s
    entry["history"] = history
    entry["rounds_done"] = len(history)


def _target_rounds(sweep: SweepSpec, entry: Mapping[str, Any]) -> int:
    """The absolute round a run must reach: the sweep-level override,
    else the run's own ``fl.rounds`` — so re-invoking a finished sweep
    with a larger ``rounds`` EXTENDS every run instead of silently
    reporting the old, shorter histories as complete."""
    return sweep.rounds or spec_get(entry["spec"], "fl.rounds")


def _attempt(spec_dict: dict, ckpt: str, rounds: Optional[int],
             eval_fn, save_every: int):
    """Run (or resume) ONE grid point — the shared resume-or-fresh core
    of both executors.  A retried run re-enters here and picks up the
    previous attempt's last per-round checkpoint, so a transient crash
    costs only the rounds since the last save.

    A stale checkpoint left by an EDITED sweep (different spec at the
    same run-id path) reruns fresh, not resumes."""
    t0 = time.perf_counter()
    if checkpoint_exists(ckpt) and _ckpt_spec_matches(ckpt, spec_dict):
        exp = run_spec(None, resume=True, ckpt=ckpt, rounds=rounds,
                       eval_fn=eval_fn, save_every=save_every)
    else:
        exp = run_spec(ExperimentSpec.from_dict(spec_dict), ckpt=ckpt,
                       rounds=rounds, eval_fn=eval_fn,
                       save_every=save_every)
    return ([r.to_dict() for r in exp.history],
            time.perf_counter() - t0)


@dataclasses.dataclass
class ExecContext:
    """Everything an :class:`Executor` needs beyond the manifest: the
    sweep definition and the run-level policy knobs of ``run_sweep``."""
    sweep: SweepSpec
    rounds: Optional[int] = None
    save_every: int = 1
    eval_fn: Any = None
    raise_on_error: bool = False
    timeout_s: Optional[float] = None
    max_retries: int = 0
    backoff_s: float = 1.0

    def target_rounds(self, entry: Mapping[str, Any]) -> int:
        return _target_rounds(self.sweep, entry)


class Executor:
    """One way of running the pending grid points of a sweep.

    Subclasses set the capability flags (validated centrally by
    ``run_sweep`` so every executor rejects unsupported knobs the same
    way) and implement ``run``, which must drive each run-id in
    ``order`` to ``done``/``failed`` under the shared manifest contract:
    status transitions + ``write_manifest`` after every change, retries
    with exponential backoff, quarantine on exhausted retries, and
    ``raise_on_error`` aborting the grid with the failing run's error.
    """
    name = "abstract"
    supports_eval_fn = False    # can a Python callable reach the run?
    supports_timeout = False    # can a hung attempt be killed?

    def run(self, man: dict, out: str, order: List[str],
            ctx: ExecContext) -> None:
        raise NotImplementedError


class SequentialExecutor(Executor):
    """In-process, one run at a time — the reference executor (and the
    only one a Python ``eval_fn`` can reach)."""
    name = "sequential"
    supports_eval_fn = True
    supports_timeout = False

    def run(self, man: dict, out: str, order: List[str],
            ctx: ExecContext) -> None:
        t_exec0 = time.time()
        for rid in order:
            entry = man["runs"][rid]
            ckpt = os.path.join(out, entry["ckpt"])
            os.makedirs(os.path.dirname(ckpt), exist_ok=True)
            # queue = waiting in-process behind the earlier grid points
            _trace_span(entry, "sweep/queue", t_exec0, time.time())
            last_exc = None
            for attempt in range(1, ctx.max_retries + 2):
                if attempt > 1:
                    t_b = time.time()
                    time.sleep(ctx.backoff_s * 2 ** (attempt - 2))
                    _trace_span(entry, "sweep/backoff", t_b, time.time(),
                                attempt=attempt - 1)
                entry["status"] = "running"
                entry["attempts"] = int(entry.get("attempts") or 0) + 1
                write_manifest(out, man)
                t_a = time.time()
                try:
                    history, wall_s = _attempt(entry["spec"], ckpt,
                                               ctx.rounds, ctx.eval_fn,
                                               ctx.save_every)
                except Exception as e:  # noqa: BLE001 — recorded+retried
                    last_exc = e
                    _trace_span(entry, "sweep/attempt", t_a, time.time(),
                                attempt=int(entry["attempts"]),
                                outcome="error")
                    entry["error"] = traceback.format_exc()
                    entry["status"] = "pending"  # retry-eligible until
                    write_manifest(out, man)     # the for-else quarantines
                    continue
                _trace_span(entry, "sweep/attempt", t_a, time.time(),
                            attempt=int(entry["attempts"]), outcome="done")
                _finish_entry(entry, history, wall_s)
                write_manifest(out, man)
                break
            else:
                entry["status"] = "failed"        # retries exhausted
                write_manifest(out, man)
                if ctx.raise_on_error:
                    raise last_exc


class ProcessExecutor(Executor):
    """Spawn-context process pool: one worker process per in-flight run,
    wall-clock timeouts, grid-level parallelism."""
    name = "process"
    supports_eval_fn = False
    supports_timeout = True

    def __init__(self, max_workers: Optional[int] = None):
        self.max_workers = max_workers

    def run(self, man: dict, out: str, order: List[str],
            ctx: ExecContext) -> None:
        _run_procs(man, out, order, ctx.rounds, self.max_workers,
                   ctx.save_every, ctx.raise_on_error, ctx.timeout_s,
                   ctx.max_retries, ctx.backoff_s)


def resolve_executor(executor, max_workers: Optional[int] = None):
    """Name -> Executor instance; constructed instances pass through
    (the injection point for ``K8sExecutor(cluster=FakeCluster())``)."""
    if not isinstance(executor, str):
        # duck-typed so injected executors (e.g. cluster.K8sExecutor,
        # which avoids importing this module) need not subclass Executor
        if not callable(getattr(executor, "run", None)):
            raise TypeError(f"executor must be a name from {EXECUTORS} or "
                            f"an Executor-like instance with .run(), got "
                            f"{type(executor).__name__}")
        return executor
    if executor == "sequential":
        return SequentialExecutor()
    if executor == "process":
        return ProcessExecutor(max_workers=max_workers)
    if executor == "k8s":
        from repro.experiment.cluster import K8sExecutor
        return K8sExecutor(max_workers=max_workers)
    raise ValueError(f"executor {executor!r} not in {EXECUTORS}")


def run_sweep(sweep: SweepSpec, out: str, *,
              executor: str = "sequential",
              max_workers: Optional[int] = None,
              limit: Optional[int] = None,
              eval_fn=None,
              save_every: int = 1,
              raise_on_error: bool = False,
              timeout_s: Optional[float] = None,
              max_retries: int = 0,
              backoff_s: float = 1.0) -> SweepResult:
    """Execute (or resume) a sweep into ``out``.

    The manifest at ``<out>/sweep.json`` is written before and after
    every run, and each run checkpoints through ``run_spec(ckpt=...)``,
    so a kill at ANY point resumes: completed runs are skipped, the
    interrupted run continues from its last per-round checkpoint, and
    the rest of the grid follows.  ``limit`` stops this invocation after
    that many run *attempts* — failures count, so a failing grid cannot
    spin — and the manifest stays resumable (the CI smoke job uses it
    as a deterministic "kill").

    ``executor`` is a name from :data:`EXECUTORS` or a constructed
    :class:`Executor`.  ``"process"`` fans runs out over spawn-context
    worker processes (one per in-flight run); ``"k8s"`` submits one
    containerized Job per run over shared storage
    (:mod:`repro.experiment.cluster`).  A Python ``eval_fn`` cannot
    cross either boundary (use the sequential executor, or bake evals
    into a registered method).

    Fault tolerance: a crashed run is retried up to ``max_retries``
    times with exponential backoff (``backoff_s * 2**(attempt-1)``),
    resuming from its last checkpoint each time; exhausted retries
    quarantine the run as ``status="failed"`` with the LAST attempt's
    error in ``entry["error"]`` while the rest of the grid completes
    (unless ``raise_on_error``).  ``timeout_s`` (process/k8s executors)
    kills any single attempt exceeding the wall-clock budget — a hung
    run cannot stall the grid.
    """
    exe = resolve_executor(executor, max_workers)
    if eval_fn is not None and not exe.supports_eval_fn:
        raise ValueError("eval_fn cannot cross the process boundary; "
                         "use executor='sequential'")
    if timeout_s is not None and not exe.supports_timeout:
        raise ValueError("timeout_s needs executor='process' or 'k8s' (a "
                         "hung in-process run cannot be interrupted)")
    man = init_manifest(sweep, out)
    # a "done" run re-enters the queue when the target round count grew
    # (sweep.rounds raised, or the base fl.rounds edited in place)
    order = [rid for rid, e in man["runs"].items()
             if e["status"] != "done"
             or e["rounds_done"] < _target_rounds(sweep, e)]
    if limit is not None:
        order = order[:max(limit, 0)]

    ctx = ExecContext(sweep=sweep, rounds=sweep.rounds,
                      save_every=save_every, eval_fn=eval_fn,
                      raise_on_error=raise_on_error, timeout_s=timeout_s,
                      max_retries=max_retries, backoff_s=backoff_s)
    exe.run(man, out, order, ctx)
    return SweepResult(man, out)


def _proc_worker(conn, spec_dict: dict, ckpt: str, rounds: Optional[int],
                 save_every: int) -> None:
    """Process-executor child: run ONE grid point, report the result (or
    the full traceback) back over the pipe.  Module-level for spawn
    picklability."""
    try:
        history, wall_s = _attempt(spec_dict, ckpt, rounds, None,
                                   save_every)
        conn.send(("done", history, wall_s))
    except Exception:  # noqa: BLE001 — shipped to the parent verbatim
        conn.send(("error", traceback.format_exc()))
    finally:
        conn.close()


def _run_procs(man: dict, out: str, order: List[str],
               rounds: Optional[int], max_workers: Optional[int],
               save_every: int, raise_on_error: bool,
               timeout_s: Optional[float], max_retries: int,
               backoff_s: float) -> None:
    """Process-per-run scheduler with wall-clock timeouts and retry.

    One spawn-context process per in-flight run (spawn, not fork:
    forking a process with a live JAX runtime deadlocks; spawn
    re-imports repro in each worker from PYTHONPATH), results returned
    over a Pipe.  A run whose attempt exceeds ``timeout_s`` is
    terminated (then killed) and treated like a crash; crashes requeue
    with exponential backoff until ``max_retries`` attempts are
    exhausted, then quarantine as ``failed`` without stopping the grid.
    """
    import multiprocessing as mp

    ctx = mp.get_context("spawn")
    workers = max(max_workers or min(len(order), 4), 1)
    # (rid, attempt, not_before): retries wait out their backoff here
    ready: List[Tuple[str, int, float]] = [(rid, 1, 0.0) for rid in order]
    running: Dict[str, dict] = {}
    # epoch stamp of when each rid last became launchable (executor
    # start, or backoff expiry) — the t0 of its sweep/queue span
    ready_since: Dict[str, float] = {rid: time.time() for rid in order}

    def _launch(rid: str, attempt: int) -> None:
        entry = man["runs"][rid]
        entry["status"] = "running"
        entry["attempts"] = int(entry.get("attempts") or 0) + 1
        now = time.time()
        _trace_span(entry, "sweep/queue", ready_since.pop(rid, now), now,
                    attempt=int(entry["attempts"]))
        ckpt = os.path.join(out, entry["ckpt"])
        os.makedirs(os.path.dirname(ckpt), exist_ok=True)
        recv, send = ctx.Pipe(duplex=False)
        proc = ctx.Process(target=_proc_worker,
                           args=(send, entry["spec"], ckpt, rounds,
                                 save_every))
        proc.start()
        send.close()    # parent's copy of the child end must not keep
        running[rid] = {"proc": proc, "conn": recv,     # the pipe open
                        "attempt": attempt, "t0": time.time(),
                        "deadline": (time.monotonic() + timeout_s)
                        if timeout_s else None}
        write_manifest(out, man)

    def _attempt_span(rid: str, st: dict, outcome: str) -> None:
        _trace_span(man["runs"][rid], "sweep/attempt", st["t0"],
                    time.time(),
                    attempt=int(man["runs"][rid].get("attempts") or 0),
                    outcome=outcome)

    def _fail_or_retry(rid: str, attempt: int, err: str) -> bool:
        """Record the attempt's error; requeue with backoff or
        quarantine.  Returns True when the run is terminally failed."""
        entry = man["runs"][rid]
        entry["error"] = err
        if attempt <= max_retries:
            entry["status"] = "pending"
            nb = time.monotonic() + backoff_s * 2 ** (attempt - 1)
            now = time.time()
            _trace_span(entry, "sweep/backoff", now,
                        now + backoff_s * 2 ** (attempt - 1),
                        attempt=attempt)
            ready_since[rid] = now + backoff_s * 2 ** (attempt - 1)
            ready.append((rid, attempt + 1, nb))
        else:
            entry["status"] = "failed"
        write_manifest(out, man)
        return entry["status"] == "failed"

    def _reap(rid: str) -> dict:
        st = running.pop(rid)
        st["conn"].close()
        return st

    failed_rid = None
    while (ready or running) and failed_rid is None:
        while ready and len(running) < workers:
            i = next((j for j, (_, _, nb) in enumerate(ready)
                      if nb <= time.monotonic()), None)
            if i is None:
                break
            rid, attempt, _ = ready.pop(i)
            _launch(rid, attempt)
        progressed = False
        for rid in list(running):
            st = running[rid]
            proc = st["proc"]
            if st["conn"].poll():
                msg = st["conn"].recv()
                _reap(rid)
                proc.join()
                progressed = True
                if msg[0] == "done":
                    _attempt_span(rid, st, "done")
                    _finish_entry(man["runs"][rid], msg[1], msg[2])
                    write_manifest(out, man)
                else:
                    _attempt_span(rid, st, "error")
                    if _fail_or_retry(rid, st["attempt"], msg[1]) \
                            and raise_on_error:
                        failed_rid = rid
                        break
            elif st["deadline"] is not None \
                    and time.monotonic() > st["deadline"]:
                # hung (or just slow past the budget): terminate, then
                # kill if it ignores SIGTERM — the grid must not stall
                proc.terminate()
                proc.join(5)
                if proc.is_alive():
                    proc.kill()
                    proc.join()
                _reap(rid)
                progressed = True
                _attempt_span(rid, st, "timeout")
                err = (f"TimeoutError: run exceeded "
                       f"timeout_s={timeout_s} (terminated)")
                if _fail_or_retry(rid, st["attempt"], err) \
                        and raise_on_error:
                    failed_rid = rid
                    break
            elif not proc.is_alive():
                # dead with no message: segfault / OOM-kill / external
                _reap(rid)
                progressed = True
                _attempt_span(rid, st, "worker-died")
                err = f"WorkerDied: exitcode={proc.exitcode}"
                if _fail_or_retry(rid, st["attempt"], err) \
                        and raise_on_error:
                    failed_rid = rid
                    break
        if not progressed:
            time.sleep(0.05)

    if failed_rid is not None:
        for st in running.values():    # raise_on_error: stop the grid
            st["proc"].terminate()
            st["proc"].join()
            st["conn"].close()
        write_manifest(out, man)
        raise RuntimeError(
            f"sweep run {failed_rid!r} failed after "
            f"{man['runs'][failed_rid].get('attempts')} attempt(s):\n"
            f"{man['runs'][failed_rid]['error']}")
