"""CLI for the unified experiment API.

Replaces the ad-hoc wiring in the examples: a JSON spec (or a named
preset) is the whole experiment, and ``--resume`` continues a killed run
from its checkpoint::

    PYTHONPATH=src python -m repro.experiment.runner \
        --preset smoke --rounds 1 --out runs/smoke
    PYTHONPATH=src python -m repro.experiment.runner \
        --out runs/smoke --resume --rounds 2

Outputs land in ``--out``: ``spec.json`` (the resolved spec),
``ckpt.npz`` + ``ckpt.npz.manifest.json`` (the resumable checkpoint),
and ``history.json`` (the shared RoundRecord schema, one row per round).

``--sweep grid.json`` switches to grid mode: the JSON is a
:class:`repro.experiment.sweep.SweepSpec`, every expanded run executes
(and checkpoints) under ``--out/runs/<run_id>/``, the resumable sweep
manifest lands at ``--out/sweep.json``, and the aggregated report
(mean±std across seeds, grouped by the sweep's axes) at
``--out/report.json`` + ``report.md``.  Re-invoking the same command
resumes a killed sweep — mid-grid from the manifest and mid-run from
the interrupted run's checkpoint; ``--max-runs N`` stops after N runs
(a deterministic "kill" for smoke tests)::

    PYTHONPATH=src python -m repro.experiment.runner \
        --sweep examples/sweep_smoke.json --out runs/sweep
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Optional, Sequence, Union

from repro.configs.base import FLConfig
from repro.experiment.cli import (add_compute_flags, add_metrics_flag,
                                  add_obs_flags, cli_obs_spec, write_metrics)
from repro.experiment.report import report_markdown, write_report
from repro.experiment.run import Experiment, checkpoint_exists, run_spec
from repro.experiment.spec import DataSpec, ExperimentSpec
from repro.experiment.sweep import (SweepResult, SweepSpec, manifest_status,
                                    run_sweep)
from repro.obs.metrics import summarize_trace

PRESETS = {
    # the CI smoke config: 6 clients / 2 edges on the 16x16 smoke U-Net,
    # pruning at the round-2 cloud aggregation
    "smoke": ExperimentSpec(
        name="smoke", method="fedphd", model="ddpm-unet-smoke",
        fl=FLConfig(num_clients=6, num_edges=2, local_epochs=1,
                    edge_agg_every=1, cloud_agg_every=2, rounds=4,
                    sparse_rounds=2, prune_ratio=0.44, sh_a=1000.0),
        data=DataSpec(dataset="smoke", classes_per_client=1, batch_size=32)),
    # the paper's §V setup (accelerator scale)
    "paper": ExperimentSpec(
        name="paper", method="fedphd", model="ddpm-unet-cifar10",
        fl=FLConfig(num_clients=20, num_edges=2, local_epochs=1,
                    edge_agg_every=1, cloud_agg_every=5, rounds=100,
                    sparse_rounds=50, prune_ratio=0.44, sh_a=15000.0),
        data=DataSpec(dataset="cifar10-like", classes_per_client=2,
                      batch_size=32)),
}


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro.experiment.runner", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    src = ap.add_mutually_exclusive_group()
    src.add_argument("--spec", help="path to an ExperimentSpec JSON file")
    src.add_argument("--preset", choices=sorted(PRESETS), default="smoke",
                     help="named built-in spec (default: smoke)")
    src.add_argument("--sweep", help="path to a SweepSpec JSON file: run "
                                     "the whole grid with a resumable "
                                     "manifest + aggregated report")
    ap.add_argument("--executor", choices=("sequential", "process", "k8s"),
                    help="[--sweep] run the grid in-process (default), "
                         "over a spawn-context process pool, or as one "
                         "Kubernetes Job per grid point over shared "
                         "storage")
    ap.add_argument("--max-workers", type=int,
                    help="[--sweep --executor process|k8s] pool size / "
                         "max in-flight Jobs")
    ap.add_argument("--k8s-fake", action="store_true",
                    help="[--sweep --executor k8s] drive the executor "
                         "against the in-memory FakeCluster (no cluster, "
                         "no kubernetes package — the CI smoke path)")
    ap.add_argument("--image", default="repro:latest",
                    help="[--sweep --executor k8s] container image for "
                         "worker Jobs (default: repro:latest)")
    ap.add_argument("--namespace", default=None,
                    help="[--sweep --executor k8s] Kubernetes namespace "
                         "(default: default)")
    ap.add_argument("--max-runs", type=int,
                    help="[--sweep] stop after this many run attempts "
                         "in THIS invocation (failures count); the "
                         "manifest stays resumable")
    ap.add_argument("--group-by",
                    help="[--sweep] comma-separated axes for the "
                         "aggregated report (default: the sweep's "
                         "group_by, else its non-seed axes)")
    ap.add_argument("--timeout-s", type=float,
                    help="[--sweep --executor process] wall-clock budget "
                         "per run attempt; a run exceeding it is killed "
                         "and retried/quarantined")
    ap.add_argument("--max-retries", type=int,
                    help="[--sweep] retry a crashed/hung run this many "
                         "times (exponential backoff, resuming from its "
                         "checkpoint) before quarantining it as failed "
                         "(default 0)")
    ap.add_argument("--method", help="override spec.method (registry key)")
    ap.add_argument("--engine",
                    choices=("auto", "vectorized", "sequential"),
                    help="override spec.engine")
    # the shared CLI surface (same names/semantics as python -m
    # repro.serve): --backend/--precision/--trace/--metrics
    add_compute_flags(ap)
    add_obs_flags(ap)
    add_metrics_flag(ap)
    ap.add_argument("--seed", type=int, help="override spec.seed")
    ap.add_argument("--eval-every", type=int,
                    help="override spec.eval_every (the CLI's hook DDIM-"
                         "samples 64 images and records the proxy "
                         "inception score in RoundRecord.eval)")
    ap.add_argument("--rounds", type=int,
                    help="absolute target round (default spec.fl.rounds); "
                         "with --resume, rounds already in the checkpoint "
                         "are not re-run")
    ap.add_argument("--out", default="runs/experiment",
                    help="output directory (spec/ckpt/history)")
    ap.add_argument("--save-every", type=int, default=1,
                    help="checkpoint cadence in rounds while running "
                         "(a killed run loses at most this many rounds; "
                         "0 = only save at the end)")
    ap.add_argument("--resume", action="store_true",
                    help="continue from <out>/ckpt.npz (spec overrides are "
                         "ignored; the checkpointed spec wins)")
    return ap


def _apply_overrides(spec: ExperimentSpec,
                     args: argparse.Namespace) -> ExperimentSpec:
    over = {}
    if args.method is not None:
        over["method"] = args.method
    if args.engine is not None:
        over["engine"] = args.engine
    if args.seed is not None:
        over["seed"] = args.seed
    if args.eval_every is not None:
        over["eval_every"] = args.eval_every
    if args.backend is not None:
        over["backend"] = args.backend
    if args.precision is not None:
        over["precision"] = args.precision
    if args.trace is not None:
        # --trace [PATH] -> an explicitly-enabled ObsSpec (keeps the
        # spec's other obs knobs, e.g. flush_every from a spec file)
        over["obs"] = spec.obs.replace(enabled=True,
                                       trace=args.trace or spec.obs.trace)
    return spec.replace(**over) if over else spec


def _default_eval(params, cfg, r):
    """The CLI's eval hook (active at the spec's eval_every cadence):
    reference-free sample quality — DDIM-sample a small batch and score
    it with the proxy inception score."""
    from repro.diffusion import sample_images
    from repro.metrics import inception_score_proxy
    fake = sample_images(params, cfg, n=64, steps=10, seed=0)
    return {"is_proxy": float(inception_score_proxy(fake))}


def _main_sweep(args: argparse.Namespace) -> SweepResult:
    # single-run flags have no meaning on a grid — reject rather than
    # silently run something other than what the command line asked for
    bad = [flag for flag, val in (("--method", args.method),
                                  ("--engine", args.engine),
                                  ("--seed", args.seed),
                                  ("--eval-every", args.eval_every),
                                  ("--backend", args.backend),
                                  ("--precision", args.precision),
                                  ("--trace", args.trace),
                                  ("--metrics", args.metrics))
           if val is not None]
    if args.resume:
        bad.append("--resume")
    if bad:
        raise SystemExit(
            f"--sweep is incompatible with {', '.join(bad)}: declare "
            "per-run fields in the sweep JSON (base/axes — obs.* axes "
            "cover tracing); sweep resume is automatic from the "
            "manifest and the aggregated metrics land in report.json")
    with open(args.sweep) as f:
        sweep = SweepSpec.from_json(f.read())
    if args.rounds is not None:
        sweep = sweep.replace(rounds=args.rounds)
    executor = args.executor or "sequential"
    if args.max_workers is not None and executor not in ("process", "k8s"):
        raise SystemExit("--max-workers requires --executor process "
                         "or k8s (the sequential executor runs one grid "
                         "point at a time)")
    if (args.k8s_fake or args.namespace is not None) and executor != "k8s":
        raise SystemExit("--k8s-fake/--namespace require --executor k8s")
    if executor == "k8s":
        # construct the executor here so --k8s-fake can inject the
        # in-memory cluster double (no kubernetes package needed)
        from repro.experiment.cluster import FakeCluster, K8sExecutor
        executor = K8sExecutor(
            cluster=FakeCluster() if args.k8s_fake else None,
            image=args.image, namespace=args.namespace or "default",
            max_workers=args.max_workers,
            poll_s=0.0 if args.k8s_fake else 2.0)
    # the CLI's eval hook is live only on the sequential executor (a
    # Python callable can't cross the spawn boundary) and only fires
    # where a spec's eval_every says so
    eval_fn = _default_eval if executor == "sequential" else None
    res = run_sweep(sweep, args.out, executor=executor,
                    max_workers=args.max_workers, limit=args.max_runs,
                    eval_fn=eval_fn, save_every=args.save_every,
                    timeout_s=args.timeout_s,
                    max_retries=args.max_retries or 0)
    group_by = [g.strip() for g in (args.group_by or "").split(",")
                if g.strip()] or None
    report = write_report(res.manifest, args.out, group_by=group_by)
    counts = manifest_status(res.manifest)
    print(report_markdown(report))
    print(f"[{sweep.name}] {counts['done']}/{len(res.manifest['runs'])} "
          f"runs done ({counts['pending']} pending, "
          f"{counts['failed']} failed) -> {args.out}")
    if counts["failed"]:
        raise SystemExit(f"--sweep: {counts['failed']} run(s) failed "
                         f"(see {args.out}/sweep.json)")
    return res


def main(argv: Optional[Sequence[str]] = None
         ) -> Union[Experiment, SweepResult]:
    args = build_parser().parse_args(argv)
    os.makedirs(args.out, exist_ok=True)
    if args.sweep:
        return _main_sweep(args)
    # the mirror of _main_sweep's guard: sweep-only flags are
    # meaningless on a single run — refuse rather than silently ignore
    bad = [flag for flag, val in (("--executor", args.executor),
                                  ("--max-workers", args.max_workers),
                                  ("--max-runs", args.max_runs),
                                  ("--group-by", args.group_by),
                                  ("--timeout-s", args.timeout_s),
                                  ("--max-retries", args.max_retries),
                                  ("--namespace", args.namespace))
           if val is not None]
    if args.k8s_fake:
        bad.append("--k8s-fake")
    if args.image != "repro:latest":
        bad.append("--image")
    if bad:
        raise SystemExit(f"{', '.join(bad)} require --sweep")
    ckpt = os.path.join(args.out, "ckpt.npz")

    if args.resume:
        if not checkpoint_exists(ckpt):
            raise SystemExit(f"--resume: no checkpoint at {ckpt}")
        if args.trace is not None:
            # a resumed run replays the checkpointed spec, so --trace
            # routes through the env leg of the same resolution contract
            # (an explicit enabled=False in that spec still wins); the
            # trace appends next to the checkpoint, so a custom path
            # can't be honored here
            if args.trace:
                raise SystemExit("--trace PATH is incompatible with "
                                 "--resume (the resumed trace appends to "
                                 "<out>/ckpt.npz.trace.jsonl); use bare "
                                 "--trace")
            os.environ["FEDPHD_OBS"] = "on"
        exp = run_spec(None, rounds=args.rounds, ckpt=ckpt, resume=True,
                       save_every=args.save_every, eval_fn=_default_eval)
    else:
        if args.spec:
            with open(args.spec) as f:
                spec = ExperimentSpec.from_json(f.read())
        else:
            spec = PRESETS[args.preset]
        spec = _apply_overrides(spec, args)
        exp = run_spec(spec, rounds=args.rounds, ckpt=ckpt,
                       save_every=args.save_every, eval_fn=_default_eval)

    with open(os.path.join(args.out, "spec.json"), "w") as f:
        f.write(exp.spec.to_json() + "\n")
    with open(os.path.join(args.out, "history.json"), "w") as f:
        json.dump({"spec": exp.spec.to_dict(),
                   "history": [r.to_dict() for r in exp.history]},
                  f, indent=2)
        f.write("\n")

    last = exp.history[-1]
    total_comm = sum(r.comm_gb for r in exp.history)
    print(f"[{exp.spec.name}/{exp.spec.method}] round {last.round}: "
          f"loss={last.loss:.4f} params={last.params_m:.2f}M "
          f"total_comm={total_comm:.4f}GB -> {args.out}")

    metrics = {"name": exp.spec.name, "method": exp.spec.method,
               "rounds": last.round, "loss": last.loss,
               "params_m": last.params_m, "total_comm_gb": total_comm}
    if exp.tracer.enabled:
        exp.tracer.flush()
        ts = summarize_trace(exp.tracer.path)
        metrics.update(trace=exp.tracer.path,
                       overlap_ratio=ts["overlap_ratio"],
                       compiles=ts["compiles"],
                       recompiles=ts["recompiles"])
        print(f"trace -> {exp.tracer.path} "
              f"(overlap={ts['overlap_ratio']} compiles={ts['compiles']} "
              f"recompiles={ts['recompiles']})")
    if args.metrics:
        write_metrics(args.metrics, "experiment", metrics)
        print(f"wrote metrics to {args.metrics}")
    return exp


if __name__ == "__main__":
    main()
