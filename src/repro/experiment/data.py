"""Client construction from a declarative :class:`DataSpec`."""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.data import (CELEBA_LIKE, CIFAR10_LIKE, SMOKE_DATA, ClientData,
                        dirichlet, iid, make_dataset, shards_per_client)
from repro.data.synthetic import DatasetSpec
from repro.experiment.spec import ExperimentSpec
from repro.fl.client import Client

DATASETS = {
    "smoke": SMOKE_DATA,
    "cifar10-like": CIFAR10_LIKE,
    "celeba-like": CELEBA_LIKE,
}


def dataset_spec(name: str) -> DatasetSpec:
    if name not in DATASETS:
        raise KeyError(f"unknown dataset {name!r}; available: "
                       f"{sorted(DATASETS)}")
    return DATASETS[name]


def register_dataset(name: str, ds: DatasetSpec, *,
                     overwrite: bool = False) -> None:
    """Add a synthetic dataset to the registry ``spec.data.dataset``
    resolves through (mirrors ``repro.configs.register_config``)."""
    if name in DATASETS and not overwrite:
        raise ValueError(f"dataset {name!r} already registered "
                         "(pass overwrite=True to replace)")
    DATASETS[name] = ds


def make_clients(spec: ExperimentSpec
                 ) -> Tuple[List[Client], np.ndarray, np.ndarray]:
    """Build the spec's client population.

    Returns ``(clients, images, labels)`` — the full dataset rides along
    so callers can slice real-image references for FID-style evals.
    Everything is seeded by ``spec.seed`` (dataset generation and the
    partition) plus the per-client index (each ``ClientData`` shuffle
    stream), exactly like the pre-spec hand wiring in the examples.
    """
    ds = dataset_spec(spec.data.dataset)
    images, labels = make_dataset(ds, seed=spec.seed)
    n = spec.fl.num_clients
    if spec.data.partition == "shards":
        parts = shards_per_client(labels, n, spec.data.classes_per_client,
                                  seed=spec.seed)
    elif spec.data.partition == "iid":
        parts = iid(labels, n, seed=spec.seed)
    elif spec.data.partition == "dirichlet":
        parts = dirichlet(labels, n, alpha=spec.data.alpha, seed=spec.seed)
    else:
        raise ValueError(f"unknown partition {spec.data.partition!r}")
    clients = [Client(i, ClientData(images[p], labels[p],
                                    batch_size=spec.data.batch_size, seed=i),
                      ds.num_classes)
               for i, p in enumerate(parts)]
    return clients, images, labels
