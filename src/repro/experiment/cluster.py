"""Cluster execution fabric: the ``k8s`` sweep executor.

One containerized Job per grid point over shared storage.  The executor
(:class:`K8sExecutor`) renders each pending manifest entry into a
``batch/v1`` Job (:func:`render_job`), submits it, polls pod phases,
streams failure logs into ``entry["error"]``, and reconciles the sweep
manifest from completed artifacts — reusing the retry/backoff/timeout/
quarantine semantics of the process executor and the kill-and-resume
idempotency of per-run checkpoints, so a **preempted** worker's next
attempt resumes from ``runs/<rid>/ckpt.npz`` instead of restarting.

The shared-storage contract per run-id (all under the sweep dir, which
a real cluster mounts into every pod):

    runs/<rid>/spec.json     written by the executor before submit
    runs/<rid>/ckpt.npz      written by the worker every ``save_every``
                             rounds (run_spec's checkpoint)
    runs/<rid>/result.json   written atomically by the worker ON
                             COMPLETION ONLY: {format, run_id, spec,
                             history, wall_s, rounds_done}

``result.json`` is the completion token: the executor trusts it only
when its embedded spec matches the manifest entry AND ``rounds_done``
reached the target — so a stale artifact from an edited sweep reruns,
and a sweep whose manifest was lost rebuilds purely from artifacts.

The cluster client is **injectable**: tier-1 tests drive the whole
executor against :class:`FakeCluster`, an in-memory double that runs
the worker entrypoint in-process (with deterministic preemption /
failure injection) — zero network, no kubernetes package.  The real
:class:`K8sCluster` imports ``kubernetes`` lazily and is only needed
against a live API server.

Worker entrypoint::

    python -m repro.experiment.cluster --spec ... --ckpt ... \\
        --result ... --run-id ... [--rounds N] [--save-every K]
"""
from __future__ import annotations

import argparse
import contextlib
import dataclasses
import hashlib
import io
import json
import os
import re
import time
import traceback
from typing import Any, Dict, List, Mapping, Optional, Tuple

# worker exit code for "stopped before the target round without a
# result" — what a SIGTERM'd/preempted pod looks like from the outside
PREEMPTED_EXIT = 143
RESULT_FORMAT = 1


# ---------------------------------------------------------------------------
# Shared-storage layout + artifacts.
# ---------------------------------------------------------------------------

def run_dir(out: str, rid: str) -> str:
    return os.path.join(out, "runs", rid)


def run_spec_path(out: str, rid: str) -> str:
    return os.path.join(run_dir(out, rid), "spec.json")


def run_result_path(out: str, rid: str) -> str:
    return os.path.join(run_dir(out, rid), "result.json")


def _write_json(path: str, obj: Any) -> None:
    """Atomic (tmp + rename): a pod killed mid-write must not leave a
    half result that a reconcile pass would half-trust."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f, indent=2, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)


def load_result(out: str, rid: str) -> Optional[dict]:
    """The run's completion artifact, or None (missing/corrupt)."""
    try:
        with open(run_result_path(out, rid)) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


def result_completes(res: Optional[dict], entry: Mapping[str, Any],
                     target: int) -> bool:
    """Does this artifact finish this manifest entry?  Spec must match
    (an edited sweep's stale artifact must rerun, not reconcile) and the
    recorded history must reach the target round."""
    return (res is not None and res.get("spec") == entry["spec"]
            and int(res.get("rounds_done") or 0) >= target)


# ---------------------------------------------------------------------------
# Worker entrypoint (runs inside the Job's container).
# ---------------------------------------------------------------------------

def worker_main(argv: Optional[List[str]] = None, *,
                _stop_after: Optional[int] = None) -> int:
    """Run ONE grid point from shared storage and write its result.

    Resumes from the checkpoint when one exists for the SAME spec (the
    ``_attempt`` resume-or-fresh core), so the retry of a preempted Job
    continues instead of restarting.  ``_stop_after`` is the fault hook
    used by :class:`FakeCluster`: train only that many rounds, then
    exit ``PREEMPTED_EXIT`` *without* writing ``result.json`` — exactly
    what a node preemption after ``save_every`` checkpoints looks like.
    """
    p = argparse.ArgumentParser(prog="repro.experiment.cluster")
    p.add_argument("--spec", required=True, help="spec.json path")
    p.add_argument("--ckpt", required=True, help="checkpoint path")
    p.add_argument("--result", required=True, help="result.json path")
    p.add_argument("--run-id", required=True)
    p.add_argument("--rounds", type=int, default=None,
                   help="absolute target round (default: spec fl.rounds)")
    p.add_argument("--save-every", type=int, default=1)
    args = p.parse_args(argv)

    from repro.experiment.sweep import _attempt   # lazy: imports jax
    with open(args.spec) as f:
        spec_dict = json.load(f)
    target = args.rounds or spec_dict["fl"]["rounds"]
    cap = min(target, _stop_after) if _stop_after is not None else target
    history, wall_s = _attempt(spec_dict, args.ckpt, cap, None,
                               args.save_every)
    print(f"[worker {args.run_id}] rounds {len(history)}/{target} "
          f"wall {wall_s:.2f}s")
    if len(history) < target:       # preempted before the target round:
        return PREEMPTED_EXIT       # no completion token on purpose
    _write_json(args.result, {
        "format": RESULT_FORMAT,
        "run_id": args.run_id,
        "spec": spec_dict,
        "history": history,
        "wall_s": wall_s,
        "rounds_done": len(history),
    })
    return 0


# ---------------------------------------------------------------------------
# Job spec rendering.
# ---------------------------------------------------------------------------

_NAME_BAD = re.compile(r"[^a-z0-9-]+")


def job_name(run_id: str, attempt: int) -> str:
    """DNS-1123-safe Job name: lowercased run-id with every illegal
    char collapsed to ``-``, an attempt suffix (retries must not
    collide with the dead Job's name), and a hash tiebreaker when
    truncation to 63 chars would alias distinct run-ids."""
    base = _NAME_BAD.sub("-", run_id.lower()).strip("-") or "run"
    name = f"sweep-{base}-a{attempt}"
    if len(name) > 63:
        h = hashlib.sha1(run_id.encode()).hexdigest()[:8]
        keep = 63 - len(f"sweep---{h}-a{attempt}")
        name = f"sweep-{base[:keep].strip('-')}-{h}-a{attempt}"
    return name


def render_job(*, run_id: str, attempt: int, image: str,
               spec_path: str, ckpt_path: str, result_path: str,
               rounds: Optional[int] = None, save_every: int = 1,
               namespace: str = "default",
               mount_path: Optional[str] = None,
               pvc: Optional[str] = None,
               env: Optional[Mapping[str, str]] = None,
               devices: Optional[int] = None) -> dict:
    """One manifest entry -> a ``batch/v1`` Job dict.

    ``backoffLimit=0`` / ``restartPolicy=Never``: retries belong to the
    EXECUTOR (manifest-recorded, backoff-scheduled, checkpoint-resumed),
    not to kubelet — a silently restarted pod would double-count
    attempts.  The raw run-id rides in an annotation (labels cannot
    round-trip ``=``/``.``/``,``); the container env comes from
    :func:`repro.launch.env.host_env` so workers see the same XLA/
    logging setup as local runs (``devices`` adds the host-platform
    device-count flag for CPU-sharded workers).
    """
    from repro.launch import env as launch_env
    cmd = ["python", "-m", "repro.experiment.cluster",
           "--spec", spec_path, "--ckpt", ckpt_path,
           "--result", result_path, "--run-id", run_id,
           "--save-every", str(save_every)]
    if rounds:
        cmd += ["--rounds", str(rounds)]
    env_map = launch_env.host_env(devices, tcmalloc=False)
    env_map.update(env or {})
    volumes, mounts = [], []
    if mount_path:
        src = {"persistentVolumeClaim": {"claimName": pvc}} if pvc \
            else {"hostPath": {"path": mount_path,
                               "type": "DirectoryOrCreate"}}
        volumes.append({"name": "sweep", **src})
        mounts.append({"name": "sweep", "mountPath": mount_path})
    return {
        "apiVersion": "batch/v1",
        "kind": "Job",
        "metadata": {
            "name": job_name(run_id, attempt),
            "namespace": namespace,
            "labels": {"app": "repro-sweep"},
            "annotations": {"repro.run-id": run_id,
                            "repro.attempt": str(attempt)},
        },
        "spec": {
            "backoffLimit": 0,
            "template": {
                "metadata": {"labels": {"app": "repro-sweep"}},
                "spec": {
                    "restartPolicy": "Never",
                    "containers": [{
                        "name": "run",
                        "image": image,
                        "command": cmd,
                        "env": [{"name": k, "value": str(v)}
                                for k, v in sorted(env_map.items())],
                        "volumeMounts": mounts,
                    }],
                    "volumes": volumes,
                },
            },
        },
    }


# ---------------------------------------------------------------------------
# Cluster clients.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class JobStatus:
    """Pod-phase summary of one Job: ``Pending`` | ``Running`` |
    ``Succeeded`` | ``Failed`` (+ a human reason for failures)."""
    phase: str
    reason: str = ""


class ClusterClient:
    """What :class:`K8sExecutor` needs from a cluster — four calls.
    Implemented by :class:`K8sCluster` (real) and :class:`FakeCluster`
    (in-memory test double); anything with these methods injects."""

    def submit(self, job: dict) -> str:
        raise NotImplementedError

    def status(self, name: str) -> JobStatus:
        raise NotImplementedError

    def logs(self, name: str, tail: Optional[int] = None) -> str:
        raise NotImplementedError

    def delete(self, name: str) -> None:
        raise NotImplementedError


class FakeCluster(ClusterClient):
    """In-memory cluster: Jobs "run" by invoking :func:`worker_main`
    in-process at the first non-pending ``status()`` poll, against the
    same filesystem the executor writes — the full submit/poll/resume
    loop with zero network and no kubernetes dependency.

    Fault injection (all deterministic, consumed per submission):

    preempt_once:  {run_id: stop_after_rounds} — the run's NEXT Job
                   trains that many rounds then dies ``PREEMPTED_EXIT``
                   without a result (checkpoint intact), like a node
                   preemption.
    fail_reasons:  {run_id: reason} — the run's next Job fails without
                   executing at all (image pull errors, evictions).
    fail_submits:  reject every ``submit`` — used to prove reconcile
                   completes a sweep purely from on-disk artifacts.
    pending_polls: Jobs report ``Pending`` this many polls before
                   executing (scheduler latency).
    """

    def __init__(self, *, preempt_once: Optional[Mapping[str, int]] = None,
                 fail_reasons: Optional[Mapping[str, str]] = None,
                 fail_submits: bool = False, pending_polls: int = 0):
        self.preempt_once = dict(preempt_once or {})
        self.fail_reasons = dict(fail_reasons or {})
        self.fail_submits = fail_submits
        self.pending_polls = pending_polls
        self.jobs: Dict[str, dict] = {}
        self.submitted: List[str] = []
        self.preempted: List[str] = []
        self.deleted: List[str] = []

    def submit(self, job: dict) -> str:
        if self.fail_submits:
            raise RuntimeError("FakeCluster: submit rejected "
                               "(fail_submits=True)")
        name = job["metadata"]["name"]
        if name in self.jobs:
            raise ValueError(f"duplicate Job name {name!r}")
        for key in ("apiVersion", "kind", "metadata", "spec"):
            if key not in job:
                raise ValueError(f"malformed Job: missing {key!r}")
        self.jobs[name] = {
            "job": job,
            "run_id": job["metadata"]["annotations"]["repro.run-id"],
            "status": JobStatus("Pending"),
            "polls": 0, "log": "", "done": False,
        }
        self.submitted.append(name)
        return name

    def status(self, name: str) -> JobStatus:
        st = self.jobs[name]
        if st["done"]:
            return st["status"]
        st["polls"] += 1
        if st["polls"] <= self.pending_polls:
            return JobStatus("Pending")
        rid = st["run_id"]
        if rid in self.fail_reasons:
            st["status"] = JobStatus("Failed", self.fail_reasons.pop(rid))
            st["log"] = f"injected failure: {st['status'].reason}\n"
        else:
            st["status"] = self._execute(st)
        st["done"] = True
        return st["status"]

    def _execute(self, st: dict) -> JobStatus:
        cmd = st["job"]["spec"]["template"]["spec"]["containers"][0][
            "command"]
        argv = cmd[cmd.index("repro.experiment.cluster") + 1:]
        stop = self.preempt_once.pop(st["run_id"], None)
        buf = io.StringIO()
        try:
            with contextlib.redirect_stdout(buf), \
                    contextlib.redirect_stderr(buf):
                rc = worker_main(argv, _stop_after=stop)
        except SystemExit as e:
            rc = int(e.code or 0)
        except Exception:   # noqa: BLE001 — the "pod" crashed; its
            st["log"] = buf.getvalue() + traceback.format_exc()
            return JobStatus("Failed", "Error")      # log tells why
        st["log"] = buf.getvalue()
        if rc == 0:
            return JobStatus("Succeeded")
        if rc == PREEMPTED_EXIT and stop is not None:
            self.preempted.append(st["run_id"])
            return JobStatus("Failed", "Preempted")
        return JobStatus("Failed", f"Exit({rc})")

    def logs(self, name: str, tail: Optional[int] = None) -> str:
        log = self.jobs[name]["log"]
        if tail:
            log = "\n".join(log.splitlines()[-tail:])
        return log

    def delete(self, name: str) -> None:
        self.jobs.pop(name, None)
        self.deleted.append(name)


class K8sCluster(ClusterClient):
    """Real cluster client over the ``kubernetes`` package (optional
    dependency — imported here, not at module import, so the executor
    and FakeCluster work without it)."""

    def __init__(self, namespace: str = "default"):
        try:
            from kubernetes import client, config   # noqa: PLC0415
        except ImportError as e:
            raise RuntimeError(
                "executor='k8s' against a real cluster needs the "
                "'kubernetes' package (pip install kubernetes), or "
                "inject K8sExecutor(cluster=FakeCluster()) for the "
                "in-memory double") from e
        try:
            config.load_incluster_config()
        except Exception:   # noqa: BLE001 — not in a pod: use kubeconfig
            config.load_kube_config()
        self.namespace = namespace
        self._batch = client.BatchV1Api()
        self._core = client.CoreV1Api()

    def submit(self, job: dict) -> str:
        self._batch.create_namespaced_job(
            namespace=job["metadata"].get("namespace", self.namespace),
            body=job)
        return job["metadata"]["name"]

    def status(self, name: str) -> JobStatus:
        st = self._batch.read_namespaced_job_status(
            name=name, namespace=self.namespace).status
        if st.succeeded:
            return JobStatus("Succeeded")
        if st.failed:
            reason = ""
            for cond in st.conditions or []:
                if cond.type == "Failed":
                    reason = cond.reason or ""
            return JobStatus("Failed", reason)
        return JobStatus("Running" if st.active else "Pending")

    def logs(self, name: str, tail: Optional[int] = None) -> str:
        pods = self._core.list_namespaced_pod(
            namespace=self.namespace,
            label_selector=f"job-name={name}").items
        if not pods:
            return ""
        try:
            return self._core.read_namespaced_pod_log(
                name=pods[-1].metadata.name, namespace=self.namespace,
                tail_lines=tail)
        except Exception:   # noqa: BLE001 — logs are best-effort
            return ""

    def delete(self, name: str) -> None:
        self._batch.delete_namespaced_job(
            name=name, namespace=self.namespace,
            propagation_policy="Foreground")


# ---------------------------------------------------------------------------
# The executor.
# ---------------------------------------------------------------------------

def _sweep():
    """Late import: sweep imports run -> jax; cluster must stay cheap
    to import (the CLI parses --help without a jax init)."""
    from repro.experiment import sweep
    return sweep


class K8sExecutor:
    """``run_sweep`` executor: one Job per pending grid point.

    Scheduling mirrors ``_run_procs`` (bounded in-flight set, backoff-
    delayed retries, wall-clock deadlines, quarantine on exhausted
    retries) with Jobs in place of processes and ``result.json`` in
    place of a Pipe.  Before submitting anything it reconciles: a run
    whose completion artifact already exists on shared storage (from a
    previous executor invocation that lost its manifest, or another
    submitter) is finished in place — submit-free resume.

    ``mount_path`` translates executor-side paths to container-side
    ones for a real cluster; with the default None the container sees
    the sweep dir at its host path (what FakeCluster, running
    in-process, needs).
    """
    name = "k8s"
    supports_eval_fn = False
    supports_timeout = True

    def __init__(self, *, cluster: Optional[ClusterClient] = None,
                 image: str = "repro:latest", namespace: str = "default",
                 mount_path: Optional[str] = None,
                 pvc: Optional[str] = None,
                 env: Optional[Mapping[str, str]] = None,
                 devices: Optional[int] = None,
                 max_workers: Optional[int] = None,
                 poll_s: float = 2.0):
        self.cluster = cluster
        self.image = image
        self.namespace = namespace
        self.mount_path = mount_path
        self.pvc = pvc
        self.env = dict(env or {})
        self.devices = devices
        self.max_workers = max_workers
        self.poll_s = poll_s

    def _cpath(self, out: str, rel: str) -> str:
        """Executor-relative path -> container path."""
        return os.path.join(self.mount_path or out, rel)

    def run(self, man: dict, out: str, order: List[str], ctx) -> None:
        sweep = _sweep()
        cluster = self.cluster
        if cluster is None:
            cluster = K8sCluster(namespace=self.namespace)

        # --- reconcile: completed artifacts finish entries submit-free
        pending: List[str] = []
        for rid in order:
            entry = man["runs"][rid]
            res = load_result(out, rid)
            if result_completes(res, entry, ctx.target_rounds(entry)):
                sweep._finish_entry(entry, res["history"],
                                    float(res.get("wall_s") or 0.0))
                sweep.write_manifest(out, man)
            else:
                pending.append(rid)

        workers = max(self.max_workers or min(len(pending), 4), 1)
        # (rid, attempt, not_before): retries wait out their backoff
        ready: List[Tuple[str, int, float]] = [(rid, 1, 0.0)
                                               for rid in pending]
        running: Dict[str, dict] = {}
        # epoch stamp of when each rid became submittable (executor
        # start / backoff expiry) — the t0 of its sweep/queue span
        ready_since: Dict[str, float] = {rid: time.time()
                                         for rid in pending}

        def _submit(rid: str, attempt: int) -> None:
            entry = man["runs"][rid]
            entry["status"] = "running"
            entry["attempts"] = int(entry.get("attempts") or 0) + 1
            now = time.time()
            sweep._trace_span(entry, "sweep/queue",
                              ready_since.pop(rid, now), now,
                              attempt=int(entry["attempts"]))
            os.makedirs(run_dir(out, rid), exist_ok=True)
            _write_json(run_spec_path(out, rid), entry["spec"])
            # a stale completion token must not satisfy the poll below
            with contextlib.suppress(OSError):
                os.remove(run_result_path(out, rid))
            job = render_job(
                run_id=rid, attempt=int(entry["attempts"]),
                image=self.image,
                spec_path=self._cpath(out, f"runs/{rid}/spec.json"),
                ckpt_path=self._cpath(out, entry["ckpt"]),
                result_path=self._cpath(out, f"runs/{rid}/result.json"),
                rounds=ctx.rounds, save_every=ctx.save_every,
                namespace=self.namespace, mount_path=self.mount_path,
                pvc=self.pvc, env=self.env, devices=self.devices)
            t_sub = time.time()
            try:
                name = cluster.submit(job)
            except Exception:   # noqa: BLE001 — a rejected submit is an
                sweep._trace_span(entry, "sweep/attempt", t_sub,
                                  time.time(),
                                  attempt=int(entry["attempts"]),
                                  outcome="submit-error")
                _fail_or_retry(rid, attempt,    # attempt like any other
                               "SubmitError:\n" + traceback.format_exc())
                return
            running[rid] = {
                "name": name, "attempt": attempt, "t0": t_sub,
                "deadline": (time.monotonic() + ctx.timeout_s)
                if ctx.timeout_s else None,
            }
            sweep.write_manifest(out, man)

        failed_rid = None

        def _attempt_span(rid: str, st: dict, outcome: str) -> None:
            sweep._trace_span(man["runs"][rid], "sweep/attempt",
                              st["t0"], time.time(),
                              attempt=int(man["runs"][rid].get("attempts")
                                          or 0),
                              outcome=outcome)

        def _fail_or_retry(rid: str, attempt: int, err: str) -> None:
            nonlocal failed_rid
            entry = man["runs"][rid]
            entry["error"] = err
            if attempt <= ctx.max_retries:
                entry["status"] = "pending"
                delay = ctx.backoff_s * 2 ** (attempt - 1)
                now = time.time()
                sweep._trace_span(entry, "sweep/backoff", now, now + delay,
                                  attempt=attempt)
                ready_since[rid] = now + delay
                ready.append((rid, attempt + 1, time.monotonic() + delay))
            else:
                entry["status"] = "failed"
                if ctx.raise_on_error:
                    failed_rid = rid
            sweep.write_manifest(out, man)

        def _settle(rid: str) -> None:
            """The run's Job finished or timed out — judge by artifact."""
            st = running.pop(rid)
            entry = man["runs"][rid]
            status = cluster.status(st["name"])
            if status.phase == "Succeeded":
                res = load_result(out, rid)
                if result_completes(res, entry, ctx.target_rounds(entry)):
                    _attempt_span(rid, st, "done")
                    sweep._finish_entry(entry, res["history"],
                                        float(res.get("wall_s") or 0.0))
                    sweep.write_manifest(out, man)
                    return
                _attempt_span(rid, st, "incomplete")
                _fail_or_retry(rid, st["attempt"],
                               "IncompleteResult: Job succeeded but "
                               "result.json is missing, stale, or short "
                               "of the target round")
                return
            # a preempted worker (SIGTERM'd mid-run, checkpoint intact)
            # is first-class in the trace: its retry resumes, and the
            # manifest records how often the cluster preempted this run
            _attempt_span(rid, st,
                          "preempted" if status.reason == "Preempted"
                          else "error")
            tail = cluster.logs(st["name"], tail=20)
            _fail_or_retry(rid, st["attempt"],
                           f"JobFailed({status.reason or 'unknown'}):\n"
                           f"{tail}")

        while (ready or running) and failed_rid is None:
            while ready and len(running) < workers and failed_rid is None:
                i = next((j for j, (_, _, nb) in enumerate(ready)
                          if nb <= time.monotonic()), None)
                if i is None:
                    break
                rid, attempt, _ = ready.pop(i)
                _submit(rid, attempt)
            progressed = False
            for rid in list(running):
                if failed_rid is not None:
                    break
                st = running[rid]
                phase = cluster.status(st["name"]).phase
                if phase in ("Succeeded", "Failed"):
                    progressed = True
                    _settle(rid)
                elif st["deadline"] is not None \
                        and time.monotonic() > st["deadline"]:
                    progressed = True
                    cluster.delete(st["name"])
                    running.pop(rid)
                    _attempt_span(rid, st, "timeout")
                    _fail_or_retry(rid, st["attempt"],
                                   f"TimeoutError: Job exceeded "
                                   f"timeout_s={ctx.timeout_s} (deleted)")
            if not progressed and (ready or running):
                time.sleep(self.poll_s if self.poll_s > 0 else 0.01)

        if failed_rid is not None:
            for st in running.values():   # raise_on_error: stop the grid
                with contextlib.suppress(Exception):
                    cluster.delete(st["name"])
            sweep.write_manifest(out, man)
            raise RuntimeError(
                f"sweep run {failed_rid!r} failed after "
                f"{man['runs'][failed_rid].get('attempts')} attempt(s):\n"
                f"{man['runs'][failed_rid]['error']}")


if __name__ == "__main__":
    raise SystemExit(worker_main())
