"""The uniform ``Trainer`` protocol every registered method satisfies.

``FedPhD`` (core/hfl.py) and ``FlatTrainer`` (fl/baselines.py) both
implement it; anything registered via
:func:`repro.experiment.register_method` must too.
"""
from __future__ import annotations

from typing import Any, Dict, List, Protocol, Tuple, runtime_checkable

from repro.fl.record import RoundRecord, RunResult


@runtime_checkable
class Trainer(Protocol):
    """One federated trainer, round-stepped and checkpointable.

    - ``history`` accumulates one :class:`RoundRecord` per round in the
      shared schema (round, loss, comm_gb, params_m, selected, eval,
      optional edge_sh/pruned).
    - ``eval_fn(params, cfg, round)`` is called every ``eval_every``
      rounds inside ``run_round`` and its result stored in
      ``RoundRecord.eval``.
    - ``state()`` returns ``(arrays, meta)`` — an array pytree for
      ``repro.checkpoint.save`` plus JSON-serializable metadata (RNG
      streams, history, config mutations) — and ``restore(arrays,
      meta)`` on a freshly constructed trainer with identical
      constructor arguments resumes the run: bitwise-identical to an
      unbroken run on the sequential engine.
    """

    history: List[RoundRecord]
    params: Any

    def run_round(self, r: int) -> RoundRecord: ...

    def run(self, rounds: int) -> RunResult: ...

    def state(self) -> Tuple[Any, Dict[str, Any]]: ...

    def restore(self, arrays: Any, meta: Dict[str, Any]) -> None: ...
