"""Shared CLI surface for the repo's entry points.

``python -m repro.experiment.runner`` and ``python -m repro.serve``
speak the same flag names (``--out``, ``--metrics``, ``--backend``,
``--precision``, ``--trace``) and write ONE JSON metrics schema, so CI
and sweep tooling parse either with the same code:

  {"schema": 1, "kind": "experiment" | "serve", <flat metric keys>}

The metric keys stay flat (no nesting) — existing consumers index
``m["compiles"]`` etc. directly and the envelope only adds keys.

``--trace`` is the CLI face of the obs layer: bare ``--trace`` enables
tracing at the entry point's default path, ``--trace path.jsonl`` pins
the path, and omitting it defers to ``$FEDPHD_OBS`` (the single
resolution contract of :mod:`repro.experiment.resolve`).
"""
from __future__ import annotations

import argparse
import json
from typing import Optional

from repro.experiment.resolve import BACKENDS, PRECISIONS
from repro.obs.spec import ObsSpec
from repro.obs.trace import make_tracer

METRICS_SCHEMA = 1


def add_compute_flags(ap: argparse.ArgumentParser) -> None:
    """The shared compute knobs (override the config/checkpoint; the
    usual precedence: explicit > $FEDPHD_* > config default)."""
    ap.add_argument("--backend", default=None, choices=BACKENDS,
                    help="compute backend override (default: the spec/"
                         "checkpoint's, else $FEDPHD_BACKEND/xla)")
    ap.add_argument("--precision", default=None, choices=PRECISIONS,
                    help="compute precision override (default: the spec/"
                         "checkpoint's, else $FEDPHD_PRECISION/fp32)")


def add_obs_flags(ap: argparse.ArgumentParser) -> None:
    """``--trace [PATH]``: enable obs tracing (bare flag = the entry
    point's default trace.jsonl location)."""
    ap.add_argument("--trace", nargs="?", const="", default=None,
                    metavar="PATH",
                    help="enable obs tracing; optional trace.jsonl path "
                         "(bare --trace writes next to the run's output; "
                         "omitted entirely defers to $FEDPHD_OBS)")


def add_metrics_flag(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--metrics", default=None,
                    help="write the unified JSON metrics file here "
                         "(schema: flat keys + {schema, kind})")


def cli_obs_spec(trace_arg: Optional[str]) -> ObsSpec:
    """``--trace`` value -> ObsSpec: flag present = explicitly enabled
    (with its path, if given); absent = tri-state None, i.e. defer to
    ``$FEDPHD_OBS``."""
    if trace_arg is None:
        return ObsSpec()
    return ObsSpec(enabled=True, trace=trace_arg)


def make_cli_tracer(trace_arg: Optional[str],
                    default_path: Optional[str] = None):
    """Build the entry point's tracer straight from its ``--trace``
    value (entry points without an ExperimentSpec, e.g. serve)."""
    return make_tracer(cli_obs_spec(trace_arg), default_path=default_path)


def write_metrics(path: str, kind: str, metrics: dict) -> None:
    """The one metrics writer: flat metric keys under a shared
    ``{schema, kind}`` envelope."""
    payload = {"schema": METRICS_SCHEMA, "kind": kind, **metrics}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
