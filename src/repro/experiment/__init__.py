"""Unified experiment API: spec -> trainer -> run -> resume.

One declarative front door over FedPhD's hierarchical loop and all flat
baselines::

    from repro.experiment import ExperimentSpec, run_spec

    spec = ExperimentSpec(method="fedphd", model="ddpm-unet-smoke")
    exp = run_spec(spec, ckpt="runs/smoke/ckpt.npz")
    exp.history[-1].loss          # shared RoundRecord schema

    # later / elsewhere: continue the killed run
    exp = run_spec(None, resume=True, ckpt="runs/smoke/ckpt.npz")

CLI: ``python -m repro.experiment.runner --help``.
"""
from repro.experiment.data import (DATASETS, dataset_spec, make_clients,
                                   register_dataset)
from repro.experiment.registry import (MethodEntry, make_trainer,
                                       method_entry, register_method,
                                       registered_methods)
from repro.experiment.report import (build_report, report_markdown,
                                     run_scalars, write_report)
from repro.experiment.run import (Experiment, checkpoint_exists, run_spec)
from repro.experiment.spec import (TOPOLOGIES, DataSpec, ExperimentSpec)
from repro.experiment.cluster import (ClusterClient, FakeCluster, JobStatus,
                                      K8sCluster, K8sExecutor, render_job,
                                      worker_main)
from repro.experiment.sweep import (EXECUTORS, ExecContext, Executor,
                                    ProcessExecutor, SequentialExecutor,
                                    SweepResult, SweepRun, SweepSpec,
                                    load_manifest, manifest_path,
                                    manifest_status, resolve_executor,
                                    run_id_of, run_sweep, spec_get,
                                    spec_with)
from repro.experiment.trainer import Trainer
from repro.fl.faults import FaultModel, FaultSpec
from repro.fl.record import RoundRecord, RunResult, evals_of

__all__ = ["DATASETS", "dataset_spec", "make_clients", "register_dataset",
           "MethodEntry",
           "make_trainer", "method_entry", "register_method",
           "registered_methods", "Experiment", "checkpoint_exists",
           "run_spec", "TOPOLOGIES", "DataSpec", "ExperimentSpec",
           "FaultModel", "FaultSpec",
           "Trainer", "RoundRecord", "RunResult", "evals_of",
           "SweepResult", "SweepRun", "SweepSpec", "load_manifest",
           "manifest_path", "manifest_status", "run_id_of", "run_sweep",
           "spec_get", "spec_with",
           "EXECUTORS", "ExecContext", "Executor", "ProcessExecutor",
           "SequentialExecutor", "resolve_executor",
           "ClusterClient", "FakeCluster", "JobStatus", "K8sCluster",
           "K8sExecutor", "render_job", "worker_main",
           "build_report", "report_markdown", "run_scalars",
           "write_report"]
