"""Unified experiment API: spec -> trainer -> run -> resume.

One declarative front door over FedPhD's hierarchical loop and all flat
baselines::

    from repro.experiment import ExperimentSpec, run_spec

    spec = ExperimentSpec(method="fedphd", model="ddpm-unet-smoke")
    exp = run_spec(spec, ckpt="runs/smoke/ckpt.npz")
    exp.history[-1].loss          # shared RoundRecord schema

    # later / elsewhere: continue the killed run
    exp = run_spec(None, resume=True, ckpt="runs/smoke/ckpt.npz")

CLI: ``python -m repro.experiment.runner --help``.

Re-exports resolve lazily (PEP 562): ``repro.experiment.resolve`` is a
dependency-free leaf that ``repro.models.ops`` and ``repro.fl.engine``
import at module scope for the single ``$FEDPHD_*`` knob code path, so
importing this package must not eagerly pull the trainer stack in (that
would be circular: run -> registry -> hfl -> models.ops -> here).
"""
from importlib import import_module

# public name -> defining submodule ("." = repro.experiment.<mod>)
_EXPORTS = {
    "DATASETS": ".data", "dataset_spec": ".data", "make_clients": ".data",
    "register_dataset": ".data",
    "MethodEntry": ".registry", "make_trainer": ".registry",
    "method_entry": ".registry", "register_method": ".registry",
    "registered_methods": ".registry",
    "build_report": ".report", "report_markdown": ".report",
    "run_scalars": ".report", "write_report": ".report",
    "Experiment": ".run", "checkpoint_exists": ".run",
    "default_trace_path": ".run", "run_spec": ".run",
    "TOPOLOGIES": ".spec", "DataSpec": ".spec", "ExperimentSpec": ".spec",
    "ObsSpec": ".spec",
    "ClusterClient": ".cluster", "FakeCluster": ".cluster",
    "JobStatus": ".cluster", "K8sCluster": ".cluster",
    "K8sExecutor": ".cluster", "render_job": ".cluster",
    "worker_main": ".cluster",
    "EXECUTORS": ".sweep", "ExecContext": ".sweep", "Executor": ".sweep",
    "ProcessExecutor": ".sweep", "SequentialExecutor": ".sweep",
    "SweepResult": ".sweep", "SweepRun": ".sweep", "SweepSpec": ".sweep",
    "load_manifest": ".sweep", "manifest_path": ".sweep",
    "manifest_status": ".sweep", "resolve_executor": ".sweep",
    "run_id_of": ".sweep", "run_sweep": ".sweep", "spec_get": ".sweep",
    "spec_with": ".sweep",
    "Trainer": ".trainer",
    "KNOBS": ".resolve", "resolve_knob": ".resolve",
    "FaultModel": "repro.fl.faults", "FaultSpec": "repro.fl.faults",
    "RoundRecord": "repro.fl.record", "RunResult": "repro.fl.record",
    "evals_of": "repro.fl.record",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    target = _EXPORTS.get(name)
    if target is not None:
        mod = import_module(target, __name__) if target.startswith(".") \
            else import_module(target)
        value = getattr(mod, name)
        globals()[name] = value        # cache: resolve each name once
        return value
    # fall through to submodule access (repro.experiment.runner etc.)
    try:
        return import_module("." + name, __name__)
    except ImportError:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}") from None


def __dir__():
    return sorted(set(globals()) | set(__all__))
