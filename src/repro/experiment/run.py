"""Experiment driver: spec -> trainer -> run -> checkpoint/resume.

``Experiment`` materializes a spec (model config via the config
registry, clients via the data spec, trainer via the method registry)
and drives rounds; ``save``/``load`` wire the uniform
``Trainer.state()/restore()`` contract through ``repro.checkpoint`` so
any method — hierarchical or flat, pre- or post-prune, with persistent
per-client state — can be killed and resumed.  A resumed run reproduces
an unbroken one bitwise on the sequential engine (atol-1e-5 on the
vectorized engine); ``tests/test_experiment_api.py`` locks this.
"""
from __future__ import annotations

import os
from typing import Any, Callable, List, Optional

from repro import checkpoint
from repro.configs import get_config
from repro.experiment.data import make_clients
from repro.experiment.registry import make_trainer
from repro.experiment.spec import ExperimentSpec
from repro.fl.client import Client
from repro.fl.record import RoundRecord
from repro.obs.trace import make_tracer

CKPT_FORMAT = 1


class Experiment:
    """A spec bound to live state: clients + trainer + history.

    ``clients`` may be injected (custom populations); by default they
    are built from ``spec.data``, and ``images``/``labels`` keep the
    full generated dataset for eval references.  ``eval_fn(params, cfg,
    round)`` runs every ``spec.eval_every`` rounds, its result stored in
    ``RoundRecord.eval``.
    """

    def __init__(self, spec: ExperimentSpec, *,
                 clients: Optional[List[Client]] = None,
                 eval_fn: Optional[Callable] = None,
                 trace_path: Optional[str] = None):
        self.spec = spec
        self.model_cfg = get_config(spec.model)
        if spec.backend:
            # thread the spec's compute backend into the model config —
            # the trainer resolves ""/$FEDPHD_BACKEND at construction
            self.model_cfg = self.model_cfg.replace(backend=spec.backend)
        if spec.precision:
            # same contract for the precision axis ($FEDPHD_PRECISION)
            self.model_cfg = self.model_cfg.replace(precision=spec.precision)
        self.images = self.labels = None
        if clients is None:
            clients, self.images, self.labels = make_clients(spec)
        self.clients = clients
        # NULL_TRACER when spec.obs resolves disabled — make_trainer then
        # skips the bind entirely and the trainers keep their default
        # no-op tracer (the bitwise-no-op invariant)
        self.tracer = make_tracer(spec.obs, default_path=trace_path)
        self.trainer = make_trainer(spec, self.model_cfg, clients, eval_fn,
                                    tracer=self.tracer)

    # current (possibly post-prune) model config / params / history
    @property
    def cfg(self):
        return self.trainer.cfg

    @property
    def params(self):
        return self.trainer.params

    @property
    def history(self) -> List[RoundRecord]:
        return self.trainer.history

    @property
    def next_round(self) -> int:
        return len(self.trainer.history) + 1

    def run(self, rounds: Optional[int] = None, *,
            ckpt: Optional[str] = None,
            save_every: int = 0) -> List[RoundRecord]:
        """Advance to round ``rounds`` (absolute; default
        ``spec.fl.rounds``).  No-op if the history is already there.

        With ``ckpt`` and ``save_every=k``, a checkpoint is written
        every k rounds mid-run, so a killed run loses at most k rounds
        (the final save after the loop is the caller's job — see
        ``run_spec``)."""
        target = rounds or self.spec.fl.rounds
        for r in range(self.next_round, target + 1):
            self.trainer.run_round(r)
            if ckpt and save_every and r % save_every == 0 and r < target:
                self.save(ckpt)
        self.tracer.flush()
        return self.trainer.history

    # -- checkpointing -------------------------------------------------------
    def save(self, path: str) -> None:
        """One-file checkpoint (npz + manifest): trainer arrays, RNG
        streams, history, and the spec itself — ``Experiment.load``
        needs nothing else."""
        arrays, meta = self.trainer.state()
        meta = {**meta, "spec": self.spec.to_dict(), "format": CKPT_FORMAT}
        checkpoint.save(path, arrays, metadata=meta)

    @classmethod
    def load(cls, path: str, *, clients: Optional[List[Client]] = None,
             eval_fn: Optional[Callable] = None) -> "Experiment":
        """Rebuild the experiment from its checkpoint and resume state.
        ``clients``/``eval_fn`` must be re-supplied only when the
        original run injected custom ones.  A traced run's tracer is
        rebuilt too (append mode: the trace grows a new in-band meta
        line per session, so kill-and-resume leaves prior spans
        intact)."""
        arrays, meta = checkpoint.load(path)
        spec = ExperimentSpec.from_dict(meta["spec"])
        exp = cls(spec, clients=clients, eval_fn=eval_fn,
                  trace_path=default_trace_path(path))
        exp.trainer.restore(arrays, meta)
        return exp


def checkpoint_exists(path: str) -> bool:
    return os.path.exists(path + ".manifest.json")


def default_trace_path(ckpt: Optional[str]) -> Optional[str]:
    """Where a traced run writes when ``obs.trace`` is unset: next to
    the checkpoint (``<ckpt>.trace.jsonl``), or None (-> ``trace.jsonl``
    in the CWD, see :func:`repro.obs.trace.make_tracer`)."""
    return (ckpt + ".trace.jsonl") if ckpt else None


def run_spec(spec: Optional[ExperimentSpec], *, rounds: Optional[int] = None,
             clients: Optional[List[Client]] = None,
             eval_fn: Optional[Callable] = None,
             ckpt: Optional[str] = None, resume: bool = False,
             save_every: int = 1) -> Experiment:
    """The one-call front door: build (or resume) and run an experiment.

    ``ckpt`` names a checkpoint file; with ``resume=True`` an existing
    checkpoint is loaded and the run continues from its round counter
    (``spec`` must then be ``None`` — the checkpointed spec is the
    experiment; pass overrides like the target round via ``rounds``).
    When ``ckpt`` is given the state is saved every ``save_every``
    rounds (so a killed run is actually resumable) and once more after
    the final round.
    """
    if resume:
        if spec is not None:
            raise ValueError("resume=True loads the checkpointed spec; "
                             "pass spec=None (use rounds= to extend the "
                             "run)")
        if not (ckpt and checkpoint_exists(ckpt)):
            raise FileNotFoundError(f"resume requested but no checkpoint at "
                                    f"{ckpt!r}")
        exp = Experiment.load(ckpt, clients=clients, eval_fn=eval_fn)
    else:
        exp = Experiment(spec, clients=clients, eval_fn=eval_fn,
                         trace_path=default_trace_path(ckpt))
    exp.run(rounds, ckpt=ckpt, save_every=save_every)
    if ckpt:
        exp.save(ckpt)
    exp.tracer.flush()
    return exp
