"""The one `$FEDPHD_*` knob-resolution code path.

Every run-shaping knob the repo reads from the environment — the round
engine, the compute backend, the compute precision, and the obs
(tracing) switch — resolves through :func:`resolve_knob` with the same
precedence contract::

    explicit argument  >  $FEDPHD_<KNOB>  >  default

An explicit ``""``/``None`` means "not set" and falls through to the
env var; an env var set to ``""`` likewise falls through to the
default (so ``FEDPHD_BACKEND= pytest ...`` behaves like unset).  An
unrecognized value raises ``ValueError`` at resolution time — never a
silent fallback — so a typo'd CI matrix leg fails fast instead of
quietly re-running the default path.

This module is a dependency-free leaf (stdlib only): it is imported at
module scope by ``repro.models.ops`` and ``repro.fl.engine``, which sit
below ``repro.experiment`` in the import graph.  That works because
``repro/experiment/__init__.py`` re-exports its public API lazily
(PEP 562), so ``import repro.experiment.resolve`` never drags the
trainer stack in.  The historical per-module helpers —
``repro.models.ops.resolve_backend``/``resolve_precision`` and
``repro.fl.engine.resolve_engine`` — survive as thin wrappers over
:func:`resolve_knob`; the precedence logic lives only here.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Optional, Tuple

ENGINES = ("auto", "vectorized", "sequential")
BACKENDS = ("xla", "pallas", "ref")
PRECISIONS = ("fp32", "bf16")
OBS_MODES = ("off", "on")

# $FEDPHD_OBS accepts the usual boolean spellings; they normalize onto
# OBS_MODES before the membership check.
_OBS_ALIASES = {"1": "on", "true": "on", "yes": "on",
                "0": "off", "false": "off", "no": "off"}


@dataclasses.dataclass(frozen=True)
class Knob:
    """One resolvable knob: its env var, legal values, and default."""
    name: str
    env: str
    choices: Tuple[str, ...]
    default: str

    def normalize(self, value: str) -> str:
        if self.name == "obs":
            value = _OBS_ALIASES.get(value.lower(), value.lower())
        return value


KNOBS = {
    "engine": Knob("engine", "FEDPHD_ENGINE", ENGINES, "auto"),
    "backend": Knob("backend", "FEDPHD_BACKEND", BACKENDS, "xla"),
    "precision": Knob("precision", "FEDPHD_PRECISION", PRECISIONS, "fp32"),
    "obs": Knob("obs", "FEDPHD_OBS", OBS_MODES, "off"),
}


def resolve_knob(name: str, explicit: Optional[str] = None) -> str:
    """Resolve knob ``name``: ``explicit > $<knob.env> > knob.default``."""
    knob = KNOBS[name]
    source = "explicit" if explicit else \
        ("env" if os.environ.get(knob.env) else "default")
    value = knob.normalize(explicit or os.environ.get(knob.env, "")
                           or knob.default)
    if value not in knob.choices:
        raise ValueError(
            f"unknown {knob.name} {value!r} (from {source}); expected one "
            f"of {knob.choices}")
    return value


def knob_source(name: str, explicit: Optional[str] = None) -> str:
    """Where the resolved value came from: explicit | env | default."""
    knob = KNOBS[name]
    if explicit:
        return "explicit"
    return "env" if os.environ.get(knob.env) else "default"


def validate_env(name: str) -> Optional[str]:
    """Fail fast on a typo'd ``$FEDPHD_*`` value (the conftest matrix
    fixtures); returns the raw env value ("" and unset both -> None)."""
    knob = KNOBS[name]
    raw = os.environ.get(knob.env)
    if not raw:
        return None
    if knob.normalize(raw) not in knob.choices:
        raise RuntimeError(f"{knob.env}={raw!r}; expected one of "
                           f"{knob.choices}")
    return raw


def resolve_engine(engine: Optional[str] = None) -> Tuple[str, bool]:
    """Resolve an engine choice to ``(engine, strict)``.

    An explicit caller argument wins and is strict; ``None`` falls back
    to ``$FEDPHD_ENGINE`` (the CI matrix knob, consumed via the
    conftest fixture) and finally ``"auto"``.  A strict "vectorized"
    raises on ragged clients; a non-strict one (env-selected) falls
    back to the sequential path with a warning so suites that mix
    ragged fixtures stay green under the matrix.
    """
    return resolve_knob("engine", engine), engine is not None


def resolve_backend(backend: Optional[str] = None) -> str:
    """Explicit choice > ``$FEDPHD_BACKEND`` > ``"xla"``."""
    return resolve_knob("backend", backend)


def resolve_precision(precision: Optional[str] = None) -> str:
    """Explicit choice > ``$FEDPHD_PRECISION`` > ``"fp32"``."""
    return resolve_knob("precision", precision)


def resolve_obs(obs: Optional[str] = None) -> bool:
    """Explicit choice > ``$FEDPHD_OBS`` > off; returns the enabled bool."""
    return resolve_knob("obs", obs) == "on"
