"""Sweep aggregation: group-by over the manifest, mean±std across runs.

One report schema over every sweep (the layer the paper's tables — and
every future scaling PR — report through):

    {"format": 1, "sweep": "<name>", "group_by": ["method", ...],
     "total_runs": N, "done": k, "complete": bool,
     "groups": [{"key": {"method": "fedphd"}, "n": 3, "runs": [ids...],
                 "metrics": {"loss": {"mean":.., "std":.., "min":..,
                                      "max":.., "n": 3}, ...}}]}

Per-run scalars (``run_scalars``): final-round ``loss``, total
``comm_gb`` over the run, final ``params_m``, executor ``wall_s``, and
every numeric key of the last recorded eval as ``eval.<key>`` — so an
``eval_fn`` returning ``{"fid": ...}`` aggregates as ``eval.fid``.
Groups are keyed by the *effective* value of each group-by axis
(override if the axis varied, base-spec value otherwise); mean±std runs
over whatever remains inside a group — canonically the seed axis.

``report_markdown`` renders the same data as a GitHub-flavored table;
``write_report`` emits both ``report.json`` and ``report.md`` next to
the sweep manifest.
"""
from __future__ import annotations

import json
import math
import os
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

from repro.experiment.sweep import spec_get

REPORT_FORMAT = 1

# canonical column order: the shared RoundRecord scalars first, then
# wall-clock, then eval.* alphabetically
_METRIC_ORDER = ("loss", "comm_gb", "params_m", "wall_s")


def run_scalars(entry: Mapping[str, Any]) -> Dict[str, float]:
    """The aggregatable scalars of one manifest run entry."""
    hist = entry.get("history") or []
    if not hist:
        return {}
    last = hist[-1]
    out = {
        "loss": float(last["loss"]),
        "comm_gb": float(sum(r["comm_gb"] for r in hist)),
        "params_m": float(last["params_m"]),
    }
    if entry.get("wall_s"):
        out["wall_s"] = float(entry["wall_s"])
    # executor-side trace spans (sweep.json entry["trace"]): surface the
    # scheduling story — attempts, time queued, time lost to retries
    trace = entry.get("trace") or []
    if entry.get("attempts"):
        out["attempts"] = float(entry["attempts"])
    queue_s = sum(s["dur_s"] for s in trace
                  if s.get("name") == "sweep/queue")
    # retry cost = scheduled backoff windows + wall time of every
    # attempt that did NOT complete the run
    retry_s = sum(
        s["dur_s"] for s in trace
        if s.get("name") == "sweep/backoff"
        or (s.get("name") == "sweep/attempt"
            and s.get("attrs", {}).get("outcome") != "done"))
    if trace:
        out["queue_s"] = float(queue_s)
        out["retry_s"] = float(retry_s)
    for r in reversed(hist):               # last recorded eval wins
        ev = r.get("eval")
        if ev is None:
            continue
        if isinstance(ev, Mapping):
            for k, v in ev.items():
                if isinstance(v, bool) or not isinstance(v, (int, float)):
                    continue
                out[f"eval.{k}"] = float(v)
        elif isinstance(ev, (int, float)) and not isinstance(ev, bool):
            out["eval"] = float(ev)
        break
    return out


def _mean_std(vals: Sequence[float]) -> Dict[str, float]:
    n = len(vals)
    mean = sum(vals) / n
    var = sum((v - mean) ** 2 for v in vals) / n       # population std:
    return {"mean": mean, "std": math.sqrt(var),       # std=0 at n=1
            "min": min(vals), "max": max(vals), "n": n}


def _group_key(entry: Mapping[str, Any],
               group_by: Sequence[str]) -> Tuple:
    overrides = entry.get("overrides") or {}
    key = []
    for axis in group_by:
        if axis in overrides:
            key.append(overrides[axis])
        else:
            key.append(spec_get(entry["spec"], axis))
    return tuple(key)


def build_report(man: Mapping[str, Any],
                 group_by: Optional[Sequence[str]] = None) -> dict:
    """Aggregate a sweep manifest.  ``group_by`` defaults to the sweep's
    declared grouping (its non-seed axes); only ``done`` runs enter the
    aggregation — ``complete``/``done``/``total_runs`` expose how much
    of the grid the numbers cover."""
    from repro.experiment.sweep import SweepSpec
    sweep = SweepSpec.from_dict(man["sweep"])
    group_by = tuple(group_by) if group_by else sweep.default_group_by()

    groups: Dict[Tuple, Dict[str, Any]] = {}
    done = failed = 0
    for rid, entry in man["runs"].items():
        # failed (quarantined) runs surface in the report instead of
        # silently shrinking a group's n; pending/running stay invisible
        if entry["status"] not in ("done", "failed"):
            continue
        key = _group_key(entry, group_by)
        g = groups.setdefault(key, {"runs": [], "scalars": [],
                                    "failed": []})
        if entry["status"] == "done":
            done += 1
            g["runs"].append(rid)
            g["scalars"].append(run_scalars(entry))
        else:
            failed += 1
            g["failed"].append(rid)

    out_groups = []
    for key, g in groups.items():          # insertion = manifest order
        names = sorted({m for s in g["scalars"] for m in s})
        metrics = {}
        for m in names:
            vals = [s[m] for s in g["scalars"] if m in s]
            if vals:
                metrics[m] = _mean_std(vals)
        out_groups.append({
            "key": dict(zip(group_by, key)),
            "n": len(g["runs"]),
            "runs": g["runs"],
            "failed": len(g["failed"]),
            "failed_runs": g["failed"],
            "metrics": metrics,
        })
    total = len(man["runs"])
    return {
        "format": REPORT_FORMAT,
        "sweep": sweep.name,
        "group_by": list(group_by),
        "total_runs": total,
        "done": done,
        "failed": failed,
        "complete": done == total,
        "groups": out_groups,
    }


def _metric_columns(report: Mapping[str, Any]) -> List[str]:
    names = sorted({m for g in report["groups"] for m in g["metrics"]})
    head = [m for m in _METRIC_ORDER if m in names]
    return head + [m for m in names if m not in _METRIC_ORDER]


def _fmt(x: Any) -> str:
    if isinstance(x, float):
        return f"{x:.4g}"
    return str(x)


def report_markdown(report: Mapping[str, Any]) -> str:
    """The report as one GitHub-flavored markdown table (mean ± std).

    A ``failed`` column appears only when the sweep has quarantined
    runs, so clean sweeps render exactly as before."""
    group_by = report["group_by"]
    metrics = _metric_columns(report)
    n_failed = report.get("failed", 0)
    lines = [f"# sweep `{report['sweep']}` — {report['done']}/"
             f"{report['total_runs']} runs"
             + (f", {n_failed} FAILED" if n_failed else "")
             + ("" if report["complete"] else " (INCOMPLETE)"),
             ""]
    header = [*group_by, "n", *(["failed"] if n_failed else []), *metrics]
    lines.append("| " + " | ".join(header) + " |")
    lines.append("|" + "---|" * len(header))
    for g in report["groups"]:
        cells = [_fmt(g["key"][a]) for a in group_by] + [str(g["n"])]
        if n_failed:
            cells.append(str(g.get("failed", 0)))
        for m in metrics:
            st = g["metrics"].get(m)
            cells.append(f"{st['mean']:.4g} ± {st['std']:.2g}"
                         if st else "—")
        lines.append("| " + " | ".join(cells) + " |")
    return "\n".join(lines) + "\n"


def write_report(man: Mapping[str, Any], out: str,
                 group_by: Optional[Sequence[str]] = None) -> dict:
    """Build the report and persist ``report.json`` + ``report.md``
    next to the sweep manifest; returns the report dict."""
    report = build_report(man, group_by)
    os.makedirs(out, exist_ok=True)
    with open(os.path.join(out, "report.json"), "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    with open(os.path.join(out, "report.md"), "w") as f:
        f.write(report_markdown(report))
    return report
