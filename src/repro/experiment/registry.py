"""Method/trainer registry: one table from method name to trainer.

Every method the repo implements — the hierarchical FedPhD variants and
the flat Table-II baselines — registers a factory here, so sweeps,
benchmarks, and the CLI resolve trainers uniformly instead of wiring
``FedPhD(...)`` vs ``run_flat_fl(...)`` by hand.  Extensions register
their own methods::

    from repro.experiment import register_method

    def make_my_method(spec, cfg, clients, eval_fn):
        return MyTrainer(cfg, spec.fl, clients, seed=spec.seed, ...)

    register_method("my-method", "flat", make_my_method)

A factory returns any object satisfying the
:class:`repro.experiment.trainer.Trainer` protocol.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List

from repro.configs.base import ModelConfig
from repro.experiment.spec import TOPOLOGIES, ExperimentSpec
from repro.fl.baselines import FLAT_METHODS, FlatTrainer
from repro.fl.client import Client

TrainerFactory = Callable  # (spec, cfg, clients, eval_fn) -> Trainer


@dataclasses.dataclass(frozen=True)
class MethodEntry:
    name: str
    topology: str                       # "hierarchical" | "flat"
    factory: TrainerFactory


_METHODS: Dict[str, MethodEntry] = {}


def register_method(name: str, topology: str, factory: TrainerFactory,
                    *, overwrite: bool = False) -> None:
    if topology not in TOPOLOGIES:
        raise ValueError(f"topology {topology!r} not in {TOPOLOGIES}")
    if name in _METHODS and not overwrite:
        raise ValueError(f"method {name!r} already registered "
                         "(pass overwrite=True to replace)")
    _METHODS[name] = MethodEntry(name, topology, factory)


def method_entry(name: str) -> MethodEntry:
    if name not in _METHODS:
        raise KeyError(f"unknown method {name!r}; registered: "
                       f"{registered_methods()}")
    return _METHODS[name]


def registered_methods() -> List[str]:
    return sorted(_METHODS)


def make_trainer(spec: ExperimentSpec, cfg: ModelConfig,
                 clients: List[Client], eval_fn=None, tracer=None):
    """Resolve ``spec.method`` and build its trainer.

    ``tracer`` (a live :class:`repro.obs.Tracer`) is bound AFTER
    construction via the trainer's ``bind_tracer`` — the factory
    signature stays ``(spec, cfg, clients, eval_fn)`` so third-party
    registrations keep working; trainers without ``bind_tracer``
    simply aren't traced.
    """
    entry = method_entry(spec.method)
    if spec.topology and spec.topology != entry.topology:
        raise ValueError(f"spec.topology={spec.topology!r} but method "
                         f"{spec.method!r} is {entry.topology}")
    trainer = entry.factory(spec, cfg, clients, eval_fn)
    if tracer is not None and tracer.enabled:
        bind = getattr(trainer, "bind_tracer", None)
        if bind is not None:
            bind(tracer)
    return trainer


# ---------------------------------------------------------------------------
# Built-in methods.
# ---------------------------------------------------------------------------

def _spec_mesh(spec: ExperimentSpec):
    """``spec.mesh`` ({axis -> size} or None) to a live jax Mesh."""
    if not spec.mesh:
        return None
    from repro.launch.mesh import make_spec_mesh   # lazy: touches devices
    return make_spec_mesh(spec.mesh)


def _fedphd_factory(prune_mode: str = "",
                    aggregation: str = "") -> TrainerFactory:
    def make(spec: ExperimentSpec, cfg, clients, eval_fn):
        from repro.core.hfl import FedPhD   # lazy: core.hfl imports repro.fl
        fl = spec.fl
        if prune_mode:
            fl = dataclasses.replace(fl, prune_mode=prune_mode)
        return FedPhD(cfg, fl, clients, rng_seed=spec.seed,
                      selection=spec.selection,
                      aggregation=aggregation or spec.aggregation,
                      prune=spec.prune, lr=spec.lr, engine=spec.engine,
                      persistent_opt=spec.persistent_opt,
                      state_store=spec.state_store, mesh=_spec_mesh(spec),
                      eval_fn=eval_fn, eval_every=spec.eval_every,
                      fault=spec.fault, quant=spec.comm.quant)
    return make


def _flat_factory(method: str, aggregation: str = "fedavg") -> TrainerFactory:
    def make(spec: ExperimentSpec, cfg, clients, eval_fn):
        return FlatTrainer(method, cfg, spec.fl, clients, lr=spec.lr,
                           rng_seed=spec.seed, engine=spec.engine,
                           persistent_opt=spec.persistent_opt,
                           state_store=spec.state_store,
                           mesh=_spec_mesh(spec),
                           eval_fn=eval_fn, eval_every=spec.eval_every,
                           aggregation=aggregation, fault=spec.fault,
                           quant=spec.comm.quant)
    return make


register_method("fedphd", "hierarchical", _fedphd_factory())
# FedPhD-OS: one-shot L2 pruning at r = 0 instead of sparse-train rounds
register_method("fedphd-os", "hierarchical", _fedphd_factory("oneshot_l2"))
# staleness-aware aggregation ablations: on-time FedAvg merge + buffered
# late-delta decay (repro.fl.faults) — only meaningful with an enabled
# spec.fault that produces stragglers; equal to fedavg otherwise
register_method("fedphd-stale", "hierarchical",
                _fedphd_factory(aggregation="staleness"))
register_method("fedavg-stale", "flat",
                _flat_factory("fedavg", aggregation="staleness"))
for _m in FLAT_METHODS:
    register_method(_m, "flat", _flat_factory(_m))
