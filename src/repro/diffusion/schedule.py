"""Noise schedules for DDPM/DDIM (Ho et al. 2020, Song et al. 2021)."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DiffusionSchedule:
    betas: jnp.ndarray          # (T,)
    alphas: jnp.ndarray         # (T,)
    alpha_bars: jnp.ndarray     # (T,) cumulative products

    @property
    def num_steps(self) -> int:
        return self.betas.shape[0]


def linear_schedule(num_steps: int, beta_start: float = 1e-4,
                    beta_end: float = 0.02) -> DiffusionSchedule:
    betas = jnp.linspace(beta_start, beta_end, num_steps, dtype=jnp.float32)
    alphas = 1.0 - betas
    alpha_bars = jnp.cumprod(alphas)
    return DiffusionSchedule(betas=betas, alphas=alphas, alpha_bars=alpha_bars)


def cosine_schedule(num_steps: int, s: float = 0.008) -> DiffusionSchedule:
    t = jnp.arange(num_steps + 1, dtype=jnp.float32) / num_steps
    f = jnp.cos((t + s) / (1 + s) * jnp.pi / 2) ** 2
    alpha_bars = f / f[0]
    betas = jnp.clip(1.0 - alpha_bars[1:] / alpha_bars[:-1], 0.0, 0.999)
    alphas = 1.0 - betas
    return DiffusionSchedule(betas=betas, alphas=alphas,
                             alpha_bars=jnp.cumprod(alphas))
