from repro.diffusion.schedule import DiffusionSchedule, linear_schedule, cosine_schedule
from repro.diffusion.ddpm import q_sample, ddpm_loss, ddpm_sample_step
from repro.diffusion.ddim import ddim_sample, ddim_step, ddim_timesteps
from repro.diffusion.sampling import sample_images

__all__ = ["DiffusionSchedule", "linear_schedule", "cosine_schedule",
           "q_sample", "ddpm_loss", "ddpm_sample_step", "ddim_sample",
           "ddim_step", "ddim_timesteps", "sample_images"]
