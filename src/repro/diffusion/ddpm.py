"""DDPM forward process + training loss (paper Eqs. 5–7, Alg. 2 lines 6–12)."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.diffusion.schedule import DiffusionSchedule


def q_sample(schedule: DiffusionSchedule, x0, t, eps):
    """Forward noising: x_t = sqrt(abar_t) x0 + sqrt(1-abar_t) eps."""
    abar = schedule.alpha_bars[t]
    shape = (-1,) + (1,) * (x0.ndim - 1)
    return (jnp.sqrt(abar).reshape(shape) * x0
            + jnp.sqrt(1.0 - abar).reshape(shape) * eps)


def ddpm_loss(eps_fn: Callable, schedule: DiffusionSchedule, x0, rng):
    """Simplified DDPM loss (Eq. 6): E ||eps - eps_theta(x_t, t)||^2.

    eps_fn(x_t, t) -> predicted noise.  x0: (B, H, W, C) in [-1, 1].
    """
    B = x0.shape[0]
    rng_t, rng_eps = jax.random.split(rng)
    t = jax.random.randint(rng_t, (B,), 0, schedule.num_steps)
    eps = jax.random.normal(rng_eps, x0.shape, x0.dtype)
    x_t = q_sample(schedule, x0, t, eps)
    pred = eps_fn(x_t, t)
    return jnp.mean(jnp.square(eps - pred))


def ddpm_sample_step(eps_fn: Callable, schedule: DiffusionSchedule, x_t, t, rng):
    """One reverse step of ancestral DDPM sampling (Eq. 7)."""
    beta = schedule.betas[t]
    alpha = schedule.alphas[t]
    abar = schedule.alpha_bars[t]
    eps = eps_fn(x_t, jnp.full((x_t.shape[0],), t, jnp.int32))
    mean = (x_t - beta / jnp.sqrt(1.0 - abar) * eps) / jnp.sqrt(alpha)
    z = jax.random.normal(rng, x_t.shape, x_t.dtype)
    sigma = jnp.sqrt(beta)
    return mean + jnp.where(t > 0, sigma, 0.0) * z
