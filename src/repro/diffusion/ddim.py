"""DDIM sampler (Song et al. 2021; paper Eqs. 8–9).

The paper evaluates all methods with DDIM at 100 steps vs DDPM's 1000.
eta=0 gives the deterministic sampler used in the paper's FID evaluation.

The single-step update is exposed as :func:`ddim_step` with *per-sample*
timesteps, so a continuous-batching server (``repro.serve``) can run one
jitted program over a slot batch whose requests sit at different
denoising depths; :func:`ddim_sample` is the whole-trajectory scan built
on the same step.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.diffusion.schedule import DiffusionSchedule


def ddim_timesteps(num_train_steps: int, num_sample_steps: int) -> jnp.ndarray:
    """Evenly spaced sub-sequence of training timesteps, descending.

    When ``num_sample_steps`` divides ``num_train_steps`` this is the
    classic DDIM sub-sequence ``(S-1)*stride, ..., stride, 0`` — the
    paper's 1000/100 setting starts at t=990, and that output is kept
    bit-for-bit.  For non-divisible counts the old integer stride
    truncated the top of the trajectory (1000/600 started sampling at
    t=599 — a severely under-noised prior for x_T ~ N(0, I)); those now
    use even spacing over the full ``[0, T-1]`` range inclusive, so the
    first sampled t is always the final training timestep.
    """
    if not 1 <= num_sample_steps <= num_train_steps:
        raise ValueError(f"num_sample_steps={num_sample_steps} must be in "
                         f"[1, num_train_steps={num_train_steps}]")
    if num_sample_steps == 1:
        # the single denoising step must start from the x_T prior's
        # timestep (the stride formula would start at t=0)
        return jnp.array([num_train_steps - 1], jnp.int32)
    if num_train_steps % num_sample_steps == 0:
        stride = num_train_steps // num_sample_steps
        return (jnp.arange(num_sample_steps - 1, -1, -1) * stride) \
            .astype(jnp.int32)
    ts = jnp.linspace(num_train_steps - 1, 0.0, num_sample_steps)
    return jnp.round(ts).astype(jnp.int32)


def ddim_step(x, t, t_prev, eps, schedule: DiffusionSchedule, *,
              eta: float = 0.0, z=None):
    """One DDIM update x_t -> x_{t_prev} given the predicted noise.

    ``t`` / ``t_prev``: scalar or per-sample ``(B,)`` int32 timesteps —
    requests at different denoising depths coexist in one batch.
    ``t_prev == -1`` marks the final step to x_0 (alpha_bar_prev = 1).
    ``eta > 0`` adds the Eq. 9 stochastic term and requires ``z`` (noise
    shaped like ``x``); ``eta == 0`` is the paper's deterministic path
    and consumes no randomness.
    """
    t = jnp.asarray(t, jnp.int32)
    t_prev = jnp.asarray(t_prev, jnp.int32)
    bshape = (-1,) + (1,) * (x.ndim - 1)
    abar_t = schedule.alpha_bars[t].reshape(bshape)
    abar_prev = jnp.where(t_prev >= 0,
                          schedule.alpha_bars[jnp.maximum(t_prev, 0)],
                          1.0).reshape(bshape)
    x0_pred = (x - jnp.sqrt(1.0 - abar_t) * eps) / jnp.sqrt(abar_t)
    x0_pred = jnp.clip(x0_pred, -1.0, 1.0)
    if eta == 0.0:
        return (jnp.sqrt(abar_prev) * x0_pred
                + jnp.sqrt(jnp.maximum(1.0 - abar_prev, 0.0)) * eps)
    # Eq. 9 sigma (eta-scaled)
    sigma = eta * jnp.sqrt((1.0 - abar_prev) / (1.0 - abar_t)) \
        * jnp.sqrt(1.0 - abar_t / abar_prev)
    if z is None:
        raise ValueError("eta > 0 needs the stochastic term's noise z")
    return (jnp.sqrt(abar_prev) * x0_pred
            + jnp.sqrt(jnp.maximum(1.0 - abar_prev - sigma ** 2, 0.0)) * eps
            + sigma * z)


def ddim_sample(eps_fn: Callable, schedule: DiffusionSchedule, rng,
                shape, *, num_steps: int = 100, eta: float = 0.0,
                x_init: Optional[jnp.ndarray] = None):
    """Generate samples.  eps_fn(x_t, t:(B,)) -> predicted noise.

    ``x_init`` supplies the x_T prior draw explicitly (the serving path
    owns its per-request noise); when given with ``eta == 0`` the output
    does not depend on ``rng`` at all — the deterministic sampler's
    randomness lives entirely in the prior.  For ``eta > 0`` the
    per-step z stream is drawn exactly as before this refactor
    (split-then-draw each step), so stochastic trajectories are bitwise
    unchanged.
    """
    rng, rng_init = jax.random.split(rng)
    if x_init is None:
        x_init = jax.random.normal(rng_init, shape, jnp.float32)
    ts = ddim_timesteps(schedule.num_steps, num_steps)
    ts_prev = jnp.concatenate([ts[1:], jnp.full((1,), -1, ts.dtype)])

    if eta == 0.0:
        # deterministic path: no per-step rng split/draw at all
        def body(x, i):
            t = jnp.full((shape[0],), ts[i], jnp.int32)
            eps = eps_fn(x, t)
            return ddim_step(x, t, ts_prev[i], eps, schedule, eta=0.0), None

        x, _ = jax.lax.scan(body, x_init, jnp.arange(num_steps))
        return x

    def body(carry, i):
        x, rng = carry
        t = jnp.full((shape[0],), ts[i], jnp.int32)
        eps = eps_fn(x, t)
        # compat draw order: one split + one draw per step, identical to
        # the pre-refactor stream
        rng, rng_z = jax.random.split(rng)
        z = jax.random.normal(rng_z, shape, jnp.float32)
        x = ddim_step(x, t, ts_prev[i], eps, schedule, eta=eta, z=z)
        return (x, rng), None

    (x, _), _ = jax.lax.scan(body, (x_init, rng), jnp.arange(num_steps))
    return x
