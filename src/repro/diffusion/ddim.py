"""DDIM sampler (Song et al. 2021; paper Eqs. 8–9).

The paper evaluates all methods with DDIM at 100 steps vs DDPM's 1000.
eta=0 gives the deterministic sampler used in the paper's FID evaluation.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.diffusion.schedule import DiffusionSchedule


def ddim_timesteps(num_train_steps: int, num_sample_steps: int) -> jnp.ndarray:
    """Evenly spaced sub-sequence of training timesteps, descending."""
    stride = num_train_steps // num_sample_steps
    return jnp.arange(num_sample_steps - 1, -1, -1) * stride


def ddim_sample(eps_fn: Callable, schedule: DiffusionSchedule, rng,
                shape, *, num_steps: int = 100, eta: float = 0.0):
    """Generate samples.  eps_fn(x_t, t:(B,)) -> predicted noise."""
    rng, rng_init = jax.random.split(rng)
    x = jax.random.normal(rng_init, shape, jnp.float32)
    ts = ddim_timesteps(schedule.num_steps, num_steps)

    def body(carry, i):
        x, rng = carry
        t = ts[i]
        t_prev = jnp.where(i + 1 < num_steps, ts[jnp.minimum(i + 1, num_steps - 1)], -1)
        abar_t = schedule.alpha_bars[t]
        abar_prev = jnp.where(t_prev >= 0,
                              schedule.alpha_bars[jnp.maximum(t_prev, 0)], 1.0)
        eps = eps_fn(x, jnp.full((shape[0],), t, jnp.int32))
        x0_pred = (x - jnp.sqrt(1.0 - abar_t) * eps) / jnp.sqrt(abar_t)
        x0_pred = jnp.clip(x0_pred, -1.0, 1.0)
        # Eq. 9 sigma (eta-scaled)
        sigma = eta * jnp.sqrt((1.0 - abar_prev) / (1.0 - abar_t)) \
            * jnp.sqrt(1.0 - abar_t / abar_prev)
        rng, rng_z = jax.random.split(rng)
        z = jax.random.normal(rng_z, shape, jnp.float32)
        x_next = (jnp.sqrt(abar_prev) * x0_pred
                  + jnp.sqrt(jnp.maximum(1.0 - abar_prev - sigma ** 2, 0.0)) * eps
                  + sigma * z)
        return (x_next, rng), None

    (x, _), _ = jax.lax.scan(body, (x, rng), jnp.arange(num_steps))
    return x
