"""Convenience sampling front-end for trained diffusion U-Nets.

Lives in the library (not in ``benchmarks/``) so examples and external
callers can sample without the repo root on ``sys.path``; benchmarks
import it from here too.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.diffusion.ddim import ddim_sample
from repro.diffusion.schedule import linear_schedule


def sample_images(params, cfg: ModelConfig, n: int = 64, steps: int = 10,
                  seed: int = 0, *, masks=None, eta: float = 0.0) -> np.ndarray:
    """DDIM-sample ``n`` images (N, H, W, C) from a trained U-Net.

    ``masks``: optional sparse-phase prune masks (``make_masks`` output
    keyed by PruneGroup name) — the denoising forward then routes
    through the backend's masked GEMMs, numerically identical to
    sampling from ``apply_masks``-pre-zeroed weights.
    """
    from repro.models.unet import apply_unet
    sched = linear_schedule(cfg.diffusion_steps)
    eps_fn = lambda x, t: apply_unet(params, cfg, x, t, masks=masks)
    out = ddim_sample(eps_fn, sched, jax.random.PRNGKey(seed),
                      (n, cfg.image_size, cfg.image_size, cfg.in_channels),
                      num_steps=steps, eta=eta)
    return np.asarray(out)
