"""FedPhD core: the paper's primary contribution.

- sh_score:     Statistical Homogeneity score + accumulated distributions
                (Eqs. 18-20)
- aggregation:  homogeneity-aware weighted aggregation (Eqs. 21-24)
- selection:    SH-driven client->edge selection (Eq. 25)
- hfl:          the hierarchical-FL orchestrator (Algorithm 1)
- pruning:      DepGraph-lite structured pruning (Eqs. 16-17)
"""
from repro.core.sh_score import (sh_score, label_distribution, uniform_target,
                                 AccumulatedDistribution)
from repro.core.aggregation import (weighted_average,
                                    weighted_average_stacked,
                                    normalize_weights, fedavg_weights,
                                    sh_weights, aggregate_fedavg, aggregate_sh)
from repro.core.selection import (selection_probabilities, select_edge,
                                  ranked_alternatives, random_selection)


def __getattr__(name):
    # lazy: repro.core.hfl imports repro.fl.client, which imports
    # repro.core.pruning — avoid the circular import at package init.
    if name in ("FedPhD", "RoundRecord"):
        from repro.core import hfl
        return getattr(hfl, name)
    raise AttributeError(name)

__all__ = ["sh_score", "label_distribution", "uniform_target",
           "AccumulatedDistribution", "weighted_average",
           "weighted_average_stacked", "normalize_weights", "fedavg_weights",
           "sh_weights", "aggregate_fedavg", "aggregate_sh",
           "selection_probabilities", "select_edge", "ranked_alternatives",
           "random_selection", "FedPhD", "RoundRecord"]
