"""Client -> edge-server selection (paper Eq. 25 + resilience ranking).

P_n(e) ∝ ReLU(a * mu_e^{n'} - n_e^{n'} + b), where mu_e^{n'} / n_e^{n'}
are the edge's SH score / sample count AFTER hypothetically adding client
n — prefer the edge that becomes most homogeneous, penalize loaded edges.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from repro.core.sh_score import AccumulatedDistribution


def selection_probabilities(edges: Sequence[AccumulatedDistribution],
                            q_n: np.ndarray, n_n: int, *, a: float, b: float,
                            q_u: Optional[np.ndarray] = None) -> np.ndarray:
    raw = np.zeros(len(edges), np.float64)
    for i, e in enumerate(edges):
        n_after, mu_after = e.peek_with(q_n, n_n)
        raw[i] = max(a * mu_after - n_after + b, 0.0)
    total = raw.sum()
    if total <= 0:
        return np.full(len(edges), 1.0 / len(edges))
    return raw / total


def select_edge(rng: np.random.Generator,
                edges: Sequence[AccumulatedDistribution], q_n: np.ndarray,
                n_n: int, *, a: float, b: float) -> int:
    p = selection_probabilities(edges, q_n, n_n, a=a, b=b)
    return int(rng.choice(len(edges), p=p))


def ranked_alternatives(edges: Sequence[AccumulatedDistribution],
                        q_n: np.ndarray, n_n: int, *, a: float,
                        b: float) -> List[int]:
    """Edges ranked by P_n(e) — the k-th entry is the k-th-best fallback
    if an edge server fails (paper Appendix E resilience)."""
    p = selection_probabilities(edges, q_n, n_n, a=a, b=b)
    return list(np.argsort(-p))


def random_selection(rng: np.random.Generator, num_edges: int) -> int:
    """Baseline selection used in the paper's Fig. 7/8 comparison."""
    return int(rng.integers(num_edges))
