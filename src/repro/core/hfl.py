"""FedPhD hierarchical-FL orchestrator (paper Algorithm 1).

Simulates the three-tier topology — clients -> edge servers -> cloud —
with homogeneity-aware aggregation at both tiers, SH-driven edge
selection, and distributed structured pruning (sparse-train rounds with
the Eq. 16 regularizer, then one-shot compaction at the cloud at r = R_s;
or FedPhD-OS one-shot pruning at r = 0).

On a real multi-pod TPU deployment the two aggregation tiers map onto
ICI (intra-pod) and DCN (inter-pod) all-reduces — see
repro/launch/federated.py for the shard_map realization; this module is
the faithful event-level simulation the paper's tables are produced from.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (FLConfig, ModelConfig, config_from_dict,
                                config_to_dict)
from repro.core.aggregation import (aggregate_fedavg, aggregate_sh,
                                    fedavg_weights, normalize_weights,
                                    sh_weights)
from repro.core.pruning import (build_groups, compact, l2_scores, make_masks,
                                random_scores)
from repro.core.selection import random_selection, select_edge
from repro.core.sh_score import AccumulatedDistribution, sh_score, uniform_target
from repro.data.pipeline import stack_round
from repro.fl.client import Client, make_local_step, run_local
from repro.fl.comm import CommModel
from repro.fl.compress import (QUANTS, downlink_bytes,
                               ef_roundtrip_jit as _ef_jit, uplink_bytes)
from repro.fl.engine import (adam_stack_from_tree, make_round_engine,
                             resolve_engine, resolve_store, route_engine,
                             stacked_adam_init, stacked_zeros, store_tree,
                             tree_gather, tree_scatter)
from repro.fl.faults import (FaultSpec, apply_late, late_delta,
                             make_fault_model)
# RoundRecord is re-exported here for compatibility: it moved to
# repro.fl.record when the flat baselines adopted the same schema.
from repro.fl.record import RoundRecord, RunResult, evals_of
from repro.models import model
from repro.models.ops import resolve_backend, resolve_precision
from repro.obs.compile_tracker import CompileTracker
from repro.obs.trace import NULL_TRACER
from repro.optim import adam_init


class FedPhD:
    """The FedPhD trainer.

    method: "fedphd" (SH aggregation + SH selection),
            "fedphd-os" (one-shot pruning at init),
            ablations: selection="random", aggregation="fedavg".

    engine: "vectorized" — one jitted vmap(client)/scan(batch) program
            per round with fused on-device edge aggregation and a single
            loss sync (repro/fl/engine.py);
            "sequential" — the per-client Python reference loop;
            "auto" — vectorized whenever the selected clients share a
            batch shape, sequential (with a one-time warning) otherwise;
            None (default) — $FEDPHD_ENGINE if set, else "auto".
    cfg.backend: the compute backend every compiled program routes its
            tensor-core ops through (repro.models.ops: "xla" | "pallas"
            | "ref"; "" resolves via $FEDPHD_BACKEND at construction
            and the concrete name is baked into self.cfg).
    persistent_opt: carry per-client Adam moments across rounds in a
            stacked (N, ...) buffer, gathered/scattered by each round's
            participation selection.  Off by default (the paper restarts
            Adam every round); moments reset when pruning changes the
            parameter shapes at r = R_s.
    state_store: where that stacked buffer lives — "device", "host"
            (numpy; only the participating rows move to device per
            round, so a 10k-client population with 1% participation
            fits), or "auto" (host when N >> participants — see
            repro.fl.engine.resolve_store).
    mesh:   optional jax mesh; the stacked client axis of the vectorized
            engine is laid over ``client_axis`` inside the round engine
            (launch/federated.py shard_clients), so one run's vmapped
            local training partitions across devices.
    eval_fn/eval_every: the unified eval-hook contract —
            ``eval_fn(params, cfg, round)`` is called every
            ``eval_every`` rounds and its result stored in
            ``RoundRecord.eval`` (identical for the flat trainers).
    """

    def __init__(self, cfg: ModelConfig, fl: FLConfig, clients: List[Client],
                 *, rng_seed: int = 0, selection: str = "sh",
                 aggregation: str = "sh", prune: bool = True,
                 lr: float = 2e-4, engine: Optional[str] = None,
                 persistent_opt: bool = False, state_store: str = "auto",
                 mesh=None, client_axis: str = "data",
                 eval_fn: Optional[Callable] = None, eval_every: int = 0,
                 fault: Optional[FaultSpec] = None, quant: str = "none",
                 tracer=None):
        # bake the resolved compute backend AND precision into the
        # frozen config so every compiled program (and the checkpoint
        # manifest) pins concrete values even when they came from
        # $FEDPHD_BACKEND / $FEDPHD_PRECISION
        self.cfg = cfg = cfg.replace(
            backend=resolve_backend(cfg.backend),
            precision=resolve_precision(cfg.precision))
        # obs tracing: NULL_TRACER (the default) makes every span/event
        # call site a no-op — tracing never touches RNG or numerics
        self._obs = NULL_TRACER
        self._obs_compile = None
        if quant not in QUANTS:
            raise ValueError(f"unknown quant {quant!r}; expected one of "
                             f"{QUANTS}")
        self.quant = quant
        self.fl = fl
        self.clients = clients
        self.selection = selection
        self.aggregation = aggregation
        self.prune = prune
        self.lr = lr
        self.engine, self._engine_strict = resolve_engine(engine)
        self.persistent_opt = persistent_opt
        self._warned_ragged = False
        self.mesh = mesh
        self.client_axis = client_axis
        self._store = resolve_store(
            state_store, len(clients),
            max(1, round(fl.participation * len(clients))))
        self.eval_fn = eval_fn
        self.eval_every = eval_every
        self.np_rng = np.random.default_rng(rng_seed)
        self.rng = jax.random.PRNGKey(rng_seed)
        # fault injection: a disabled (or absent) spec yields no model
        # and every fault branch below collapses to the fault-free path
        self.fault = fault if (fault is not None and fault.enabled) else None
        self._faults = make_fault_model(self.fault, len(clients), rng_seed)
        # staleness aggregation: per-edge buffered late-delta sums,
        # merged into that edge's NEXT aggregate (dropped at the prune
        # boundary — parameter shapes change)
        self._late_buf: Dict[int, Dict] = {}

        num_classes = clients[0].num_classes
        self.q_u = uniform_target(num_classes)
        self.edges = [AccumulatedDistribution(num_classes)
                      for _ in range(fl.num_edges)]

        self.rng, sub = jax.random.split(self.rng)
        self.params = model.init(sub, cfg)
        self.groups = build_groups(cfg, self.params)
        self.comm = CommModel()
        self.history: List[RoundRecord] = []
        self.pruned = False

        if prune and fl.prune_mode.startswith("oneshot"):
            self._prune_now(mode=fl.prune_mode)

        self._rebuild_steps()
        if tracer is not None:
            self.bind_tracer(tracer)

    # -- observability -------------------------------------------------------
    def bind_tracer(self, tracer) -> None:
        """Attach an obs tracer (repro.obs): subsequent rounds emit
        phase spans / fault events / compile counters through it.
        ``None`` (or the NULL_TRACER) keeps the no-op path."""
        self._obs = tracer if tracer is not None else NULL_TRACER
        self._obs_compile = CompileTracker(self._obs) \
            if (self._obs.enabled
                and getattr(self._obs, "compile_tracking", False)) else None
        self._watch_compiles()

    def _watch_compiles(self) -> None:
        """(Re)point the compile tracker at the current jitted entry
        points — called after every ``_rebuild_steps`` so the post-prune
        plain engine gets its own expected first compile."""
        if self._obs_compile is None:
            return
        for name, fn in (("step_plain", self.step_plain),
                         ("step_sparse", self.step_sparse),
                         ("engine_plain", self._engine_plain),
                         ("engine_sparse", self._engine_sparse)):
            if fn is not None:
                self._obs_compile.watch(name, fn)

    # -- pruning ------------------------------------------------------------
    def _prune_now(self, mode: str) -> None:
        if mode == "oneshot_random":
            self.rng, sub = jax.random.split(self.rng)
            scores = random_scores(sub, self.groups)
        else:  # group_norm or oneshot_l2
            scores = l2_scores(self.params, self.groups,
                               backend=self.cfg.backend)
        masks = make_masks(scores, self.groups, self.fl.prune_ratio)
        self.params, self.cfg, report = compact(self.params, self.cfg,
                                                self.groups, masks)
        self.groups = build_groups(self.cfg, self.params)
        self.pruned = True
        self.prune_report = report

    def _rebuild_steps(self) -> None:
        sparse = (self.prune and not self.pruned
                  and self.fl.prune_mode == "group_norm")
        self.step_sparse = make_local_step(self.cfg, self.fl, sparse=True,
                                           groups=self.groups, lr=self.lr) \
            if sparse else None
        self.step_plain = make_local_step(self.cfg, self.fl, sparse=False,
                                          lr=self.lr)
        self._engine_sparse = make_round_engine(
            self.cfg, self.fl, sparse=True, groups=self.groups,
            lr=self.lr, mesh=self.mesh, client_axis=self.client_axis,
            quant=self.quant) if sparse else None
        self._engine_plain = make_round_engine(self.cfg, self.fl,
                                               sparse=False, lr=self.lr,
                                               mesh=self.mesh,
                                               client_axis=self.client_axis,
                                               quant=self.quant)
        # one Adam zero-tree per model shape, shared by every client in
        # every sequential round (the vectorized engine builds its own
        # in-program constant)
        self._opt_zero = adam_init(self.params)
        # persistent per-client moments: a stacked (N, ...) buffer both
        # engines gather/scatter by participation.  Rebuilt (i.e. reset
        # to zeros) whenever pruning changes the parameter shapes.
        self._opt_stack = stacked_adam_init(self.params, len(self.clients),
                                            host=self._store == "host") \
            if self.persistent_opt else None
        # per-client error-feedback residuals for the quantized uplink:
        # fp32, congruent with params, reset here (= at the prune
        # boundary, where the leaf shapes change under them)
        self._err_stack = stacked_zeros(self.params, len(self.clients),
                                        dtype=np.float32,
                                        host=self._store == "host") \
            if self.quant != "none" else None
        self._watch_compiles()

    # -- bookkeeping ----------------------------------------------------------
    def _param_count_m(self) -> float:
        return sum(x.size for x in jax.tree.leaves(self.params)) / 1e6

    def _model_bytes(self) -> int:
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(self.params))

    def _wire_bytes(self):
        """Bytes-on-wire per transfer: ``(up, up_late, down)`` — the
        quantized on-time uplink (payload + per-leaf scales), the fp32
        late/staleness uplink, and the compute-dtype download."""
        return (uplink_bytes(self.params, self.quant),
                uplink_bytes(self.params, "none"),
                downlink_bytes(self.params, self.cfg.precision))

    # -- local training + edge aggregation (Alg. 1 lines 7-21) ---------------
    def _use_vectorized(self, round_clients) -> bool:
        use, self._warned_ragged = route_engine(
            self.engine, self._engine_strict, round_clients,
            self._warned_ragged, "FedPhD", method="fedphd")
        return use

    def _local_and_edge_sequential(self, r, assignment, sparse_round, wire,
                                   faults=None):
        """Reference path: one jitted step per batch, Python aggregation.

        Under an active fault schedule (``faults``): non-arrived clients
        run zero steps (their RNG streams still advance in lockstep with
        the stacked path), dropped/straggling clients truncate at their
        step budget, only reporting clients enter the edge aggregate
        (weights renormalized among them) and count uplink, and LATE
        clients' deltas buffer into ``_late_buf`` for the staleness
        merge at the edge's next aggregation.

        With ``quant`` active, each ON-TIME reporter's delta runs the
        error-feedback quantize->dequantize round trip and the edge
        aggregates the reconstructed ``start + deq`` — late deltas ship
        (and buffer) fp32.
        """
        fl = self.fl
        up_q, up_f, down = wire
        step_fn = self.step_sparse if sparse_round else self.step_plain
        round_losses: List[float] = []
        loss_mask: List[bool] = []
        up_bytes, down_bytes = 0.0, 0.0
        for e, cids in assignment.items():
            if not cids:
                continue
            edge_model = getattr(self, "_edge_models", {}).get(e, self.params)
            client_models, counts, mus = [], [], []
            late_models, late_counts = [], []
            n_arrived = 0
            for cid in cids:
                cl = self.clients[cid]
                self.rng, sub = jax.random.split(self.rng)
                budget = faults.budget_of(cid) if faults else None
                opt_in = tree_gather(self._opt_stack, int(cid)) \
                    if self.persistent_opt else self._opt_zero
                p, opt_out, loss = run_local(step_fn, edge_model, cl,
                                             epochs=fl.local_epochs, rng=sub,
                                             opt_state=opt_in,
                                             max_steps=budget)
                completed = faults is None or faults.completed_of(cid)
                late = faults is not None and faults.late_of(cid)
                if self.persistent_opt and completed:
                    self._opt_stack = tree_scatter(self._opt_stack,
                                                   int(cid), opt_out)
                round_losses.append(loss)
                loss_mask.append(budget is None or budget > 0)
                if faults is not None and faults.arrived_of(cid):
                    n_arrived += 1
                if completed:
                    self.edges[e].update(cl.q_n, cl.n_samples)     # Eq. 19
                    up_bytes += self.comm.client_edge(up_f if late
                                                      else up_q)    # upload
                if late:
                    late_models.append(p)
                    late_counts.append(cl.n_samples)
                elif completed:                       # reporting on time
                    if self.quant != "none":
                        delta = jax.tree.map(lambda a, b: a - b, p,
                                             edge_model)
                        e_row = store_tree(
                            tree_gather(self._err_stack, int(cid)), "device")
                        deq, new_err = _ef_jit(delta, e_row, self.quant)
                        self._err_stack = tree_scatter(self._err_stack,
                                                       int(cid), new_err)
                        p = jax.tree.map(lambda s, d: s + d, edge_model, deq)
                    client_models.append(p)
                    counts.append(cl.n_samples)
                    mus.append(sh_score(cl.q_n, self.q_u))
            if r % fl.edge_agg_every == 0:
                if client_models:
                    if self.aggregation == "sh":
                        agg = aggregate_sh(client_models, counts, mus,
                                           fl.sh_a, fl.sh_b)    # Eq. 23/24
                    else:
                        agg = aggregate_fedavg(client_models, counts)
                else:
                    # no client reported: the edge keeps its model
                    agg = edge_model
                if self.aggregation == "staleness":
                    buf = self._late_buf.pop(e, None)
                    if buf is not None:     # merge last round's stragglers
                        agg = apply_late(agg, buf, self.fault.staleness
                                         if self.fault else 0.0)
                    if late_models:
                        tot = max(sum(counts) + sum(late_counts), 1)
                        w = [n / tot for n in late_counts]
                        self._late_buf[e] = late_delta(late_models,
                                                       edge_model, w)
                if not hasattr(self, "_edge_models"):
                    self._edge_models = {}
                self._edge_models[e] = agg
                n_down = len(cids) if faults is None else n_arrived
                down_bytes += self.comm.client_edge(down) * n_down  # down
        return round_losses, up_bytes, down_bytes, loss_mask

    def _local_and_edge_vectorized(self, r, assignment, sparse_round, wire,
                                   faults=None):
        """Device-resident path: one program for all clients + edge agg.

        Fault injection stays shape-static: straggler/dropout budgets
        truncate the (C, S) valid mask as a data-only prefix AND (no
        recompilation), non-reporting clients are zeroed out of the
        (E, C) aggregation einsum with weights renormalized among the
        reporters, and late clients' staleness deltas come back via the
        ``w_late`` operand's in-engine einsum.
        """
        fl = self.fl
        obs = self._obs
        with obs.span("round/host_prep", round=r):
            order = [(e, cid) for e, cids in assignment.items()
                     for cid in cids]
            # identical RNG folding to the sequential loop: one split per
            # client in edge-iteration order
            subs = []
            for _ in order:
                self.rng, sub = jax.random.split(self.rng)
                subs.append(sub)
            clients = [self.clients[cid] for _, cid in order]
            # masking is identity when no client needed padding — elide
            # the per-step select ops at trace time in that (common) case
            batches, valid, masked = stack_round([cl.data for cl in clients],
                                                 fl.local_epochs)
            if faults is not None:
                # prefix truncation: client i executes only its first
                # budget_i steps.  Same shapes as the fault-free round.
                budgets = np.asarray([faults.budget_of(cid)
                                      for _, cid in order])
                prefix = np.arange(valid.shape[1])[None, :] < budgets[:, None]
                masked = masked or not bool(prefix.all())
                valid = valid & prefix
        with obs.span("round/h2d", round=r):
            batches = {k: jnp.asarray(v) for k, v in batches.items()}
            valid = jnp.asarray(valid)
            rngs = jnp.stack(subs)
            edge_models = getattr(self, "_edge_models", {})
            edge_stack = jax.tree.map(
                lambda *leaves: jnp.stack(leaves),
                *[edge_models.get(e, self.params)
                  for e in range(fl.num_edges)])
            edge_idx = jnp.asarray(np.asarray([e for e, _ in order],
                                              np.int32))

        # fused aggregation rows: W[e] = normalized Eq. 22/24 weights of
        # edge e's REPORTING clients, zero elsewhere (graceful
        # degradation: dropped/late clients never enter the einsum)
        staleness = self.aggregation == "staleness"
        w_mat = np.zeros((fl.num_edges, len(order)), np.float32)
        w_late = np.zeros((fl.num_edges, len(order)), np.float32) \
            if staleness else None
        any_late = False
        for e, cids in assignment.items():
            if not cids:
                continue
            rep = [cid for cid in cids
                   if faults is None or faults.reporting_of(cid)]
            if rep:
                counts = [self.clients[cid].n_samples for cid in rep]
                mus = [sh_score(self.clients[cid].q_n, self.q_u)
                       for cid in rep]
                w = sh_weights(counts, mus, fl.sh_a, fl.sh_b) \
                    if self.aggregation == "sh" else fedavg_weights(counts)
                idxs = [i for i, (ee, cid) in enumerate(order)
                        if ee == e and cid in rep]
                w_mat[e, idxs] = normalize_weights(w)
            if staleness and faults is not None:
                late = [cid for cid in cids if faults.late_of(cid)]
                if late:
                    any_late = True
                    tot = max(sum(self.clients[cid].n_samples for cid in rep)
                              + sum(self.clients[cid].n_samples
                                    for cid in late), 1)
                    for i, (ee, cid) in enumerate(order):
                        if ee == e and cid in late:
                            w_late[e, i] = self.clients[cid].n_samples / tot

        # self.mesh (when set) is handled INSIDE the engine: the
        # _make_sharded_engine wrapper lays every client-leading operand
        # over the mesh's client axis before dispatch
        engine = self._engine_sparse if sparse_round else self._engine_plain
        idx_arr = np.asarray([cid for _, cid in order])
        # host-store gathered rows are numpy: stage them to device
        # explicitly so the engine's opt_states donation stays live
        with obs.span("round/dispatch", round=r):
            out = engine(edge_stack, edge_idx, batches, valid, rngs,
                         jnp.asarray(w_mat),
                         opt_states=(store_tree(
                             tree_gather(self._opt_stack, idx_arr), "device")
                             if self.persistent_opt else None),
                         w_late=(jnp.asarray(w_late) if any_late else None),
                         err=(store_tree(
                             tree_gather(self._err_stack, idx_arr), "device")
                             if self.quant != "none" else None),
                         masked=masked, per_client_opt=self.persistent_opt)
        if self.persistent_opt:
            if faults is None:
                self._opt_stack = tree_scatter(self._opt_stack, idx_arr,
                                               out["opt"])
            else:
                # only COMPLETED clients keep their updated moments
                comp = np.asarray([i for i, (_, cid) in enumerate(order)
                                   if faults.completed_of(cid)])
                if len(comp):
                    self._opt_stack = tree_scatter(
                        self._opt_stack, idx_arr[comp],
                        tree_gather(out["opt"], comp))
        if self.quant != "none":
            # only ON-TIME reporters shipped a quantized payload, so
            # only their residual rows advance (mirrors the sequential
            # loop; late/dropped lanes keep their buffers)
            rep = np.asarray([i for i, (_, cid) in enumerate(order)
                              if faults is None or faults.reporting_of(cid)])
            if len(rep):
                self._err_stack = tree_scatter(
                    self._err_stack, idx_arr[rep],
                    tree_gather(out["err"], rep))
        agg_stack = out["agg"]
        # NO host sync here: the (C,) loss array stays a device future
        # until _finish_round — under the pipelined run() the next
        # round's host-side data prep and H2D copy overlap this round's
        # device compute before anything blocks on it
        round_losses = out["losses"]
        loss_mask = [faults is None or faults.budget_of(cid) > 0
                     for _, cid in order]

        up_q, up_f, down = wire
        up_bytes, down_bytes = 0.0, 0.0
        n_arrived = {e: 0 for e in assignment}
        for e, cid in order:
            cl = self.clients[cid]
            if faults is not None and faults.arrived_of(cid):
                n_arrived[e] += 1
            if faults is None or faults.completed_of(cid):
                self.edges[e].update(cl.q_n, cl.n_samples)      # Eq. 19
                late = faults is not None and faults.late_of(cid)
                up_bytes += self.comm.client_edge(up_f if late
                                                  else up_q)     # upload
        if r % fl.edge_agg_every == 0:
            with obs.span("round/edge_agg", round=r):
                if not hasattr(self, "_edge_models"):
                    self._edge_models = {}
                for e, cids in assignment.items():
                    if not cids:
                        continue
                    if np.any(w_mat[e] > 0):
                        agg = jax.tree.map(lambda leaf, _e=e: leaf[_e],
                                           agg_stack)
                    else:
                        # no client reported: a zero w_mat row makes the
                        # einsum row a zero tree — the edge keeps its model
                        agg = edge_models.get(e, self.params)
                    if staleness:
                        buf = self._late_buf.pop(e, None)
                        if buf is not None:  # merge last round's stragglers
                            agg = apply_late(agg, buf, self.fault.staleness
                                             if self.fault else 0.0)
                        if w_late is not None and np.any(w_late[e] > 0):
                            self._late_buf[e] = jax.tree.map(
                                lambda leaf, _e=e: leaf[_e], out["late"])
                    self._edge_models[e] = agg
                    n_down = len(cids) if faults is None else n_arrived[e]
                    down_bytes += self.comm.client_edge(down) * n_down
        return round_losses, up_bytes, down_bytes, loss_mask

    # -- one communication round (Alg. 1 lines 3-32) -------------------------
    def run_round(self, r: int) -> RoundRecord:
        return self._finish_round(self._start_round(r))

    def _start_round(self, r: int) -> Dict:
        """Dispatch one round: selection, host data prep + H2D, the
        round program, edge/cloud aggregation and (at r = R_s) pruning
        — everything except blocking on the device losses.  Returns the
        pending-round dict ``_finish_round`` turns into a RoundRecord.

        On the vectorized engine nothing here forces a host sync, so
        ``run()`` double-buffers rounds: round r+1's ``stacked_epochs``
        shuffle/stack and H2D copy (the one buffer donation could not
        cover — ROADMAP "Open items") run while round r's program is
        still executing.
        """
        fl = self.fl
        if self._faults is not None:
            # churn first (its own RNG stream), then sample participants
            # from the online pool only — with churn=0 the np_rng
            # consumption is identical to the fault-free path
            online = self._faults.begin_round()
            pool = np.flatnonzero(online)
            C = min(max(1, round(fl.participation * len(self.clients))),
                    len(pool))
            sel_ids = pool[self.np_rng.choice(len(pool), size=C,
                                              replace=False)]
        else:
            C = max(1, round(fl.participation * len(self.clients)))
            sel_ids = self.np_rng.choice(len(self.clients), size=C,
                                         replace=False)

        # line 4-5: clients select edge servers
        assignment: Dict[int, List[int]] = {e: [] for e in range(fl.num_edges)}
        for cid in sel_ids:
            cl = self.clients[cid]
            if self.selection == "sh":
                e = select_edge(self.np_rng, self.edges, cl.q_n,
                                cl.n_samples, a=fl.sh_a, b=fl.sh_b)
            else:
                e = random_selection(self.np_rng, fl.num_edges)
            assignment[e].append(cid)

        sparse_round = (self.prune and not self.pruned
                        and fl.prune_mode == "group_norm" and r < fl.sparse_rounds)

        faults = None
        if self._faults is not None:
            steps = [fl.local_epochs * self.clients[c].data.steps_per_epoch
                     for c in sel_ids]
            faults = self._faults.draw_round(
                sel_ids, steps, self.aggregation == "staleness")
            if self._obs.enabled:
                self._obs.event("fault/draw", round=r,
                                **faults.summary())

        wire = self._wire_bytes()
        # lines 7-21: per-edge local training + edge aggregation
        if self._use_vectorized([self.clients[c] for c in sel_ids]):
            round_losses, up_bytes, down_bytes, loss_mask = \
                self._local_and_edge_vectorized(
                    r, assignment, sparse_round, wire, faults)
        else:
            # the reference loop syncs per batch: host prep, compute and
            # aggregation interleave, so it gets one dispatch span
            with self._obs.span("round/dispatch", round=r):
                round_losses, up_bytes, down_bytes, loss_mask = \
                    self._local_and_edge_sequential(
                        r, assignment, sparse_round, wire, faults)

        pruned_this_round = False
        # lines 23-31: cloud aggregation every r_g rounds.  The
        # edge<->cloud tier ships fp32 uploads (quantization is the
        # client->edge uplink only) and compute-dtype broadcasts.
        if r % fl.cloud_agg_every == 0 and hasattr(self, "_edge_models"):
            with self._obs.span("round/cloud_agg", round=r):
                models, counts, mus = [], [], []
                for e, m in self._edge_models.items():
                    models.append(m)
                    counts.append(self.edges[e].n)
                    mus.append(self.edges[e].sh(self.q_u))      # Eq. 20
                    up_bytes += self.comm.edge_cloud(wire[1])   # upload
                if models:
                    if self.aggregation == "sh":
                        self.params = aggregate_sh(
                            models, counts, mus, fl.sh_a, fl.sh_b)  # Eq. 21/22
                    else:
                        self.params = aggregate_fedavg(models, counts)
                # line 26-28: structured pruning at r = R_s
                if (self.prune and not self.pruned
                        and fl.prune_mode == "group_norm"
                        and r >= fl.sparse_rounds):
                    with self._obs.span("round/prune", round=r):
                        self._prune_now(mode="group_norm")
                        self._rebuild_steps()
                    pruned_this_round = True
                    wire = self._wire_bytes()
                    # buffered late deltas have pre-prune shapes: drop them
                    self._late_buf = {}
                # broadcast + refresh (lines 29-31)
                down_bytes += self.comm.edge_cloud(wire[2]) * fl.num_edges
                self._edge_models = {e: self.params
                                     for e in range(fl.num_edges)}
                for e in self.edges:
                    e.refresh()

        # snapshot end-of-round state the record needs: edge SH and the
        # params/cfg the eval hook sees must not leak mutations from a
        # round dispatched before this one is finalized
        return {"round": r, "losses": round_losses,
                "up_bytes": up_bytes, "down_bytes": down_bytes,
                "sel_ids": sel_ids,
                "pruned": pruned_this_round, "params": self.params,
                "cfg": self.cfg, "params_m": self._param_count_m(),
                "edge_sh": [e.sh(self.q_u) for e in self.edges],
                "loss_mask": loss_mask,
                "availability": faults.availability() if faults else None}

    def _finish_round(self, pend: Dict) -> RoundRecord:
        """Sync the pending round's losses and append its RoundRecord."""
        losses = pend["losses"]
        if not isinstance(losses, list):          # device future -> host
            with self._obs.span("round/loss_sync", round=pend["round"]):
                losses = [float(x) for x in np.asarray(losses)]
        r = pend["round"]
        mask = pend.get("loss_mask")
        if mask is not None:        # faults: average over executed clients
            losses = [l for l, m in zip(losses, mask) if m]
        rec = RoundRecord(
            round=r,
            loss=float(np.mean(losses)) if losses
            else (0.0 if mask is not None else float("nan")),
            # totals as the sum of the ROUNDED up/down fields, so
            # comm_gb == comm_up_gb + comm_down_gb holds exactly
            comm_gb=pend["up_bytes"] / 1e9 + pend["down_bytes"] / 1e9,
            comm_up_gb=pend["up_bytes"] / 1e9,
            comm_down_gb=pend["down_bytes"] / 1e9,
            params_m=pend["params_m"],
            selected=[int(c) for c in pend["sel_ids"]],
            edge_sh=pend["edge_sh"],
            pruned=pend["pruned"],
            availability=pend.get("availability"),
        )
        # append BEFORE the eval hook: the round executed (trainer state
        # and RNG streams advanced), so a raising eval_fn must lose the
        # eval, not the round — otherwise a later run()/resume would
        # re-run an already-applied round and diverge
        self.history.append(rec)
        if self._obs_compile is not None:
            # compiles triggered by this round's dispatch/sync are in
            # the caches by now; growth beyond the first per fn = a
            # shape/dtype leaked into a trace
            self._obs_compile.check(round=r)
        if self.eval_fn and self.eval_every and r % self.eval_every == 0:
            rec.eval = self.eval_fn(pend["params"], pend["cfg"], r)
        return rec

    def run(self, rounds: Optional[int] = None, *,
            eval_every: Optional[int] = None) -> RunResult:
        """Run rounds ``len(history)+1 .. rounds`` (continues after a
        restore).  Returns ``RunResult`` — unpacks as the legacy
        ``history, evals`` tuple; eval results also land in
        ``RoundRecord.eval`` (the unified hook contract).

        Rounds are double-buffered: round r+1 is dispatched
        (``_start_round`` — selection, stacked_epochs shuffle/stack,
        H2D copy, round-program dispatch) before round r's losses are
        synced (``_finish_round``), so host-side data prep overlaps
        device compute on the vectorized engine.  Records are
        finalized in round order and the per-round numerics are
        identical to stepping ``run_round`` directly — only the sync
        point moves.
        """
        rounds = rounds or self.fl.rounds
        if eval_every is not None:            # legacy per-call override
            self.eval_every = eval_every
        pend = None
        try:
            for r in range(len(self.history) + 1, rounds + 1):
                cur = self._start_round(r)
                # hand cur to the guard BEFORE finishing prev: if
                # _finish_round(prev) raises (eval hook), prev is
                # already in history (append-before-eval) and the
                # finally still finalizes the dispatched cur — no
                # executed round is ever orphaned
                prev, pend = pend, cur
                if prev is not None:
                    self._finish_round(prev)
        finally:
            # a raising _start_round (e.g. strict-vectorized hitting a
            # ragged selection) must not orphan the already-executed
            # previous round: finalize it so history matches the
            # advanced trainer state.  Finalize only when it extends
            # history contiguously — if prev's own finalize died before
            # its append, recording cur would leave a round-number gap
            if pend is not None and len(self.history) == pend["round"] - 1:
                self._finish_round(pend)
        return RunResult(self.history, evals_of(self.history))

    # -- checkpoint state (repro.experiment resume contract) -----------------
    def state(self):
        """``(arrays, meta)``: everything the trajectory depends on.

        ``arrays`` is a pytree for ``repro.checkpoint.save``; ``meta``
        is JSON-serializable (RNG bit-generator states, the possibly
        post-prune ModelConfig, and the history records).  Restoring
        into a freshly constructed trainer reproduces an unbroken run
        bitwise on the sequential engine.
        """
        arrays = {
            "params": self.params,
            "rng": self.rng,
            "opt_stack": self._opt_stack,
            "edge_models": ({str(e): m for e, m in self._edge_models.items()}
                            if hasattr(self, "_edge_models") else None),
            "edge_counts": np.stack([e.counts for e in self.edges]),
            "edge_n": np.asarray([e.n for e in self.edges], np.int64),
            "late_buf": ({str(e): t for e, t in self._late_buf.items()}
                         or None),
            # quantized-uplink error-feedback residuals (None when
            # quant == "none"): restoring them bitwise is what keeps a
            # kill-and-resume trajectory identical to an unbroken run
            "err_stack": self._err_stack,
        }
        meta = {
            "trainer": "fedphd",
            "pruned": bool(self.pruned),
            "cfg": config_to_dict(self.cfg),
            "np_rng": self.np_rng.bit_generator.state,
            "client_rngs": [cl.data.rng_state() for cl in self.clients],
            "history": [rec.to_dict() for rec in self.history],
            "fault": self._faults.state() if self._faults else None,
        }
        return arrays, meta

    def restore(self, arrays, meta) -> None:
        """Inverse of ``state()`` on a trainer built with the same
        constructor arguments (same cfg/fl/clients/seed)."""
        to_dev = lambda t: jax.tree.map(jnp.asarray, t)
        cfg = config_from_dict(meta["cfg"])
        # pre-backend/precision checkpoints carry "" — resolve as at init
        self.cfg = cfg.replace(backend=resolve_backend(cfg.backend),
                               precision=resolve_precision(cfg.precision))
        self.pruned = bool(meta["pruned"])
        self.params = to_dev(arrays["params"])
        self.rng = jnp.asarray(arrays["rng"])
        self.groups = build_groups(self.cfg, self.params)
        if arrays.get("edge_models") is not None:
            self._edge_models = {int(e): to_dev(m)
                                 for e, m in arrays["edge_models"].items()}
        elif hasattr(self, "_edge_models"):
            del self._edge_models
        for i, e in enumerate(self.edges):
            e.counts = np.asarray(arrays["edge_counts"][i],
                                  np.float64).copy()
            e.n = int(arrays["edge_n"][i])
        self._late_buf = ({int(e): to_dev(t)
                           for e, t in arrays["late_buf"].items()}
                          if arrays.get("late_buf") else {})
        self.np_rng.bit_generator.state = meta["np_rng"]
        for cl, st in zip(self.clients, meta["client_rngs"]):
            cl.data.set_rng_state(st)
        if self._faults is not None and meta.get("fault"):
            self._faults.set_state(meta["fault"])
        self.history = [RoundRecord.from_dict(d) for d in meta["history"]]
        self._rebuild_steps()
        if self.quant != "none" and arrays.get("err_stack") is not None:
            # after _rebuild_steps (which zeroes the stack for the
            # restored cfg's shapes) — land the saved residuals where
            # this trainer keeps them
            self._err_stack = store_tree(arrays["err_stack"], self._store)
        if self.persistent_opt:
            self._opt_stack = adam_stack_from_tree(arrays["opt_stack"],
                                                   self._store)
