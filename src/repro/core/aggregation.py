"""Model aggregation: FedAvg weighting and FedPhD homogeneity-aware
weighting (paper Eqs. 21–24).

All aggregations are weighted pytree sums; they run on host (numpy-free,
jax.tree based) and are identical at the edge and cloud tiers — only the
weights differ.
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def weighted_average(param_trees: Sequence, weights: Sequence[float]):
    """sum_i w_i * theta_i with weights normalized to 1."""
    w = np.asarray(weights, np.float64)
    total = w.sum()
    if total <= 0:
        w = np.full_like(w, 1.0 / len(w))
    else:
        w = w / total
    def combine(*leaves):
        acc = leaves[0].astype(jnp.float32) * w[0]
        for wi, leaf in zip(w[1:], leaves[1:]):
            acc = acc + leaf.astype(jnp.float32) * wi
        return acc.astype(leaves[0].dtype)
    return jax.tree.map(combine, *param_trees)


def fedavg_weights(sample_counts: Sequence[int]) -> np.ndarray:
    """rho_n = D_n / D (Eq. 10)."""
    n = np.asarray(sample_counts, np.float64)
    return n / max(n.sum(), 1.0)


def sh_weights(sample_counts: Sequence[int], sh_scores: Sequence[float],
               a: float, b: float) -> np.ndarray:
    """Eqs. 22/24: rho = ReLU(n + a*mu + b) / sum ReLU(...)."""
    n = np.asarray(sample_counts, np.float64)
    mu = np.asarray(sh_scores, np.float64)
    raw = np.maximum(n + a * mu + b, 0.0)
    total = raw.sum()
    if total <= 0:                      # degenerate: fall back to FedAvg
        return fedavg_weights(sample_counts)
    return raw / total


def aggregate_fedavg(param_trees: Sequence, sample_counts: Sequence[int]):
    return weighted_average(param_trees, fedavg_weights(sample_counts))


def aggregate_sh(param_trees: Sequence, sample_counts: Sequence[int],
                 sh_scores: Sequence[float], a: float, b: float):
    """Homogeneity-aware aggregation (edge: Eq. 23/24; cloud: Eq. 21/22)."""
    return weighted_average(param_trees, sh_weights(sample_counts, sh_scores,
                                                    a, b))
