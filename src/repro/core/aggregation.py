"""Model aggregation: FedAvg weighting and FedPhD homogeneity-aware
weighting (paper Eqs. 21–24).

All aggregations are weighted pytree sums; they run on host (numpy-free,
jax.tree based) and are identical at the edge and cloud tiers — only the
weights differ.
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def normalize_weights(weights: Sequence[float]) -> np.ndarray:
    """Normalize to a convex combination; uniform fallback if degenerate."""
    w = np.asarray(weights, np.float64)
    total = w.sum()
    if total <= 0:
        return np.full_like(w, 1.0 / len(w))
    return w / total


def combine_leaf(stacked: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """einsum-contract the leading member axis of one stacked leaf.

    ``stacked``: (N, ...) member-stacked leaf; ``w``: (N,) or (G, N)
    weights.  Accumulates once in fp32 and casts back, rounding integer
    leaves (e.g. the Adam step counter) instead of truncating.
    """
    dtype = stacked.dtype
    acc = jnp.einsum("gn,n...->g..." if w.ndim == 2 else "n,n...->...",
                     w.astype(jnp.float32), stacked.astype(jnp.float32))
    if jnp.issubdtype(dtype, jnp.integer):
        return jnp.round(acc).astype(dtype)
    return acc.astype(dtype)


def weighted_average_stacked(stacked_tree, weights):
    """sum_n w_n * theta_n over the leading member axis of every leaf.

    ``weights`` may be (N,) — one averaged tree — or a (G, N) matrix of
    per-group weight rows (fused multi-edge aggregation), in which case
    every output leaf keeps a leading group axis.  Rows are used as
    given (callers normalize; see ``normalize_weights``).
    """
    w = jnp.asarray(np.asarray(weights, np.float32))
    return jax.tree.map(lambda leaf: combine_leaf(leaf, w), stacked_tree)


def weighted_average(param_trees: Sequence, weights: Sequence[float]):
    """sum_i w_i * theta_i with weights normalized to 1.

    One stacked fp32 einsum per leaf (not a per-member Python
    accumulation): device-friendly, single up/downcast, and integer
    leaves (Adam ``t``) survive the round trip via round-to-nearest.
    """
    w = normalize_weights(weights)
    stacked = jax.tree.map(lambda *leaves: jnp.stack(leaves), *param_trees)
    return weighted_average_stacked(stacked, w)


def uniform_weights(n: int) -> np.ndarray:
    """Unnormalized equal weights — ``normalize_weights`` turns them into
    exactly 1/n (SCAFFOLD's unweighted control-variate mean)."""
    return np.ones(n)


def fedavg_weights(sample_counts: Sequence[int]) -> np.ndarray:
    """rho_n = D_n / D (Eq. 10)."""
    n = np.asarray(sample_counts, np.float64)
    return n / max(n.sum(), 1.0)


def sh_weights(sample_counts: Sequence[int], sh_scores: Sequence[float],
               a: float, b: float) -> np.ndarray:
    """Eqs. 22/24: rho = ReLU(n + a*mu + b) / sum ReLU(...)."""
    n = np.asarray(sample_counts, np.float64)
    mu = np.asarray(sh_scores, np.float64)
    raw = np.maximum(n + a * mu + b, 0.0)
    total = raw.sum()
    if total <= 0:                      # degenerate: fall back to FedAvg
        return fedavg_weights(sample_counts)
    return raw / total


def aggregate_fedavg(param_trees: Sequence, sample_counts: Sequence[int]):
    return weighted_average(param_trees, fedavg_weights(sample_counts))


def aggregate_sh(param_trees: Sequence, sample_counts: Sequence[int],
                 sh_scores: Sequence[float], a: float, b: float):
    """Homogeneity-aware aggregation (edge: Eq. 23/24; cloud: Eq. 21/22)."""
    return weighted_average(param_trees, sh_weights(sample_counts, sh_scores,
                                                    a, b))
