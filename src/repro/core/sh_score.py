"""Statistical Homogeneity (SH) score and accumulated distributions.

Paper §IV-B, Eqs. 18–20.  The SH score mu = 2 - ||q - q_u||_2 measures how
close a label distribution q is to the target (uniform) distribution q_u;
mu in [2 - sqrt(2), 2] for probability vectors.  Edge servers maintain an
*accumulated* distribution (Eq. 19) over the clients that reported to them
since the last cloud refresh.
"""
from __future__ import annotations

from typing import Optional

import numpy as np


def uniform_target(num_classes: int) -> np.ndarray:
    return np.full((num_classes,), 1.0 / num_classes, np.float64)


def sh_score(q: np.ndarray, q_u: Optional[np.ndarray] = None) -> float:
    """Eq. 18 / Eq. 20: mu = 2 - sqrt(sum_y |q(y) - q_u(y)|^2)."""
    q = np.asarray(q, np.float64)
    if q_u is None:
        q_u = uniform_target(q.shape[-1])
    return float(2.0 - np.sqrt(np.sum(np.square(q - q_u))))


def label_distribution(labels: np.ndarray, num_classes: int) -> np.ndarray:
    """Empirical label distribution q_n(y) of a client dataset."""
    counts = np.bincount(np.asarray(labels, np.int64), minlength=num_classes)
    total = max(counts.sum(), 1)
    return counts.astype(np.float64) / total


class AccumulatedDistribution:
    """Edge server's running distribution q_e(y) with sample count n_e.

    Eq. 19: q_e' = (q_e * n_e + sum_n q_n * n_n) / (n_e + sum_n n_n).
    ``refresh()`` re-initializes every r_g rounds (Alg. 1 line 31).
    """

    def __init__(self, num_classes: int):
        self.num_classes = num_classes
        self.counts = np.zeros((num_classes,), np.float64)
        self.n = 0

    def update(self, q_n: np.ndarray, n_n: int) -> None:
        self.counts += np.asarray(q_n, np.float64) * n_n
        self.n += int(n_n)

    @property
    def q(self) -> np.ndarray:
        if self.n == 0:
            return uniform_target(self.num_classes)
        return self.counts / self.n

    def sh(self, q_u: Optional[np.ndarray] = None) -> float:
        return sh_score(self.q, q_u)

    def peek_with(self, q_n: np.ndarray, n_n: int):
        """(n_e', mu_e') if client (q_n, n_n) were added — used by Eq. 25."""
        counts = self.counts + np.asarray(q_n, np.float64) * n_n
        n = self.n + int(n_n)
        q = counts / max(n, 1)
        return n, sh_score(q)

    def refresh(self) -> None:
        self.counts[:] = 0.0
        self.n = 0
