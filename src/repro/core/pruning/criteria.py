"""Pruning criteria: L2 group-norm (paper §IV-A) and random (FedPhD-OS).

The per-unit sum-of-squares reduction (the Eq. 17 inner term, shared
with the Omega regularizer) dispatches through
:func:`repro.models.ops.group_sq_norms_2d`: any non-scan-stacked group
member is a contiguous chunk-reshape — slice the owned span, move the
group axis last, reshape to ``(K, size*chunk)`` — which is exactly the
layout the ``group_l2_norms`` Pallas kernel reduces.  Scan-stacked
members keep the jnp fallback (their leading cycle axis must survive
the reduction).
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.core.pruning.groups import PruneGroup, GroupMember, get_path
from repro.models import ops


def member_unit_sq(params, g: PruneGroup, m: GroupMember,
                   backend: str = "") -> jnp.ndarray:
    """Sum of squares per unit for one member.

    Returns (size,) or (stacked, size) float32.
    """
    p = get_path(params, m.path)
    axis = m.axis + (1 if g.stacked else 0)
    sl = jax.lax.slice_in_dim(p, m.offset, m.offset + g.size * m.chunk,
                              axis=axis)
    if ops.resolve_backend(backend) != "xla" and not g.stacked:
        w2d = jnp.moveaxis(sl, axis, -1).reshape(
            -1, g.size * m.chunk).astype(jnp.float32)
        return ops.group_sq_norms_2d(w2d, g.size, backend=backend)
    shape = list(sl.shape)
    shape[axis:axis + 1] = [g.size, m.chunk]
    r = sl.reshape(shape).astype(jnp.float32)
    reduce_axes = tuple(i for i in range(r.ndim)
                        if i != axis and not (g.stacked and i == 0))
    return jnp.sum(jnp.square(r), axis=reduce_axes)


def group_sq_norms(params, g: PruneGroup, backend: str = "") -> jnp.ndarray:
    """||theta^g[k]||_2^2 per unit k (Eq. 17 inner term)."""
    out = None
    for m in g.members:
        s = member_unit_sq(params, g, m, backend)
        out = s if out is None else out + s
    return out


def l2_scores(params, groups: List[PruneGroup],
              backend: str = "") -> Dict[str, jnp.ndarray]:
    """Group-norm importance scores (sqrt of summed squares)."""
    return {g.name: jnp.sqrt(group_sq_norms(params, g, backend))
            for g in groups}


def random_scores(rng, groups: List[PruneGroup]) -> Dict[str, jnp.ndarray]:
    """FedPhD-OS one-shot random pruning scores."""
    out = {}
    for g in groups:
        rng, sub = jax.random.split(rng)
        shape = (g.stacked, g.size) if g.stacked else (g.size,)
        out[g.name] = jax.random.uniform(sub, shape)
    return out
