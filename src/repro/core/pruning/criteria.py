"""Pruning criteria: L2 group-norm (paper §IV-A) and random (FedPhD-OS)."""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.core.pruning.groups import PruneGroup, GroupMember, get_path


def member_unit_sq(params, g: PruneGroup, m: GroupMember) -> jnp.ndarray:
    """Sum of squares per unit for one member.

    Returns (size,) or (stacked, size) float32.
    """
    p = get_path(params, m.path)
    axis = m.axis + (1 if g.stacked else 0)
    sl = jax.lax.slice_in_dim(p, m.offset, m.offset + g.size * m.chunk,
                              axis=axis)
    shape = list(sl.shape)
    shape[axis:axis + 1] = [g.size, m.chunk]
    r = sl.reshape(shape).astype(jnp.float32)
    reduce_axes = tuple(i for i in range(r.ndim)
                        if i != axis and not (g.stacked and i == 0))
    return jnp.sum(jnp.square(r), axis=reduce_axes)


def group_sq_norms(params, g: PruneGroup) -> jnp.ndarray:
    """||theta^g[k]||_2^2 per unit k (Eq. 17 inner term)."""
    out = None
    for m in g.members:
        s = member_unit_sq(params, g, m)
        out = s if out is None else out + s
    return out


def l2_scores(params, groups: List[PruneGroup]) -> Dict[str, jnp.ndarray]:
    """Group-norm importance scores (sqrt of summed squares)."""
    return {g.name: jnp.sqrt(group_sq_norms(params, g)) for g in groups}


def random_scores(rng, groups: List[PruneGroup]) -> Dict[str, jnp.ndarray]:
    """FedPhD-OS one-shot random pruning scores."""
    out = {}
    for g in groups:
        rng, sub = jax.random.split(rng)
        shape = (g.stacked, g.size) if g.stacked else (g.size,)
        out[g.name] = jax.random.uniform(sub, shape)
    return out
