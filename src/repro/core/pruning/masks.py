"""Pruning masks: selection from scores, mask application (sparse phase).

TPU alignment policy (DESIGN.md §3.1): kept *channel/lane* counts are
rounded to multiples of 8 (128 once the group is >=1024 wide, so MXU-fed
dims stay lane-aligned after compaction); head/expert units are integral
already and not rounded.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.core.pruning.groups import PruneGroup, get_path, set_path


def alignment_for(g: PruneGroup) -> int:
    if g.unit in ("head", "expert"):
        return 1
    if g.size >= 1024 and g.size % 128 == 0:
        return 128
    if g.size >= 16 and g.size % 8 == 0:
        return 8
    return 1


def kept_count(g: PruneGroup, ratio: float) -> int:
    align = alignment_for(g)
    keep = max(1, round(g.size * (1.0 - ratio)))
    if align > 1:
        keep = max(align, round(keep / align) * align)
    return min(keep, g.size)


def make_masks(scores: Dict[str, jnp.ndarray], groups: List[PruneGroup],
               ratio: float) -> Dict[str, jnp.ndarray]:
    """Top-k-by-score 0/1 masks, per group (per cycle for stacked groups)."""
    masks = {}
    for g in groups:
        s = scores[g.name]
        k = kept_count(g, ratio)
        # rank-based top-k: ties break deterministically (stable argsort)
        # and exactly k units survive per row — a >=-threshold mask
        # would keep extras on tied scores
        idx = jnp.argsort(-s, axis=-1, stable=True)
        rank = jnp.argsort(idx, axis=-1, stable=True)
        mask = (rank < k).astype(jnp.float32)
        masks[g.name] = mask
    return masks


def keep_indices(mask: jnp.ndarray, k: int) -> jnp.ndarray:
    """Sorted indices of kept units; mask (..., size) -> (..., k)."""
    idx = jnp.argsort(-mask, axis=-1, stable=True)[..., :k]
    return jnp.sort(idx, axis=-1)


def _mask_vector(mask_row, g: PruneGroup, m, dim: int):
    """Expand a (size,) mask into a (dim,) multiplier for one member."""
    rep = jnp.repeat(mask_row, m.chunk)
    full = jnp.ones((dim,), jnp.float32)
    return jax.lax.dynamic_update_slice(full, rep, (m.offset,))


def apply_masks(params, groups: List[PruneGroup],
                masks: Dict[str, jnp.ndarray]):
    """Zero out pruned units (shape-stable sparse-training phase)."""
    for g in groups:
        mask = masks[g.name]
        for m in g.members:
            p = get_path(params, m.path)
            axis = m.axis + (1 if g.stacked else 0)
            dim = p.shape[axis]
            if g.stacked:
                vec = jax.vmap(lambda mr: _mask_vector(mr, g, m, dim))(mask)
                shape = [mask.shape[0]] + [1] * (p.ndim - 1)
                shape[axis] = dim
                mult = vec.reshape(shape)
            else:
                vec = _mask_vector(mask, g, m, dim)
                shape = [1] * p.ndim
                shape[axis] = dim
                mult = vec.reshape(shape)
            params = set_path(params, m.path, p * mult.astype(p.dtype))
    return params


def sparsity_report(groups: List[PruneGroup],
                    masks: Dict[str, jnp.ndarray]) -> Dict[str, tuple]:
    out = {}
    for g in groups:
        m = masks[g.name]
        kept = int(jnp.sum(m[0] if g.stacked else m))
        out[g.name] = (kept, g.size)
    return out
