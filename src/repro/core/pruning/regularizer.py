"""Group-lasso sparse-training regularizer (paper Eqs. 16–17).

Omega(G, k) = sum_g lambda_g * sum_k ||theta^g[k]||_2^2 with the
depth-aware scale lambda_g = lambda_0 / Q(theta^g), where
Q = mean |l - l_mid| — U-Net middle layers (most redundant) get the
largest regularization pressure.
"""
from __future__ import annotations

from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from repro.core.pruning.criteria import group_sq_norms
from repro.core.pruning.groups import PruneGroup


def depth_lambdas(groups: List[PruneGroup], lambda0: float) -> Dict[str, np.ndarray]:
    """lambda_g per group (per cycle for stacked groups)."""
    max_layer = max((max(g.layer_indices) for g in groups if g.layer_indices),
                    default=0)
    l_mid = max_layer / 2.0
    out = {}
    for g in groups:
        idx = np.asarray(g.layer_indices, np.float32)
        q = np.abs(idx - l_mid)
        q = np.maximum(q, 0.5)          # avoid divide-by-zero at the middle
        out[g.name] = (lambda0 / q).astype(np.float32)
    return out


def omega(params, groups: List[PruneGroup],
          lambdas: Dict[str, np.ndarray],
          backend: str = "") -> jnp.ndarray:
    """The regularization term added to the local loss during sparse
    rounds.  ``backend`` routes the inner group reductions through
    :func:`repro.models.ops.group_sq_norms_2d` (xla | pallas | ref)."""
    total = jnp.zeros((), jnp.float32)
    for g in groups:
        sq = group_sq_norms(params, g, backend)              # (size,) or (C, size)
        lam = jnp.asarray(lambdas[g.name])
        if g.stacked:
            total = total + jnp.sum(lam * jnp.sum(sq, axis=-1))
        else:
            total = total + lam[0] * jnp.sum(sq)
    return total
