"""Physical compaction: slice pruned units out, emit a smaller config.

This is the paper's "structured pruning on theta at r = R_s" (Alg. 1
line 26) adapted to JAX/TPU: masks keep shapes static during sparse
training; compaction happens ONCE at the cloud and triggers a single
re-jit of the training step with genuinely smaller tensors (DESIGN.md §3.1).
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.core.pruning.groups import PruneGroup, get_path, set_path
from repro.core.pruning.masks import keep_indices


def _unit_flat_indices(keep_idx, chunk: int, offset: int):
    """(k,) unit indices -> (k*chunk,) flat element indices."""
    base = keep_idx * chunk + offset
    return (base[..., :, None] + jnp.arange(chunk)[None, :]).reshape(
        keep_idx.shape[:-1] + (-1,))


def _compact_param_axis(param, axis: int, members, g: PruneGroup,
                        keep_idx) -> jnp.ndarray:
    """Rebuild one parameter along one axis, gathering kept units.

    members: the group's members on this (path, axis), sorted by offset.
    Unowned regions of the axis are kept whole.
    """
    stacked = bool(g.stacked)
    dim = param.shape[axis]
    pieces = []
    cursor = 0
    for m in sorted(members, key=lambda m: m.offset):
        if m.offset > cursor:
            pieces.append(jax.lax.slice_in_dim(param, cursor, m.offset,
                                               axis=axis))
        flat = _unit_flat_indices(keep_idx, m.chunk, m.offset)
        if stacked:
            take = jax.vmap(lambda p, i: jnp.take(p, i, axis=axis - 1))
            pieces.append(take(param, flat))
        else:
            pieces.append(jnp.take(param, flat, axis=axis))
        cursor = m.offset + g.size * m.chunk
    if cursor < dim:
        pieces.append(jax.lax.slice_in_dim(param, cursor, dim, axis=axis))
    return jnp.concatenate(pieces, axis=axis) if len(pieces) > 1 else pieces[0]


def compact_params(params, groups: List[PruneGroup],
                   masks: Dict[str, jnp.ndarray]) -> Tuple[Dict, Dict[str, int]]:
    """Slice kept units out of every group.  Returns (params, kept-counts)."""
    kept_counts: Dict[str, int] = {}
    for g in groups:
        mask = masks[g.name]
        row = mask[0] if g.stacked else mask
        k = int(jnp.sum(row))
        kept_counts[g.name] = k
        keep_idx = keep_indices(mask, k)
        # group members by (path, axis) so shared params are rebuilt once
        by_pa = defaultdict(list)
        for m in g.members:
            axis = m.axis + (1 if g.stacked else 0)
            by_pa[(m.path, axis)].append(m)
        for (path, axis), members in by_pa.items():
            p = get_path(params, path)
            new_p = _compact_param_axis(p, axis, members, g, keep_idx)
            params = set_path(params, path, new_p)
    return params, kept_counts


def _uniform(groups: List[PruneGroup], kept: Dict[str, int],
             suffix: str) -> int:
    vals = {kept[g.name] for g in groups if g.name.endswith(suffix)}
    if not vals:
        return 0
    assert len(vals) == 1, f"non-uniform kept counts for {suffix}: {vals}"
    return vals.pop()


def compact_config(cfg: ModelConfig, groups: List[PruneGroup],
                   kept: Dict[str, int]) -> ModelConfig:
    """Derive the post-compaction config (uniform-ratio pruning keeps the
    scan-stacked layers shape-compatible)."""
    if cfg.arch_type == "unet":
        return cfg  # internal channel counts live in param shapes only
    changes = {}
    k_heads = _uniform(groups, kept, "/heads")
    if k_heads:
        changes["num_kv_heads"] = k_heads
        changes["num_heads"] = k_heads * cfg.q_per_kv
    k_ffn = _uniform(groups, kept, "/ffn") or _uniform(groups, kept, "/cmix_ffn")
    if k_ffn:
        changes["d_ff"] = k_ffn
    k_lru = _uniform(groups, kept, "/lru")
    if k_lru:
        changes["lru_width"] = k_lru
    k_tmix = _uniform(groups, kept, "/tmix_heads")
    if k_tmix:
        changes["num_heads"] = k_tmix
        changes["num_kv_heads"] = k_tmix
    if cfg.moe is not None:
        moe_changes = {}
        k_exp = _uniform(groups, kept, "/experts")
        if k_exp:
            moe_changes["num_experts"] = k_exp
            moe_changes["experts_per_token"] = min(cfg.moe.experts_per_token,
                                                   k_exp)
        k_shared = _uniform(groups, kept, "/shared_ffn")
        if k_shared:
            moe_changes["d_shared"] = k_shared
        if moe_changes:
            changes["moe"] = dataclasses.replace(cfg.moe, **moe_changes)
    return cfg.replace(name=cfg.name + "-pruned", **changes) if changes else cfg


def compact(params, cfg: ModelConfig, groups: List[PruneGroup],
            masks: Dict[str, jnp.ndarray]):
    """Full compaction: (params, cfg, masks) -> (new_params, new_cfg, report)."""
    new_params, kept = compact_params(params, groups, masks)
    new_cfg = compact_config(cfg, groups, kept)
    report = {g.name: (kept[g.name], g.size) for g in groups}
    return new_params, new_cfg, report
