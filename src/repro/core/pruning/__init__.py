from repro.core.pruning.groups import (PruneGroup, GroupMember, build_groups,
                                       get_path, set_path)
from repro.core.pruning.criteria import l2_scores, random_scores, group_sq_norms
from repro.core.pruning.masks import (make_masks, apply_masks, kept_count,
                                      keep_indices, sparsity_report)
from repro.core.pruning.regularizer import omega, depth_lambdas
from repro.core.pruning.compact import compact, compact_params, compact_config

__all__ = [
    "PruneGroup", "GroupMember", "build_groups", "get_path", "set_path",
    "l2_scores", "random_scores", "group_sq_norms",
    "make_masks", "apply_masks", "kept_count", "keep_indices",
    "sparsity_report", "omega", "depth_lambdas",
    "compact", "compact_params", "compact_config",
]
