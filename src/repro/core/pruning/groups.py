"""DepGraph-lite: declared dependency groups for structured pruning.

DepGraph (Fang et al. 2023) traces the autograd graph to find parameters
that must be pruned together.  In JAX we declare those groups structurally
per model family — more robust than tracing and equally faithful
(DESIGN.md §3.4).  A ``PruneGroup`` names a set of *units* (channels,
heads, experts, recurrence lanes) and the parameter slices each unit owns.

Paths address the parameter pytree; groups over ``lax.scan``-stacked
cycle parameters carry ``stacked = n_cycles`` and per-cycle layer indices
(for the paper's depth-aware λ_g, Eq. 17).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Sequence, Tuple

import numpy as np

from repro.configs.base import (ModelConfig, ATTN_GLOBAL, ATTN_LOCAL,
                                RECURRENT, RWKV)

Path = Tuple[Any, ...]


@dataclass(frozen=True)
class GroupMember:
    """One parameter slice owned by a group.

    Unit ``k`` owns indices ``[offset + k*chunk, offset + (k+1)*chunk)``
    along ``axis`` of the (unstacked) parameter at ``path``.
    """
    path: Path
    axis: int
    chunk: int = 1
    offset: int = 0


@dataclass(frozen=True)
class PruneGroup:
    name: str
    size: int                       # number of prunable units
    members: Tuple[GroupMember, ...]
    stacked: int = 0                # n_cycles if params are scan-stacked, else 0
    layer_indices: Tuple[int, ...] = ()   # per cycle (stacked) or single layer
    unit: str = "channel"           # channel | head | expert | lane


# ---------------------------------------------------------------------------
# pytree path utilities
# ---------------------------------------------------------------------------
def get_path(tree, path: Path):
    for p in path:
        tree = tree[p]
    return tree


def set_path(tree, path: Path, value):
    """Functional set — returns a new tree (dicts/lists copied along path)."""
    if not path:
        return value
    head, rest = path[0], path[1:]
    if isinstance(tree, dict):
        new = dict(tree)
    elif isinstance(tree, (list, tuple)):
        new = list(tree)
    else:
        raise TypeError(f"cannot descend into {type(tree)}")
    new[head] = set_path(tree[head], rest, value)
    if isinstance(tree, tuple):
        new = tuple(new)
    return new


# ---------------------------------------------------------------------------
# transformer groups
# ---------------------------------------------------------------------------
def _attn_head_group(prefix: Path, cfg: ModelConfig, has_bias: bool,
                     has_out_bias: bool, key: str = "attn") -> List[GroupMember]:
    hd, G = cfg.head_dim, cfg.q_per_kv
    m = [
        GroupMember(prefix + (key, "wq"), axis=1, chunk=G * hd),
        GroupMember(prefix + (key, "wk"), axis=1, chunk=hd),
        GroupMember(prefix + (key, "wv"), axis=1, chunk=hd),
        GroupMember(prefix + (key, "wo"), axis=0, chunk=G * hd),
    ]
    if has_bias:
        m += [GroupMember(prefix + (key, "bq"), axis=0, chunk=G * hd),
              GroupMember(prefix + (key, "bk"), axis=0, chunk=hd),
              GroupMember(prefix + (key, "bv"), axis=0, chunk=hd)]
    return m


def _ffn_group(prefix: Path, glu: bool, bias: bool) -> List[GroupMember]:
    m = [GroupMember(prefix + ("ffn", "w_in"), axis=1),
         GroupMember(prefix + ("ffn", "w_out"), axis=0)]
    if glu:
        m.append(GroupMember(prefix + ("ffn", "w_gate"), axis=1))
    if bias:
        m.append(GroupMember(prefix + ("ffn", "b_in"), axis=0))
    return m


def _layer_groups(prefix: Path, lp: Dict, kind: int, cfg: ModelConfig,
                  *, stacked: int, layers: Tuple[int, ...],
                  tag: str) -> List[PruneGroup]:
    groups: List[PruneGroup] = []

    def G(name, size, members, unit):
        groups.append(PruneGroup(name=f"{tag}/{name}", size=size,
                                 members=tuple(members), stacked=stacked,
                                 layer_indices=layers, unit=unit))

    if kind in (ATTN_GLOBAL, ATTN_LOCAL):
        if "attn" in lp:
            G("heads", cfg.num_kv_heads,
              _attn_head_group(prefix, cfg, "bq" in lp["attn"],
                               "bo" in lp["attn"]), "head")
        # MLA layers: latent bottleneck shared by all heads — not pruned
        # (DESIGN.md §4); their FFN/MoE still is.
        if "cross" in lp:
            G("cross_heads", cfg.num_kv_heads,
              _attn_head_group(prefix, cfg, "bq" in lp["cross"],
                               "bo" in lp["cross"], key="cross"), "head")
        if "moe" in lp:
            moe = cfg.moe
            G("experts", moe.num_experts, [
                GroupMember(prefix + ("moe", "router"), axis=1),
                GroupMember(prefix + ("moe", "w_gate"), axis=0),
                GroupMember(prefix + ("moe", "w_in"), axis=0),
                GroupMember(prefix + ("moe", "w_out"), axis=0),
            ], "expert")
            if "shared" in lp["moe"]:
                G("shared_ffn", moe.d_shared, [
                    GroupMember(prefix + ("moe", "shared", "w_in"), axis=1),
                    GroupMember(prefix + ("moe", "shared", "w_gate"), axis=1),
                    GroupMember(prefix + ("moe", "shared", "w_out"), axis=0),
                ], "channel")
        elif "ffn" in lp:
            G("ffn", cfg.d_ff, _ffn_group(prefix, cfg.glu, cfg.use_ffn_bias),
              "channel")
    elif kind == RECURRENT:
        W = cfg.lru_width
        G("lru", W, [
            GroupMember(prefix + ("rec", "w_x"), axis=1),
            GroupMember(prefix + ("rec", "w_y"), axis=1),
            GroupMember(prefix + ("rec", "conv_w"), axis=1),
            GroupMember(prefix + ("rec", "conv_b"), axis=0),
            GroupMember(prefix + ("rec", "w_a"), axis=0),
            GroupMember(prefix + ("rec", "w_a"), axis=1),
            GroupMember(prefix + ("rec", "b_a"), axis=0),
            GroupMember(prefix + ("rec", "w_i"), axis=0),
            GroupMember(prefix + ("rec", "w_i"), axis=1),
            GroupMember(prefix + ("rec", "b_i"), axis=0),
            GroupMember(prefix + ("rec", "log_lambda"), axis=0),
            GroupMember(prefix + ("rec", "w_out"), axis=0),
        ], "lane")
        if "ffn" in lp:
            G("ffn", cfg.d_ff, _ffn_group(prefix, cfg.glu, cfg.use_ffn_bias),
              "channel")
    elif kind == RWKV:
        hd = cfg.head_dim
        G("tmix_heads", cfg.num_heads, [
            GroupMember(prefix + ("tmix", "w_r"), axis=1, chunk=hd),
            GroupMember(prefix + ("tmix", "w_k"), axis=1, chunk=hd),
            GroupMember(prefix + ("tmix", "w_v"), axis=1, chunk=hd),
            GroupMember(prefix + ("tmix", "w_g"), axis=1, chunk=hd),
            GroupMember(prefix + ("tmix", "w_o"), axis=0, chunk=hd),
            GroupMember(prefix + ("tmix", "u"), axis=0),
            GroupMember(prefix + ("tmix", "ln_scale"), axis=0, chunk=hd),
            GroupMember(prefix + ("tmix", "decay_b"), axis=1, chunk=hd),
            GroupMember(prefix + ("tmix", "w0"), axis=0, chunk=hd),
        ], "head")
        G("cmix_ffn", cfg.d_ff, [
            GroupMember(prefix + ("cmix", "w_k"), axis=1),
            GroupMember(prefix + ("cmix", "w_v"), axis=0),
        ], "channel")
    return groups


def transformer_groups(cfg: ModelConfig, params: Dict) -> List[PruneGroup]:
    from repro.models.transformer import stack_plan
    plan = stack_plan(cfg)
    plen = len(plan.pattern)
    groups: List[PruneGroup] = []
    for i, lp in enumerate(params["head"]):
        groups += _layer_groups(("head", i), lp, plan.pattern[0], cfg,
                                stacked=0, layers=(i,), tag=f"head{i}")
    for pos in range(plen):
        lp = params["cycles"][pos]
        if lp is None:
            continue
        layers = tuple(plan.n_head + c * plen + pos
                       for c in range(plan.n_cycles))
        groups += _layer_groups(("cycles", pos), lp, plan.pattern[pos], cfg,
                                stacked=plan.n_cycles, layers=layers,
                                tag=f"cyc{pos}")
    base = plan.n_head + plan.n_cycles * plen
    for i, kind in enumerate(plan.tail_kinds):
        groups += _layer_groups(("tail", i), params["tail"][i], kind, cfg,
                                stacked=0, layers=(base + i,), tag=f"tail{i}")
    if cfg.arch_type == "encdec":
        ne = cfg.num_encoder_layers
        layers = tuple(range(ne))  # encoder depth indexed separately
        groups += _layer_groups(("encoder", "blocks"), params["encoder"]["blocks"],
                                ATTN_GLOBAL, cfg, stacked=ne, layers=layers,
                                tag="enc")
    return groups


# ---------------------------------------------------------------------------
# U-Net groups (paper's model): ResBlock internal channels + attention heads
# ---------------------------------------------------------------------------
def unet_groups(cfg: ModelConfig, params: Dict) -> List[PruneGroup]:
    groups: List[PruneGroup] = []
    layer_counter = [0]

    def resblock(prefix: Path, rp):
        lidx = layer_counter[0]
        layer_counter[0] += 1
        cout = rp["conv1"]["w"].shape[-1]
        groups.append(PruneGroup(
            name="/".join(map(str, prefix)), size=int(cout),
            members=(
                GroupMember(prefix + ("conv1", "w"), axis=3),
                GroupMember(prefix + ("conv1", "b"), axis=0),
                GroupMember(prefix + ("temb", "w"), axis=1),
                GroupMember(prefix + ("temb", "b"), axis=0),
                GroupMember(prefix + ("norm2", "scale"), axis=0),
                GroupMember(prefix + ("norm2", "bias"), axis=0),
                GroupMember(prefix + ("conv2", "w"), axis=2),
            ),
            layer_indices=(lidx,), unit="channel"))

    def attnblock(prefix: Path, ap):
        lidx = layer_counter[0]
        layer_counter[0] += 1
        c = ap["proj"]["w"].shape[2]
        groups.append(PruneGroup(
            name="/".join(map(str, prefix)), size=int(c),
            members=(
                GroupMember(prefix + ("qkv", "w"), axis=3, offset=0),
                GroupMember(prefix + ("qkv", "w"), axis=3, offset=c),
                GroupMember(prefix + ("qkv", "w"), axis=3, offset=2 * c),
                GroupMember(prefix + ("qkv", "b"), axis=0, offset=0),
                GroupMember(prefix + ("qkv", "b"), axis=0, offset=c),
                GroupMember(prefix + ("qkv", "b"), axis=0, offset=2 * c),
                GroupMember(prefix + ("proj", "w"), axis=2),
            ),
            layer_indices=(lidx,), unit="channel"))

    for side in ("down", "up"):
        for lvl, lvl_p in enumerate(params[side]):
            for b, blk in enumerate(lvl_p["blocks"]):
                resblock((side, lvl, "blocks", b, "res"), blk["res"])
                if "attn" in blk:
                    attnblock((side, lvl, "blocks", b, "attn"), blk["attn"])
        if side == "down":
            resblock(("mid", "res1"), params["mid"]["res1"])
            attnblock(("mid", "attn"), params["mid"]["attn"])
            resblock(("mid", "res2"), params["mid"]["res2"])
    return groups


def build_groups(cfg: ModelConfig, params: Dict) -> List[PruneGroup]:
    if cfg.arch_type == "unet":
        return unet_groups(cfg, params)
    return transformer_groups(cfg, params)
