"""Roofline-term extraction from compiled HLO.

``compiled.cost_analysis()`` on the CPU backend reports per-device FLOPs
with every ``while`` body counted ONCE (verified empirically), so it
cannot price a scanned-layer model.  This module parses the optimized
HLO text instead:

  1. split into computations, map instruction name -> result shape;
  2. build the call graph (while condition/body, conditional branches,
     fusion/call ``calls=``/``to_apply=``);
  3. recover scan trip counts from the integer constant in each while's
     condition computation;
  4. FLOPs: every dot/convolution, weighted by its control-ancestor
     multiplier;
  5. HBM bytes: operands + results of top-level instructions in control
     computations (post-fusion, each such op is one HBM round trip);
  6. collective bytes: operand bytes of all-gather / all-reduce /
     reduce-scatter / all-to-all / collective-permute, same multipliers,
     split intra-pod (ICI) vs cross-pod (DCN) by replica-group span.

All numbers are PER DEVICE (shapes in SPMD-partitioned HLO are
per-device); the roofline terms divide by per-chip peak rates, so
  compute_term    = flops_per_device / PEAK_FLOPS
  memory_term     = hbm_bytes_per_device / HBM_BW
  collective_term = ici_bytes / ICI_BW + dcn_bytes / DCN_BW.
"""
from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

from repro.roofline import hw

# --------------------------------------------------------------------------
# HLO text parsing
# --------------------------------------------------------------------------
_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_SHAPE = re.compile(r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|s32|s16|s8|u64|u32|u16|u8|pred|c64|c128)\[([\d,]*)\]")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)\)(.*)$")
_CALL_ATTR = re.compile(r"(?:condition|body|to_apply|calls)=%?([\w\.\-]+)")
_BRANCH_ATTR = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_S32 = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_OPERAND = re.compile(r"%([\w\.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_REPL_GROUPS = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * hw.DTYPE_BYTES[dt]
    return total


def _result_dims(type_str: str) -> List[int]:
    m = _SHAPE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    args: str
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    instrs: List[Instr]


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        h = _COMP_HEADER.match(line.strip())
        if h and line.rstrip().endswith("{"):
            cur = Computation(name=h.group(2), is_entry=bool(h.group(1)),
                              instrs=[])
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        im = _INSTR.match(line)
        if im:
            cur.instrs.append(Instr(name=im.group(1), type_str=im.group(2),
                                    opcode=im.group(3), args=im.group(4),
                                    attrs=im.group(5)))
    return comps


_TRIP_CFG = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _call_edges(comp: Computation) -> List[Tuple[str, str, str, int]]:
    """(instr, callee, kind, trip) edges out of a computation."""
    edges = []
    for ins in comp.instrs:
        blob = ins.args + " " + ins.attrs
        if ins.opcode == "while":
            tm = _TRIP_CFG.search(blob)
            trip = int(tm.group(1)) if tm else 0   # 0 = unknown, use cond
            for attr, kind in (("condition", "cond"), ("body", "body")):
                m = re.search(attr + r"=%?([\w\.\-]+)", blob)
                if m:
                    edges.append((ins.name, m.group(1), kind, trip))
        else:
            for m in _CALL_ATTR.finditer(blob):
                edges.append((ins.name, m.group(1), "call", 1))
            bm = _BRANCH_ATTR.search(blob)
            if bm:
                for c in bm.group(1).split(","):
                    edges.append((ins.name, c.strip().lstrip("%"), "call", 1))
    return edges


def _trip_count(cond: Computation) -> int:
    """Largest s32 constant in the while condition — the scan bound."""
    best = 1
    for ins in cond.instrs:
        for m in _CONST_S32.finditer(ins.type_str + " " + ins.opcode + "("
                                     + ins.args + ")" + ins.attrs):
            best = max(best, int(m.group(1)))
    return best


def _propagate_multipliers(comps: Dict[str, Computation]):
    """multiplier per computation; control[name]=True if reachable via
    entry/while/branch edges (not inside a fusion)."""
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        entry = next(iter(comps.values()))
    mult: Dict[str, float] = defaultdict(float)
    control: Dict[str, bool] = defaultdict(bool)

    def visit(name: str, m: float, is_control: bool):
        comp = comps.get(name)
        if comp is None:
            return
        mult[name] += m
        control[name] = control[name] or is_control
        # a computation can be called from several sites; the additive
        # accumulation above is what we want.
        edges = _call_edges(comp)
        for instr_name, callee, kind, trip in edges:
            if kind == "body":
                if trip <= 0:
                    cond_name = next((c for i2, c, k2, _ in edges
                                      if i2 == instr_name and k2 == "cond"),
                                     None)
                    trip = _trip_count(comps[cond_name]) \
                        if cond_name and cond_name in comps else 1
                visit(callee, m * trip, is_control)
            elif kind == "call":
                visit(callee, m, False)
    visit(entry.name, 1.0, True)
    return mult, control, entry.name


def _dot_flops(ins: Instr, shapes: Dict[str, List[int]]) -> float:
    out = 1
    for d in _result_dims(ins.type_str):
        out *= d
    cm = _CONTRACT.search(ins.attrs) or _CONTRACT.search(ins.args)
    lhs_name_m = _OPERAND.search(ins.args)
    k = 1
    if cm and lhs_name_m:
        lhs_shape = shapes.get(lhs_name_m.group(1), [])
        dims = [int(x) for x in cm.group(1).split(",") if x]
        for d in dims:
            if d < len(lhs_shape):
                k *= lhs_shape[d]
    return 2.0 * out * k


def _conv_flops(ins: Instr, shapes: Dict[str, List[int]]) -> float:
    out = 1
    for d in _result_dims(ins.type_str):
        out *= d
    ops = _OPERAND.findall(ins.args)
    if len(ops) >= 2:
        kshape = shapes.get(ops[1], [])
        if kshape:
            # kernel HWIO: spatial*in_features multiply-adds per output
            k = 1
            for d in kshape[:-1]:
                k *= d
            return 2.0 * out * k
    return 2.0 * out


@dataclasses.dataclass
class RooflineTerms:
    flops: float                 # per device
    hbm_bytes: float             # per device
    ici_bytes: float             # per device, intra-pod collectives
    dcn_bytes: float             # per device, cross-pod collectives
    collective_ops: Dict[str, float]

    @property
    def compute_s(self) -> float:
        return self.flops / hw.PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / hw.HBM_BW

    @property
    def collective_s(self) -> float:
        return self.ici_bytes / hw.ICI_BW + self.dcn_bytes / hw.DCN_BW

    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    def to_dict(self) -> Dict:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "ici_bytes_per_device": self.ici_bytes,
            "dcn_bytes_per_device": self.dcn_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant(),
            "collective_ops": self.collective_ops,
        }


_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def analyze_hlo(text: str, *, pod_size: int = 256) -> RooflineTerms:
    comps = parse_hlo(text)
    mult, control, entry = _propagate_multipliers(comps)

    flops = 0.0
    hbm = 0.0
    ici = 0.0
    dcn = 0.0
    coll_ops: Dict[str, float] = defaultdict(float)

    # fusion-computation facts for in-place / staging normalization
    fusion_root: Dict[str, str] = {}
    fusion_dus_update: Dict[str, int] = {}
    for cname, comp in comps.items():
        if not comp.instrs:
            continue
        last = comp.instrs[-1]
        fusion_root[cname] = last.opcode
        for ins in comp.instrs:
            if ins.opcode == "dynamic-update-slice":
                ops = _OPERAND.findall(ins.args)
                local = {i.name: _shape_bytes(i.type_str)
                         for i in comp.instrs}
                if len(ops) > 1:
                    fusion_dus_update[cname] = local.get(ops[1], 0)

    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        shapes = {i.name: _result_dims(i.type_str) for i in comp.instrs}
        sizes = {i.name: _shape_bytes(i.type_str) for i in comp.instrs}
        dtypes = {}
        for i in comp.instrs:
            sm = _SHAPE.search(i.type_str)
            dtypes[i.name] = sm.group(1) if sm else "f32"
        for ins in comp.instrs:
            if ins.opcode == "dot":
                flops += m * _dot_flops(ins, shapes)
            elif ins.opcode == "convolution":
                flops += m * _conv_flops(ins, shapes)
            elif ins.opcode == "fusion":
                # dots/convs inside fused computations are picked up when
                # we walk those computations (they inherit the multiplier
                # through the call edge); nothing to do here for flops.
                pass
            if not control.get(cname):
                continue
            if ins.opcode in _SKIP_BYTES_OPS or ins.opcode == "while":
                continue
            op_bytes = sizes.get(ins.name, _shape_bytes(ins.type_str))
            operand_names = _OPERAND.findall(ins.args)
            operand_bytes = sum(sizes.get(o, 0) for o in operand_names)
            # indexed/windowed ops touch only slice-sized data, not the
            # full operand buffer:
            if ins.opcode in ("dynamic-slice", "gather", "slice",
                              "concatenate", "reshape", "transpose",
                              "broadcast", "reverse", "pad"):
                total = 2 * op_bytes
            elif ins.opcode in ("dynamic-update-slice", "scatter"):
                upd = sizes.get(operand_names[1], 0) if len(operand_names) > 1 \
                    else op_bytes
                total = 2 * upd
            elif ins.opcode == "fusion":
                callee = None
                cm = _CALL_ATTR.search(ins.args + " " + ins.attrs)
                if cm:
                    callee = cm.group(1)
                if callee in fusion_dus_update:
                    # in-place update fusion (scan save-stack / KV write):
                    # TPU aliases the big buffer; traffic = the slice
                    small_ops = sum(b for o in operand_names
                                    if (b := sizes.get(o, 0)) != op_bytes)
                    total = 2 * fusion_dus_update[callee] + small_ops
                else:
                    total = op_bytes + operand_bytes
                    # CPU stages bf16 values as f32 fusion results (convert
                    # roots) — a TPU build keeps bf16: halve those.
                    if (dtypes.get(ins.name) == "f32"
                            and fusion_root.get(callee) == "convert"):
                        total -= op_bytes // 2
            else:
                total = op_bytes + operand_bytes
            # CPU-backend normalization: XLA's CPU pipeline computes bf16
            # dots in f32 and "promotes" bf16 all-reduces to f32; a TPU
            # build keeps them bf16.  Normalize so the roofline reflects
            # the TPU program, not CPU staging (EXPERIMENTS.md §Dry-run).
            if ins.opcode in ("dot", "convolution") \
                    and dtypes.get(ins.name) == "f32" \
                    and any(dtypes.get(o) == "bf16"
                            for o in operand_names):
                total -= op_bytes // 2
            if ins.opcode in COLLECTIVES:
                # all-gather: per-device traffic ~ full (output) size;
                # others: operand size
                volume = op_bytes if ins.opcode == "all-gather" \
                    else (operand_bytes if operand_bytes else op_bytes)
                if ins.opcode == "all-reduce" and (
                        "_promoted" in ins.args or "_promoted" in ins.attrs):
                    volume *= 0.5        # bf16 on TPU, f32-promoted on CPU
                rg = _REPL_GROUPS.search(ins.attrs) or _REPL_GROUPS.search(ins.args)
                span = int(rg.group(2)) if rg else 1
                groups = int(rg.group(1)) if rg else 1
                cross_pod = (groups * span > pod_size and span > pod_size) \
                    or (groups > 1 and span > pod_size)
                # ring cost factor ~ 2*(n-1)/n for all-reduce, (n-1)/n else
                factor = 2.0 if ins.opcode == "all-reduce" else 1.0
                eff = volume * factor
                coll_ops[ins.opcode] += m * eff
                if span > pod_size:
                    dcn += m * eff
                else:
                    ici += m * eff
            else:
                hbm += m * total
    return RooflineTerms(flops=flops, hbm_bytes=hbm, ici_bytes=ici,
                         dcn_bytes=dcn, collective_ops=dict(coll_ops))
