from repro.roofline import hw
from repro.roofline.analysis import RooflineTerms, analyze_hlo, parse_hlo

__all__ = ["hw", "RooflineTerms", "analyze_hlo", "parse_hlo"]
