"""Jit-cache growth tracking: compile counts as trace counters.

Generalizes the serve layer's ``compile_count()`` (which read
``jax.jit``'s private ``_cache_size()`` on one function) into a tracker
any component can point at its jitted entry points.  The contract:

  * the FIRST compile of each watched function is expected (jit is
    lazy; the sparse->plain engine swap at the prune boundary is a new
    function and gets its own expected first compile);
  * any growth beyond that is an *unexpected recompile* — a shape or
    dtype leaked into a trace, exactly the regression the ROADMAP's
    "zero steady-state recompiles" line guards — and is emitted as a
    ``compile/<name>`` counter with ``attrs.unexpected > 0``.

``_cache_size`` is a private jax API; :func:`cache_size` degrades to
``None`` on wrappers that don't expose it (e.g. the mesh-sharded
engine closure), and the tracker silently skips those.
"""
from __future__ import annotations

from typing import Optional


def cache_size(fn) -> Optional[int]:
    """Entries in a jitted function's compilation cache (None if the
    object does not expose jit's cache API)."""
    probe = getattr(fn, "_cache_size", None)
    if probe is None:
        return None
    try:
        return int(probe())
    except Exception:
        return None


class _Watch:
    __slots__ = ("fn", "last", "allow", "compiles", "unexpected")

    def __init__(self, fn, last):
        self.fn = fn
        self.last = last        # cache size at last check
        self.allow = 1          # expected compiles not yet consumed
        self.compiles = 0       # growth observed since watch()
        self.unexpected = 0     # growth beyond the granted allowance


class CompileTracker:
    """Watches jitted functions and emits cache-growth counters.

    Each ``watch()`` call grants ONE expected compile: the initial
    registration covers jit's lazy first trace, and re-watching at a
    declared recompile boundary (the trainers re-watch from
    ``_rebuild_steps`` after pruning) covers the new shape signature —
    the memoized engines can hand back the same underlying
    ``PjitFunction`` pre- and post-prune, so fn identity alone cannot
    distinguish the expected prune-boundary compile from a leak.
    """

    def __init__(self, tracer):
        self._tracer = tracer
        self._watched = {}

    def watch(self, name: str, fn) -> bool:
        """(Re)register ``fn`` under ``name``, granting one expected
        compile; entries already in the cache at first watch don't
        count.  Returns False if ``fn`` does not expose a jit cache
        (not watched)."""
        size = cache_size(fn)
        if size is None:
            self._watched.pop(name, None)
            return False
        prev = self._watched.get(name)
        if prev is not None and prev.fn is fn:
            prev.allow += 1                  # declared recompile boundary
            return True
        self._watched[name] = _Watch(fn, size)
        return True

    def check(self, **attrs) -> int:
        """Poll every watched cache; emit a ``compile/<name>`` counter
        per grown cache and return the number of *unexpected* compiles
        seen in this check (growth beyond the granted allowance)."""
        unexpected_total = 0
        for name, w in self._watched.items():
            cur = cache_size(w.fn)
            if cur is None or cur <= w.last:
                continue
            delta = cur - w.last
            w.last = cur
            expected = min(delta, w.allow)
            w.allow -= expected
            w.compiles += delta
            unexpected = delta - expected
            w.unexpected += unexpected
            unexpected_total += unexpected
            self._tracer.counter("compile/" + name, delta, total=cur,
                                 unexpected=unexpected, **attrs)
        return unexpected_total

    def compiles(self) -> int:
        """Total compiles observed across watched functions."""
        return sum(w.compiles for w in self._watched.values())

    def recompiles(self) -> int:
        """Compiles beyond the granted allowances (the leaks)."""
        return sum(w.unexpected for w in self._watched.values())
