"""Trace-derived metrics: phase totals, pipeline overlap, recompiles.

Post-hoc analysis of a ``trace.jsonl`` — nothing here runs on the hot
path.  The headline number is the **overlap ratio**: the trainers' "run()"
double-buffers rounds (``_start_round(r+1)`` executes while round r's
device compute is in flight, before ``_finish_round(r)`` syncs its
losses), and the phase spans make that overlap directly measurable:

    window(r)  = loss_sync(r).t0 - dispatch(r).t1
                 (the in-flight gap of round r)
    hidden(r)  = host-side span time of round r+1 (host_prep, h2d,
                 dispatch) clipped to window(r)
    overlap    = sum_r hidden(r) / sum_r window(r)

~1.0 means the next round's host prep + H2D staging is fully hidden
behind device compute (the ROADMAP's "as fast as the hardware allows"
north star); ~0.0 means stepped, serialized rounds.  Spans are only
compared within one tracer session (between ``meta`` lines) because
``perf_counter`` readings are not comparable across processes.
"""
from __future__ import annotations

import json
from typing import List, Optional, Union

# next-round host-side phases that can hide behind in-flight device work
HOST_PHASES = ("round/host_prep", "round/h2d", "round/dispatch")


def read_trace(path: str) -> List[dict]:
    """Parse a trace.jsonl into a list of event dicts (skips blanks)."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def _sessions(events: List[dict]) -> List[List[dict]]:
    """Split a trace at its meta lines (one session per tracer open)."""
    sessions, cur = [], []
    for ev in events:
        if ev.get("ev") == "meta":
            if cur:
                sessions.append(cur)
            cur = []
        else:
            cur.append(ev)
    if cur:
        sessions.append(cur)
    return sessions


def _overlap(session: List[dict]):
    """(hidden_s, window_s) summed over consecutive round pairs."""
    disp_end, sync_start, host = {}, {}, {}
    for ev in session:
        if ev.get("ev") != "span":
            continue
        r = ev.get("attrs", {}).get("round")
        if r is None:
            continue
        if ev["name"] == "round/dispatch":
            disp_end[r] = max(disp_end.get(r, ev["t1"]), ev["t1"])
        elif ev["name"] == "round/loss_sync":
            sync_start[r] = min(sync_start.get(r, ev["t0"]), ev["t0"])
        if ev["name"] in HOST_PHASES:
            host.setdefault(r, []).append((ev["t0"], ev["t1"]))
    hidden = window = 0.0
    for r, t_d in disp_end.items():
        t_s = sync_start.get(r)
        if t_s is None or t_s <= t_d:
            continue
        window += t_s - t_d
        for (a, b) in host.get(r + 1, []):
            hidden += max(0.0, min(b, t_s) - max(a, t_d))
    return hidden, window


def summarize_trace(trace: Union[str, List[dict]]) -> dict:
    """Aggregate a trace into per-phase totals, the measured overlap
    ratio, and compile/recompile counts.

    Returns ``{"sessions", "rounds", "phases": {name: {"n", "total_s",
    "mean_s", "max_s"}}, "overlap_ratio" (None when no in-flight window
    was observed), "overlap_hidden_s", "overlap_window_s", "compiles",
    "recompiles"}``.
    """
    events = read_trace(trace) if isinstance(trace, str) else list(trace)
    phases, rounds = {}, set()
    compiles = recompiles = 0
    for ev in events:
        kind = ev.get("ev")
        if kind == "span":
            st = phases.setdefault(ev["name"],
                                   {"n": 0, "total_s": 0.0, "max_s": 0.0})
            st["n"] += 1
            st["total_s"] += ev["dur_s"]
            st["max_s"] = max(st["max_s"], ev["dur_s"])
            r = ev.get("attrs", {}).get("round")
            if r is not None:
                rounds.add(r)
        elif kind == "counter" and ev["name"].startswith("compile/"):
            compiles += ev.get("value", 0)
            recompiles += ev.get("attrs", {}).get("unexpected", 0)
    for st in phases.values():
        st["mean_s"] = st["total_s"] / st["n"]
    hidden = window = 0.0
    sessions = _sessions(events)
    for session in sessions:
        h, w = _overlap(session)
        hidden += h
        window += w
    return {
        "sessions": len(sessions),
        "rounds": len(rounds),
        "phases": phases,
        "overlap_ratio": (hidden / window) if window > 0 else None,
        "overlap_hidden_s": hidden,
        "overlap_window_s": window,
        "compiles": int(compiles),
        "recompiles": int(recompiles),
    }
