"""ObsSpec: the declarative, sweepable obs configuration.

Mirrors FaultSpec's shape: a frozen dataclass field on ExperimentSpec,
JSON-round-trippable (``to_dict``/``from_dict`` with unknown-key
filtering so old manifests keep loading), addressable from sweep axes
as ``"obs.enabled"`` etc.

``enabled`` is a tri-state: ``None`` (the default) defers to
``$FEDPHD_OBS`` via the single resolve code path, so a spec that never
mentions obs can still be traced from the environment, while an
explicit ``True``/``False`` in the spec always wins (same precedence
contract as engine/backend/precision).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.experiment.resolve import resolve_knob


@dataclasses.dataclass(frozen=True)
class ObsSpec:
    """Tracing + metrics configuration (disabled by default)."""
    # tri-state: True/False are explicit; None resolves $FEDPHD_OBS > off
    enabled: Optional[bool] = None
    # trace.jsonl path; "" = next to the run's checkpoint (or CWD)
    trace: str = ""
    # events buffered before a file flush; 1 = write-through (default:
    # the trace must be readable the moment a run stops, and the hot
    # path is only touched when tracing is on anyway)
    flush_every: int = 1
    # watch jit caches and flag growth beyond the first compile per fn
    compile_tracking: bool = True

    def __post_init__(self):
        if self.flush_every < 1:
            raise ValueError(f"flush_every must be >= 1, got "
                             f"{self.flush_every}")

    @property
    def resolved_enabled(self) -> bool:
        """``enabled`` if explicit, else ``$FEDPHD_OBS`` > off."""
        explicit = None if self.enabled is None else \
            ("on" if self.enabled else "off")
        return resolve_knob("obs", explicit) == "on"

    def replace(self, **kw) -> "ObsSpec":
        return dataclasses.replace(self, **kw)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ObsSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})
