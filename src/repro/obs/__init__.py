"""Observability layer: structured tracing for rounds, sweeps, serving.

Zero-overhead-when-disabled by construction: every instrumented call
site holds a tracer that is either a real :class:`~repro.obs.trace.
Tracer` (JSON-lines span/counter/event emission) or the shared
:data:`~repro.obs.trace.NULL_TRACER` whose methods are no-ops and whose
``span()`` returns one reusable no-op context manager.  Tracing is
host-side wall-clock only — it never touches RNG streams, device
buffers, or numerics — so ``obs`` disabled (the default) is a bitwise
no-op on every engine x backend x precision leg, and *enabled* changes
timing visibility, not trajectories (asserted by tests/test_obs.py).

Enable per-run via ``ExperimentSpec(obs=ObsSpec(enabled=True))``, the
CLI ``--trace`` flag, or ``$FEDPHD_OBS=1`` (resolution contract:
``explicit > env > off``, owned by repro.experiment.resolve).

Trace schema: see repro.obs.trace (one JSON object per line, stable
golden keys) and README "Observability".
"""
from repro.obs.compile_tracker import CompileTracker, cache_size
from repro.obs.metrics import read_trace, summarize_trace
from repro.obs.spec import ObsSpec
from repro.obs.trace import (NULL_TRACER, SCHEMA_VERSION, NullTracer,
                             Tracer, make_tracer)

__all__ = ["CompileTracker", "cache_size", "read_trace", "summarize_trace",
           "ObsSpec", "NULL_TRACER", "SCHEMA_VERSION", "NullTracer",
           "Tracer", "make_tracer"]
