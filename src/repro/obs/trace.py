"""The trace emitters: Tracer (JSON-lines) and the no-op NullTracer.

Schema (stable; tests/test_obs.py pins the golden keys) — one JSON
object per line of ``trace.jsonl``:

  meta     {"ev":"meta", "schema":1, "wall_time":<epoch s>, "attrs":{}}
           one per tracer open; a resumed run appends a new meta line,
           so sessions are delimited in-band
  span     {"ev":"span", "name":<str>, "t0":<s>, "t1":<s>,
            "dur_s":<s>, "attrs":{...}}
           t0/t1 are time.perf_counter() readings — monotonic and
           mutually comparable within one session (between two meta
           lines), which is all the overlap math needs
  event    {"ev":"event", "name":<str>, "t":<s>, "attrs":{...}}
  counter  {"ev":"counter", "name":<str>, "t":<s>, "value":<num>,
            "attrs":{...}}

Span names in use: ``round/host_prep``, ``round/h2d``,
``round/dispatch``, ``round/loss_sync``, ``round/edge_agg``,
``round/cloud_agg``, ``round/prune`` (trainers; ``attrs.round`` keys
the round), ``serve/tick`` (DiffusionServer).  Counter names:
``compile/<fn>`` (jit-cache growth; ``attrs.unexpected`` > 0 flags a
recompile beyond the expected first compile).  Event names:
``fault/draw`` (availability summary), ``serve/fault``.

Everything here is host-side bookkeeping: no jax imports, no device
syncs, no RNG.  The NULL_TRACER singleton makes the disabled path a
handful of attribute lookups and a no-op context manager — cheap
enough to leave the instrumentation permanently in the hot loops.
"""
from __future__ import annotations

import json
import time
from typing import Optional

SCHEMA_VERSION = 1

# golden key sets (tests/test_obs.py asserts these exact sets per ev)
SPAN_KEYS = ("ev", "name", "t0", "t1", "dur_s", "attrs")
EVENT_KEYS = ("ev", "name", "t", "attrs")
COUNTER_KEYS = ("ev", "name", "t", "value", "attrs")
META_KEYS = ("ev", "schema", "wall_time", "attrs")


class _NullSpan:
    """Reusable no-op context manager (one shared instance)."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """The disabled tracer: every method is a no-op.

    Shared singleton (:data:`NULL_TRACER`); trainers hold it when obs
    is off so call sites never branch on "is tracing on?".
    """
    enabled = False
    compile_tracking = False

    def span(self, name, **attrs):
        return _NULL_SPAN

    def record_span(self, name, t0, t1, **attrs):
        pass

    def event(self, name, **attrs):
        pass

    def counter(self, name, value, **attrs):
        pass

    def flush(self):
        pass

    def close(self):
        pass


NULL_TRACER = NullTracer()


class _Span:
    __slots__ = ("_tracer", "_name", "_attrs", "_t0")

    def __init__(self, tracer, name, attrs):
        self._tracer = tracer
        self._name = name
        self._attrs = attrs

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        self._tracer._emit({"ev": "span", "name": self._name,
                            "t0": self._t0, "t1": t1,
                            "dur_s": t1 - self._t0, "attrs": self._attrs})
        return False


class Tracer:
    """JSON-lines trace writer (append mode: resumes extend the file)."""
    enabled = True

    def __init__(self, path: str, *, flush_every: int = 1,
                 compile_tracking: bool = True):
        self.path = str(path)
        self.compile_tracking = compile_tracking
        self._flush_every = max(1, int(flush_every))
        self._buf = []
        self._f = open(self.path, "a")
        self._emit({"ev": "meta", "schema": SCHEMA_VERSION,
                    "wall_time": time.time(), "attrs": {}})

    # -- emission ----------------------------------------------------------

    def span(self, name: str, **attrs) -> _Span:
        """Context manager timing a phase; attrs land on the span line."""
        return _Span(self, name, attrs)

    def record_span(self, name: str, t0: float, t1: float, **attrs):
        """A span with externally measured perf_counter endpoints."""
        self._emit({"ev": "span", "name": name, "t0": t0, "t1": t1,
                    "dur_s": t1 - t0, "attrs": attrs})

    def event(self, name: str, **attrs):
        self._emit({"ev": "event", "name": name,
                    "t": time.perf_counter(), "attrs": attrs})

    def counter(self, name: str, value, **attrs):
        self._emit({"ev": "counter", "name": name,
                    "t": time.perf_counter(), "value": value,
                    "attrs": attrs})

    # -- plumbing ----------------------------------------------------------

    def _emit(self, obj: dict):
        if self._f is None:
            return
        self._buf.append(json.dumps(obj, sort_keys=True))
        if len(self._buf) >= self._flush_every:
            self.flush()

    def flush(self):
        if self._f is None or not self._buf:
            return
        self._f.write("\n".join(self._buf) + "\n")
        self._f.flush()
        self._buf.clear()

    def close(self):
        if self._f is None:
            return
        self.flush()
        self._f.close()
        self._f = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass


def make_tracer(obs=None, default_path: Optional[str] = None):
    """Build the run's tracer from an ObsSpec (or None).

    Returns :data:`NULL_TRACER` unless the spec resolves enabled
    (explicit ``enabled`` > ``$FEDPHD_OBS`` > off).  The trace path is
    ``obs.trace`` if set, else ``default_path`` (callers pass a file
    next to the checkpoint), else ``trace.jsonl`` in the CWD.
    """
    if obs is None or not obs.resolved_enabled:
        return NULL_TRACER
    path = obs.trace or default_path or "trace.jsonl"
    return Tracer(path, flush_every=obs.flush_every,
                  compile_tracking=obs.compile_tracking)
