from repro.data.synthetic import (DatasetSpec, CIFAR10_LIKE, CELEBA_LIKE,
                                  SMOKE_DATA, make_dataset, make_token_dataset)
from repro.data.partition import iid, shards_per_client, dirichlet
from repro.data.pipeline import ClientData

__all__ = ["DatasetSpec", "CIFAR10_LIKE", "CELEBA_LIKE", "SMOKE_DATA",
           "make_dataset", "make_token_dataset", "iid", "shards_per_client",
           "dirichlet", "ClientData"]
