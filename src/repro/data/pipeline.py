"""Minimal batching pipeline for client-local training."""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np


class ClientData:
    """One client's local dataset with epoch iteration (Alg. 2)."""

    def __init__(self, images: np.ndarray, labels: np.ndarray, *,
                 batch_size: int, seed: int = 0):
        self.images = images
        self.labels = labels
        self.batch_size = min(batch_size, len(images))
        self._rng = np.random.default_rng(seed)

    def __len__(self) -> int:
        return len(self.images)

    def epoch(self) -> Iterator[Dict[str, np.ndarray]]:
        idx = self._rng.permutation(len(self.images))
        nb = max(len(idx) // self.batch_size, 1)
        for b in range(nb):
            sel = idx[b * self.batch_size:(b + 1) * self.batch_size]
            yield {"images": self.images[sel], "labels": self.labels[sel]}

    def batches(self, num: int) -> Iterator[Dict[str, np.ndarray]]:
        """num batches, reshuffling between epochs."""
        produced = 0
        while produced < num:
            for batch in self.epoch():
                yield batch
                produced += 1
                if produced >= num:
                    return
