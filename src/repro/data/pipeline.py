"""Minimal batching pipeline for client-local training."""
from __future__ import annotations

from typing import Dict, Iterator, Sequence, Tuple

import numpy as np


class ClientData:
    """One client's local dataset with epoch iteration (Alg. 2)."""

    def __init__(self, images: np.ndarray, labels: np.ndarray, *,
                 batch_size: int, seed: int = 0):
        self.images = images
        self.labels = labels
        self.batch_size = min(batch_size, len(images))
        self._rng = np.random.default_rng(seed)

    def __len__(self) -> int:
        return len(self.images)

    # -- checkpoint support --------------------------------------------------
    # The shuffle RNG advances once per epoch a client participates in,
    # so bitwise kill-and-resume (repro.experiment) must carry it.
    def rng_state(self) -> dict:
        """JSON-serializable bit-generator state of the shuffle RNG."""
        return self._rng.bit_generator.state

    def set_rng_state(self, state: dict) -> None:
        self._rng.bit_generator.state = state

    def epoch(self) -> Iterator[Dict[str, np.ndarray]]:
        idx = self._rng.permutation(len(self.images))
        nb = max(len(idx) // self.batch_size, 1)
        for b in range(nb):
            sel = idx[b * self.batch_size:(b + 1) * self.batch_size]
            yield {"images": self.images[sel], "labels": self.labels[sel]}

    @property
    def steps_per_epoch(self) -> int:
        return max(len(self.images) // self.batch_size, 1)

    def stacked_epochs(self, num_epochs: int, steps: int | None = None
                       ) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
        """Pre-shuffled batches for a whole local round, stacked for scan.

        Returns ``(batches, valid)`` where every leaf of ``batches`` has
        a leading step axis of length ``steps`` and ``valid`` is a
        (steps,) bool mask.  The first ``num_epochs * steps_per_epoch``
        entries are exactly the batches ``epoch()`` would have yielded
        (same RNG consumption, so a sequential and a stacked consumer
        stay in lockstep); the tail repeats the last real batch with
        ``valid=False`` so ragged clients pad to a shape-static scan
        length without affecting training.
        """
        stack: list = []
        for _ in range(num_epochs):
            stack.extend(self.epoch())
        n_real = len(stack)
        steps = n_real if steps is None else steps
        if steps < n_real:
            raise ValueError(f"steps={steps} < {n_real} real batches")
        stack.extend([stack[-1]] * (steps - n_real))
        batches = {k: np.stack([b[k] for b in stack]) for k in stack[0]}
        valid = np.arange(steps) < n_real
        return batches, valid

    def batches(self, num: int) -> Iterator[Dict[str, np.ndarray]]:
        """num batches, reshuffling between epochs."""
        produced = 0
        while produced < num:
            for batch in self.epoch():
                yield batch
                produced += 1
                if produced >= num:
                    return


def stack_round(datas: Sequence[ClientData], num_epochs: int
                ) -> Tuple[Dict[str, np.ndarray], np.ndarray, bool]:
    """Stack every client's ``stacked_epochs`` onto a leading client axis.

    Pads all clients to the round's max step count and returns
    ``(batches, valid, masked)``: every ``batches`` leaf has shape
    (C, S, B, ...), ``valid`` is the (C, S) padded-step mask, and
    ``masked`` is False when no client needed padding — the engine uses
    that to elide the per-step select ops at trace time (the common
    uniform-client case).  Requires a uniform per-client batch shape
    (callers gate on ``repro.fl.engine.uniform_batch_shape``).
    """
    steps = max(d.steps_per_epoch for d in datas) * num_epochs
    per = [d.stacked_epochs(num_epochs, steps) for d in datas]
    batches = {k: np.stack([b[k] for b, _ in per]) for k in per[0][0]}
    valid = np.stack([v for _, v in per])
    return batches, valid, not bool(valid.all())
