"""Non-IID federated partitioners (paper §V-A2 / Fig. 3).

- ``shards_per_client``: each client holds images from exactly k classes
  (paper: CIFAR-10 k=2, CelebA k=1).
- ``dirichlet``: Dir(alpha) label-skew partitioner (standard FL benchmark).
- ``iid``: uniform random split (the paper's FedAvg-IID reference).
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np


def iid(labels: np.ndarray, num_clients: int, seed: int = 0
        ) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(labels))
    return [np.sort(s) for s in np.array_split(idx, num_clients)]


def shards_per_client(labels: np.ndarray, num_clients: int,
                      classes_per_client: int, seed: int = 0
                      ) -> List[np.ndarray]:
    """Each client gets ``classes_per_client`` class-shards (paper setup)."""
    rng = np.random.default_rng(seed)
    num_shards = num_clients * classes_per_client
    by_class: Dict[int, np.ndarray] = {}
    for c in np.unique(labels):
        idx = np.where(labels == c)[0]
        rng.shuffle(idx)
        by_class[int(c)] = idx
    # build shards: sort by class, slice into equal shards
    order = np.concatenate([by_class[c] for c in sorted(by_class)])
    shards = np.array_split(order, num_shards)
    shard_ids = rng.permutation(num_shards)
    out = []
    for n in range(num_clients):
        take = shard_ids[n * classes_per_client:(n + 1) * classes_per_client]
        out.append(np.sort(np.concatenate([shards[s] for s in take])))
    return out


def dirichlet(labels: np.ndarray, num_clients: int, alpha: float = 0.3,
              seed: int = 0, min_size: int = 2) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    while True:
        buckets: List[List[int]] = [[] for _ in range(num_clients)]
        for c in classes:
            idx = np.where(labels == c)[0]
            rng.shuffle(idx)
            props = rng.dirichlet(np.full(num_clients, alpha))
            cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
            for b, part in zip(buckets, np.split(idx, cuts)):
                b.extend(part.tolist())
        if min(len(b) for b in buckets) >= min_size:
            return [np.sort(np.asarray(b)) for b in buckets]
