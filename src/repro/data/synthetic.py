"""Synthetic class-structured datasets (hardware/data-gate substitute).

Real CIFAR-10 / CelebA are not downloadable in this container (repro
band 2/5), so the FL experiments use class-conditional Gaussian-mixture
images: every class has a deterministic smooth "prototype" pattern and
samples are prototype + structured noise.  This preserves exactly what
the paper's experiments need from the data: (i) distinct per-class
distributions (so non-IID partitions bite), (ii) a well-defined global
distribution for FID-style comparisons, (iii) image-shaped tensors for
the U-Net.  DESIGN.md §1 records the substitution.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    num_classes: int
    image_size: int
    channels: int = 3
    samples_per_class: int = 512


CIFAR10_LIKE = DatasetSpec("cifar10-like", num_classes=10, image_size=32)
CELEBA_LIKE = DatasetSpec("celeba-like", num_classes=4, image_size=64)
SMOKE_DATA = DatasetSpec("smoke", num_classes=4, image_size=16,
                         samples_per_class=64)


def _class_prototype(rng: np.random.Generator, size: int, channels: int):
    """Smooth low-frequency pattern per class."""
    coarse = rng.normal(size=(4, 4, channels))
    # bilinear upsample to (size, size)
    xi = np.linspace(0, 3, size)
    x0 = np.floor(xi).astype(int)
    x1 = np.minimum(x0 + 1, 3)
    w = xi - x0                                               # (size,)
    rows = (coarse[x0] * (1 - w)[:, None, None]
            + coarse[x1] * w[:, None, None])                  # (size, 4, C)
    proto = (rows[:, x0] * (1 - w)[None, :, None]
             + rows[:, x1] * w[None, :, None])                # (size, size, C)
    return np.tanh(proto * 1.5)


def make_dataset(spec: DatasetSpec, seed: int = 0
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (images (N,H,W,C) float32 in [-1,1], labels (N,) int32)."""
    rng = np.random.default_rng(seed)
    protos = np.stack([_class_prototype(rng, spec.image_size, spec.channels)
                       for _ in range(spec.num_classes)])
    images, labels = [], []
    for c in range(spec.num_classes):
        noise = rng.normal(scale=0.35,
                           size=(spec.samples_per_class, spec.image_size,
                                 spec.image_size, spec.channels))
        x = np.clip(protos[c][None] + noise, -1.0, 1.0)
        images.append(x.astype(np.float32))
        labels.append(np.full((spec.samples_per_class,), c, np.int32))
    perm = rng.permutation(spec.num_classes * spec.samples_per_class)
    return (np.concatenate(images)[perm], np.concatenate(labels)[perm])


def make_token_dataset(num_classes: int, vocab_size: int, seq_len: int,
                       samples_per_class: int, seed: int = 0
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """Class-conditional token sequences (for FL-over-LM extensions):
    each class has its own token unigram distribution."""
    rng = np.random.default_rng(seed)
    tokens, labels = [], []
    for c in range(num_classes):
        logits = rng.normal(size=(vocab_size,)) * 2.0
        p = np.exp(logits) / np.exp(logits).sum()
        t = rng.choice(vocab_size, size=(samples_per_class, seq_len), p=p)
        tokens.append(t.astype(np.int32))
        labels.append(np.full((samples_per_class,), c, np.int32))
    perm = rng.permutation(num_classes * samples_per_class)
    return np.concatenate(tokens)[perm], np.concatenate(labels)[perm]
