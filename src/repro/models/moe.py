"""Mixture-of-experts FFN with capacity-based scatter dispatch.

Baseline dispatch (this file) is fully dense-shape static: tokens are
scattered into an (E, C, d) buffer via position-in-expert indices computed
with a one-hot cumsum, batched expert matmuls run on the buffer, and
outputs are gathered back.  Under pjit the token axis shards over
("pod","data") and the expert axis over "model"; XLA inserts the
all-to-all-equivalent collectives.  The explicit shard_map all-to-all
variant lives in repro/launch/expert_parallel.py (perf hillclimb).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.common import activation_fn, dense_init
from repro.models.ffn import init_ffn, apply_ffn


def init_moe(key, d_model: int, moe: MoEConfig, *, activation: str,
             dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    E, de = moe.num_experts, moe.d_expert
    std = 1.0 / (d_model ** 0.5)
    p = {
        "router": dense_init(ks[0], d_model, E, jnp.float32),  # router in fp32
        "w_gate": (jax.random.normal(ks[1], (E, d_model, de)) * std).astype(dtype),
        "w_in": (jax.random.normal(ks[2], (E, d_model, de)) * std).astype(dtype),
        "w_out": (jax.random.normal(ks[3], (E, de, d_model)) * (de ** -0.5)).astype(dtype),
    }
    if moe.num_shared_experts > 0:
        p["shared"] = init_ffn(ks[4], d_model, moe.d_shared, glu=True,
                               bias=False, dtype=dtype)
    return p


def router_topk(logits, k: int) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """logits: (T, E) -> (weights (T,k), ids (T,k), probs (T,E))."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    weights, ids = jax.lax.top_k(probs, k)
    weights = weights / (jnp.sum(weights, axis=-1, keepdims=True) + 1e-9)
    return weights, ids, probs


def load_balance_loss(probs, ids, num_experts: int) -> jnp.ndarray:
    """Switch-style aux loss: E * sum_e f_e * P_e."""
    T, k = ids.shape
    counts = jnp.zeros((num_experts,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    f = counts / (T * k)
    P = jnp.mean(probs, axis=0)
    return num_experts * jnp.sum(f * P)


def capacity(T: int, k: int, num_experts: int, factor: float) -> int:
    c = int(T * k * factor / num_experts) + 1
    return max(8, -(-c // 8) * 8)  # round up to 8


def dispatch_indices(ids, num_experts: int, cap: int):
    """Position-in-expert for each (token, choice) pair.

    ids: (T, k) int32 expert assignments.
    Returns pos: (T, k) int32 position within the expert buffer, and
    keep: (T, k) bool (False = dropped, over capacity).
    """
    T, k = ids.shape
    flat = ids.reshape(-1)                                   # (T*k,)
    onehot = jax.nn.one_hot(flat, num_experts, dtype=jnp.int32)
    incl = jnp.cumsum(onehot, axis=0)                        # inclusive
    pos = jnp.take_along_axis(incl - onehot, flat[:, None], axis=1)[:, 0]
    keep = pos < cap
    return pos.reshape(T, k), keep.reshape(T, k)


def apply_moe(p, x, moe: MoEConfig, *, activation: str):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    k, E = moe.experts_per_token, moe.num_experts

    logits = xt.astype(jnp.float32) @ p["router"]
    weights, ids, probs = router_topk(logits, k)
    cap = capacity(T, k, E, moe.capacity_factor)
    pos, keep = dispatch_indices(ids, E, cap)

    # scatter tokens into (E, C, d)
    flat_ids = ids.reshape(-1)
    flat_pos = jnp.where(keep.reshape(-1), pos.reshape(-1), cap - 1)
    contrib = jnp.repeat(xt, k, axis=0) * keep.reshape(-1, 1).astype(xt.dtype)
    buf = jnp.zeros((E, cap, d), xt.dtype).at[flat_ids, flat_pos].add(contrib)

    # batched expert FFN (swiglu)
    act = activation_fn(activation)
    h = jnp.einsum("ecd,edf->ecf", buf, p["w_in"])
    g = act(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"]))
    out_buf = jnp.einsum("ecf,efd->ecd", g * h, p["w_out"])

    # gather + weighted combine
    gathered = out_buf[flat_ids, flat_pos]                   # (T*k, d)
    gathered = gathered * (weights.reshape(-1, 1) * keep.reshape(-1, 1)).astype(xt.dtype)
    out = jnp.sum(gathered.reshape(T, k, d), axis=1)

    if moe.num_shared_experts > 0:
        out = out + apply_ffn(p["shared"], xt, activation=activation, glu=True)

    aux = load_balance_loss(probs, ids, E) * moe.router_aux_loss
    return out.reshape(B, S, d), aux
