"""DDPM U-Net (Ho et al. 2020) in pure JAX — the paper's model (§V-A).

NHWC layout.  The dense CIFAR-10 config (base=128, mults (1,2,2,2),
2 res-blocks, attention at 16x16) reproduces the paper's 35.7M-parameter
U-Net.  Structured-pruning dependency groups: the *internal* channels of
every ResBlock (conv1-out ∥ temb-proj-out ∥ norm2 ∥ conv2-in) and the
per-head channels of every attention block — the DepGraph-consistent
groups that do not touch the residual stream (DESIGN.md §3).

Every tensor-core op (conv as im2col+GEMM, the temb denses, the
attention blocks) routes through :mod:`repro.models.ops`, selected by
``cfg.backend`` — xla einsums (default), the Pallas kernels, or the
pure-jnp reference.  ``apply_unet(..., masks=)`` runs the sparse-phase
masked forward: per-group 0/1 masks (keyed by PruneGroup name) are
applied as col/row masks on each block's GEMMs instead of pre-zeroing
the weights, so the pallas backend skips whole pruned MXU tiles —
numerically identical to ``apply_masks`` + plain forward.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import ops
from repro.models.common import group_norm, sinusoidal_embedding

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Conv helpers
# ---------------------------------------------------------------------------
def conv_init(key, kh, kw, cin, cout, scale=1.0):
    fan_in = kh * kw * cin
    w = jax.random.normal(key, (kh, kw, cin, cout)) * (scale / fan_in ** 0.5)
    return {"w": w.astype(jnp.float32), "b": jnp.zeros((cout,), jnp.float32)}


def conv(p, x, stride=1, padding="SAME", *, backend: str = "",
         col_mask=None, row_mask=None):
    """SAME conv — see :func:`repro.models.ops.conv` for the im2col
    lowering rationale and the masked sparse-phase contract."""
    return ops.conv(p, x, stride=stride, padding=padding, backend=backend,
                    col_mask=col_mask, row_mask=row_mask)


def dense_p(key, cin, cout, scale=1.0):
    w = jax.random.normal(key, (cin, cout)) * (scale / cin ** 0.5)
    return {"w": w.astype(jnp.float32), "b": jnp.zeros((cout,), jnp.float32)}


def dense(p, x, *, backend: str = "", col_mask=None):
    return ops.dense(p, x, backend=backend, col_mask=col_mask)


def norm_p(c):
    return {"scale": jnp.ones((c,), jnp.float32),
            "bias": jnp.zeros((c,), jnp.float32)}


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------
def init_resblock(key, cin, cout, temb_dim):
    ks = jax.random.split(key, 4)
    p = {
        "norm1": norm_p(cin),
        "conv1": conv_init(ks[0], 3, 3, cin, cout),
        "temb": dense_p(ks[1], temb_dim, cout),
        "norm2": norm_p(cout),
        "conv2": conv_init(ks[2], 3, 3, cout, cout, scale=1e-6),
    }
    if cin != cout:
        p["skip"] = conv_init(ks[3], 1, 1, cin, cout)
    return p


def apply_resblock(p, x, temb, *, dropout_rng=None, dropout=0.0,
                   backend: str = "", mask=None):
    """``mask`` (cout,): the block's PruneGroup mask over its internal
    channels — conv1/temb output columns, norm2 affine, conv2 input
    rows — exactly the members ``apply_masks`` would pre-zero."""
    h = jax.nn.silu(group_norm(x, p["norm1"]["scale"], p["norm1"]["bias"]))
    h = conv(p["conv1"], h, backend=backend, col_mask=mask)
    h = h + dense(p["temb"], jax.nn.silu(temb), backend=backend,
                  col_mask=mask)[:, None, None, :]
    n2s, n2b = p["norm2"]["scale"], p["norm2"]["bias"]
    if mask is not None:
        n2s, n2b = n2s * mask, n2b * mask
    h = jax.nn.silu(group_norm(h, n2s, n2b))
    if dropout > 0.0 and dropout_rng is not None:
        keep = jax.random.bernoulli(dropout_rng, 1.0 - dropout, h.shape)
        h = h * keep / (1.0 - dropout)
    h = conv(p["conv2"], h, backend=backend, row_mask=mask)
    skip = conv(p["skip"], x, backend=backend) if "skip" in p else x
    return skip + h


def init_attnblock(key, c):
    ks = jax.random.split(key, 2)
    return {
        "norm": norm_p(c),
        "qkv": conv_init(ks[0], 1, 1, c, 3 * c),
        "proj": conv_init(ks[1], 1, 1, c, c, scale=1e-6),
    }


def apply_attnblock(p, x, *, backend: str = "", mask=None):
    """``mask`` (c,): per-channel attention group mask — tiled over the
    q/k/v thirds of the qkv projection and the proj input rows."""
    B, H, W, C = x.shape
    h = group_norm(x, p["norm"]["scale"], p["norm"]["bias"])
    # np.concatenate for host (serving) masks: jnp would device-commit
    # them and drop ops' static sparsity specialization
    cat = np.concatenate if ops.is_static_mask(mask) else jnp.concatenate
    qkv_mask = None if mask is None else cat([mask, mask, mask])
    qkv = conv(p["qkv"], h, backend=backend, col_mask=qkv_mask)
    Ci = qkv.shape[-1] // 3          # may be < C after structured pruning
    qkv = qkv.reshape(B, H * W, 3, Ci)
    q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
    out = ops.attention(q[:, :, None, :], k[:, :, None, :],
                        v[:, :, None, :], causal=False,
                        backend=backend)[:, :, 0, :]
    out = out.reshape(B, H, W, Ci)
    return x + conv(p["proj"], out, backend=backend, row_mask=mask)


# ---------------------------------------------------------------------------
# U-Net
# ---------------------------------------------------------------------------
def init_unet(key, cfg: ModelConfig) -> Params:
    ch = cfg.base_channels
    temb_dim = ch * 4
    keys = iter(jax.random.split(key, 512))
    nk = lambda: next(keys)

    params: Params = {
        "temb1": dense_p(nk(), ch, temb_dim),
        "temb2": dense_p(nk(), temb_dim, temb_dim),
        "conv_in": conv_init(nk(), 3, 3, cfg.in_channels, ch),
        "norm_out": norm_p(ch),
        "conv_out": conv_init(nk(), 3, 3, ch, cfg.in_channels, scale=1e-6),
    }

    res = cfg.image_size
    down: List[Params] = []
    chans = [ch]
    cur = ch
    for lvl, mult in enumerate(cfg.channel_mults):
        cout = ch * mult
        blocks = []
        for _ in range(cfg.num_res_blocks):
            blk = {"res": init_resblock(nk(), cur, cout, temb_dim)}
            cur = cout
            if res in cfg.attn_resolutions:
                blk["attn"] = init_attnblock(nk(), cur)
            blocks.append(blk)
            chans.append(cur)
        lvl_p: Params = {"blocks": blocks}
        if lvl != len(cfg.channel_mults) - 1:
            lvl_p["down"] = conv_init(nk(), 3, 3, cur, cur)
            chans.append(cur)
            res //= 2
        down.append(lvl_p)
    params["down"] = down

    params["mid"] = {
        "res1": init_resblock(nk(), cur, cur, temb_dim),
        "attn": init_attnblock(nk(), cur),
        "res2": init_resblock(nk(), cur, cur, temb_dim),
    }

    up: List[Params] = []
    for lvl, mult in reversed(list(enumerate(cfg.channel_mults))):
        cout = ch * mult
        blocks = []
        for _ in range(cfg.num_res_blocks + 1):
            skip_c = chans.pop()
            blk = {"res": init_resblock(nk(), cur + skip_c, cout, temb_dim)}
            cur = cout
            if res in cfg.attn_resolutions:
                blk["attn"] = init_attnblock(nk(), cur)
            blocks.append(blk)
        lvl_p = {"blocks": blocks}
        if lvl != 0:
            lvl_p["up"] = conv_init(nk(), 3, 3, cur, cur)
            res *= 2
        up.append(lvl_p)
    params["up"] = up
    return params


def apply_unet(params: Params, cfg: ModelConfig, x, t, *,
               dropout_rng=None, train: bool = False,
               masks: Optional[Dict[str, jnp.ndarray]] = None):
    """Noise prediction eps_theta(x_t, t).  x: (B,H,W,C) NHWC; t: (B,).

    ``masks``: optional sparse-phase prune masks keyed by PruneGroup
    name (``make_masks`` output for ``unet_groups``) — the forward then
    equals ``apply_unet(apply_masks(params, groups, masks), ...)`` but
    routes the masked GEMMs through the backend's masked matmul.
    """
    backend = cfg.backend
    drop = cfg.dropout if train else 0.0
    rngs = iter(jax.random.split(dropout_rng, 256)) if dropout_rng is not None \
        else iter([])
    nrng = (lambda: next(rngs)) if dropout_rng is not None else (lambda: None)
    # PruneGroup names are "/".join(path) of the block prefix
    mk = (lambda *path: None) if masks is None else \
        (lambda *path: masks.get("/".join(map(str, path))))

    temb = sinusoidal_embedding(t, cfg.base_channels)
    temb = dense(params["temb2"], jax.nn.silu(
        dense(params["temb1"], temb, backend=backend)), backend=backend)

    h = conv(params["conv_in"], x, backend=backend)
    skips = [h]
    for lvl, lvl_p in enumerate(params["down"]):
        for bi, blk in enumerate(lvl_p["blocks"]):
            h = apply_resblock(blk["res"], h, temb, dropout_rng=nrng(),
                               dropout=drop, backend=backend,
                               mask=mk("down", lvl, "blocks", bi, "res"))
            if "attn" in blk:
                h = apply_attnblock(blk["attn"], h, backend=backend,
                                    mask=mk("down", lvl, "blocks", bi,
                                            "attn"))
            skips.append(h)
        if "down" in lvl_p:
            h = conv(lvl_p["down"], h, stride=2, backend=backend)
            skips.append(h)

    h = apply_resblock(params["mid"]["res1"], h, temb, dropout_rng=nrng(),
                       dropout=drop, backend=backend, mask=mk("mid", "res1"))
    h = apply_attnblock(params["mid"]["attn"], h, backend=backend,
                        mask=mk("mid", "attn"))
    h = apply_resblock(params["mid"]["res2"], h, temb, dropout_rng=nrng(),
                       dropout=drop, backend=backend, mask=mk("mid", "res2"))

    for lvl, lvl_p in enumerate(params["up"]):
        for bi, blk in enumerate(lvl_p["blocks"]):
            h = jnp.concatenate([h, skips.pop()], axis=-1)
            h = apply_resblock(blk["res"], h, temb, dropout_rng=nrng(),
                               dropout=drop, backend=backend,
                               mask=mk("up", lvl, "blocks", bi, "res"))
            if "attn" in blk:
                h = apply_attnblock(blk["attn"], h, backend=backend,
                                    mask=mk("up", lvl, "blocks", bi, "attn"))
        if "up" in lvl_p:
            B, H, W, C = h.shape
            h = jax.image.resize(h, (B, H * 2, W * 2, C), "nearest")
            h = conv(lvl_p["up"], h, backend=backend)

    h = jax.nn.silu(group_norm(h, params["norm_out"]["scale"],
                               params["norm_out"]["bias"]))
    return conv(params["conv_out"], h, backend=backend)
