"""DDPM U-Net (Ho et al. 2020) in pure JAX — the paper's model (§V-A).

NHWC layout.  The dense CIFAR-10 config (base=128, mults (1,2,2,2),
2 res-blocks, attention at 16x16) reproduces the paper's 35.7M-parameter
U-Net.  Structured-pruning dependency groups: the *internal* channels of
every ResBlock (conv1-out ∥ temb-proj-out ∥ norm2 ∥ conv2-in) and the
per-head channels of every attention block — the DepGraph-consistent
groups that do not touch the residual stream (DESIGN.md §3).
"""
from __future__ import annotations

from typing import Any, Dict, List

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import group_norm, sinusoidal_embedding

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Conv helpers
# ---------------------------------------------------------------------------
def conv_init(key, kh, kw, cin, cout, scale=1.0):
    fan_in = kh * kw * cin
    w = jax.random.normal(key, (kh, kw, cin, cout)) * (scale / fan_in ** 0.5)
    return {"w": w.astype(jnp.float32), "b": jnp.zeros((cout,), jnp.float32)}


def _same_pads(size: int, k: int, stride: int):
    out = -(-size // stride)
    pad = max((out - 1) * stride + k - size, 0)
    return out, (pad // 2, pad - pad // 2)


def conv(p, x, stride=1, padding="SAME"):
    """SAME conv lowered as im2col + einsum (matches lax.conv numerics
    to fp32 tolerance).

    The einsum formulation matters for the vectorized round engine
    (repro/fl/engine.py): under vmap the conv WEIGHTS carry a client
    axis, which XLA:CPU executes as a pathologically slow batched-
    filter convolution — and conv thunks inside lax.scan additionally
    lose the runtime thread pool.  As an einsum it batches into plain
    GEMMs, which stay fast both vmapped and inside scan.
    """
    if padding != "SAME":
        raise ValueError(f"im2col conv supports SAME padding only, "
                         f"got {padding!r}")
    w = p["w"]
    kh, kw, cin, cout = w.shape
    if kh == kw == 1 and stride == 1:
        return jnp.einsum("bhwc,cd->bhwd", x, w[0, 0]) + p["b"]
    H, W = x.shape[1], x.shape[2]
    oh, (ph0, ph1) = _same_pads(H, kh, stride)
    ow, (pw0, pw1) = _same_pads(W, kw, stride)
    xp = jnp.pad(x, ((0, 0), (ph0, ph1), (pw0, pw1), (0, 0)))
    cols = [xp[:, di:di + stride * (oh - 1) + 1:stride,
               dj:dj + stride * (ow - 1) + 1:stride, :]
            for di in range(kh) for dj in range(kw)]
    patches = jnp.stack(cols, axis=3)            # (B, oh, ow, kh*kw, cin)
    y = jnp.einsum("bhwkc,kcd->bhwd", patches, w.reshape(kh * kw, cin, cout))
    return y + p["b"]


def dense_p(key, cin, cout, scale=1.0):
    w = jax.random.normal(key, (cin, cout)) * (scale / cin ** 0.5)
    return {"w": w.astype(jnp.float32), "b": jnp.zeros((cout,), jnp.float32)}


def dense(p, x):
    return x @ p["w"] + p["b"]


def norm_p(c):
    return {"scale": jnp.ones((c,), jnp.float32),
            "bias": jnp.zeros((c,), jnp.float32)}


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------
def init_resblock(key, cin, cout, temb_dim):
    ks = jax.random.split(key, 4)
    p = {
        "norm1": norm_p(cin),
        "conv1": conv_init(ks[0], 3, 3, cin, cout),
        "temb": dense_p(ks[1], temb_dim, cout),
        "norm2": norm_p(cout),
        "conv2": conv_init(ks[2], 3, 3, cout, cout, scale=1e-6),
    }
    if cin != cout:
        p["skip"] = conv_init(ks[3], 1, 1, cin, cout)
    return p


def apply_resblock(p, x, temb, *, dropout_rng=None, dropout=0.0):
    h = jax.nn.silu(group_norm(x, p["norm1"]["scale"], p["norm1"]["bias"]))
    h = conv(p["conv1"], h)
    h = h + dense(p["temb"], jax.nn.silu(temb))[:, None, None, :]
    h = jax.nn.silu(group_norm(h, p["norm2"]["scale"], p["norm2"]["bias"]))
    if dropout > 0.0 and dropout_rng is not None:
        keep = jax.random.bernoulli(dropout_rng, 1.0 - dropout, h.shape)
        h = h * keep / (1.0 - dropout)
    h = conv(p["conv2"], h)
    skip = conv(p["skip"], x) if "skip" in p else x
    return skip + h


def init_attnblock(key, c):
    ks = jax.random.split(key, 2)
    return {
        "norm": norm_p(c),
        "qkv": conv_init(ks[0], 1, 1, c, 3 * c),
        "proj": conv_init(ks[1], 1, 1, c, c, scale=1e-6),
    }


def apply_attnblock(p, x):
    B, H, W, C = x.shape
    h = group_norm(x, p["norm"]["scale"], p["norm"]["bias"])
    qkv = conv(p["qkv"], h)
    Ci = qkv.shape[-1] // 3          # may be < C after structured pruning
    qkv = qkv.reshape(B, H * W, 3, Ci)
    q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
    logits = jnp.einsum("bqc,bkc->bqk", q, k) * (Ci ** -0.5)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bqk,bkc->bqc", probs, v).reshape(B, H, W, Ci)
    return x + conv(p["proj"], out)


# ---------------------------------------------------------------------------
# U-Net
# ---------------------------------------------------------------------------
def init_unet(key, cfg: ModelConfig) -> Params:
    ch = cfg.base_channels
    temb_dim = ch * 4
    keys = iter(jax.random.split(key, 512))
    nk = lambda: next(keys)

    params: Params = {
        "temb1": dense_p(nk(), ch, temb_dim),
        "temb2": dense_p(nk(), temb_dim, temb_dim),
        "conv_in": conv_init(nk(), 3, 3, cfg.in_channels, ch),
        "norm_out": norm_p(ch),
        "conv_out": conv_init(nk(), 3, 3, ch, cfg.in_channels, scale=1e-6),
    }

    res = cfg.image_size
    down: List[Params] = []
    chans = [ch]
    cur = ch
    for lvl, mult in enumerate(cfg.channel_mults):
        cout = ch * mult
        blocks = []
        for _ in range(cfg.num_res_blocks):
            blk = {"res": init_resblock(nk(), cur, cout, temb_dim)}
            cur = cout
            if res in cfg.attn_resolutions:
                blk["attn"] = init_attnblock(nk(), cur)
            blocks.append(blk)
            chans.append(cur)
        lvl_p: Params = {"blocks": blocks}
        if lvl != len(cfg.channel_mults) - 1:
            lvl_p["down"] = conv_init(nk(), 3, 3, cur, cur)
            chans.append(cur)
            res //= 2
        down.append(lvl_p)
    params["down"] = down

    params["mid"] = {
        "res1": init_resblock(nk(), cur, cur, temb_dim),
        "attn": init_attnblock(nk(), cur),
        "res2": init_resblock(nk(), cur, cur, temb_dim),
    }

    up: List[Params] = []
    for lvl, mult in reversed(list(enumerate(cfg.channel_mults))):
        cout = ch * mult
        blocks = []
        for _ in range(cfg.num_res_blocks + 1):
            skip_c = chans.pop()
            blk = {"res": init_resblock(nk(), cur + skip_c, cout, temb_dim)}
            cur = cout
            if res in cfg.attn_resolutions:
                blk["attn"] = init_attnblock(nk(), cur)
            blocks.append(blk)
        lvl_p = {"blocks": blocks}
        if lvl != 0:
            lvl_p["up"] = conv_init(nk(), 3, 3, cur, cur)
            res *= 2
        up.append(lvl_p)
    params["up"] = up
    return params


def apply_unet(params: Params, cfg: ModelConfig, x, t, *,
               dropout_rng=None, train: bool = False):
    """Noise prediction eps_theta(x_t, t).  x: (B,H,W,C) NHWC; t: (B,)."""
    drop = cfg.dropout if train else 0.0
    rngs = iter(jax.random.split(dropout_rng, 256)) if dropout_rng is not None \
        else iter([])
    nrng = (lambda: next(rngs)) if dropout_rng is not None else (lambda: None)

    temb = sinusoidal_embedding(t, cfg.base_channels)
    temb = dense(params["temb2"], jax.nn.silu(dense(params["temb1"], temb)))

    h = conv(params["conv_in"], x)
    skips = [h]
    for lvl, lvl_p in enumerate(params["down"]):
        for blk in lvl_p["blocks"]:
            h = apply_resblock(blk["res"], h, temb, dropout_rng=nrng(),
                               dropout=drop)
            if "attn" in blk:
                h = apply_attnblock(blk["attn"], h)
            skips.append(h)
        if "down" in lvl_p:
            h = conv(lvl_p["down"], h, stride=2)
            skips.append(h)

    h = apply_resblock(params["mid"]["res1"], h, temb, dropout_rng=nrng(),
                       dropout=drop)
    h = apply_attnblock(params["mid"]["attn"], h)
    h = apply_resblock(params["mid"]["res2"], h, temb, dropout_rng=nrng(),
                       dropout=drop)

    for lvl_p in params["up"]:
        for blk in lvl_p["blocks"]:
            h = jnp.concatenate([h, skips.pop()], axis=-1)
            h = apply_resblock(blk["res"], h, temb, dropout_rng=nrng(),
                               dropout=drop)
            if "attn" in blk:
                h = apply_attnblock(blk["attn"], h)
        if "up" in lvl_p:
            B, H, W, C = h.shape
            h = jax.image.resize(h, (B, H * 2, W * 2, C), "nearest")
            h = conv(lvl_p["up"], h)

    h = jax.nn.silu(group_norm(h, params["norm_out"]["scale"],
                               params["norm_out"]["bias"]))
    return conv(params["conv_out"], h)
