"""Dense feed-forward (optionally gated) blocks."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import activation_fn, dense_init


def init_ffn(key, d_model: int, d_ff: int, *, glu: bool, bias: bool,
             dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {
        "w_in": dense_init(ks[0], d_model, d_ff, dtype),
        "w_out": dense_init(ks[1], d_ff, d_model, dtype),
    }
    if glu:
        p["w_gate"] = dense_init(ks[2], d_model, d_ff, dtype)
    if bias:
        p["b_in"] = jnp.zeros((d_ff,), dtype)
        p["b_out"] = jnp.zeros((d_model,), dtype)
    return p


def apply_ffn(p, x, *, activation: str, glu: bool):
    act = activation_fn(activation)
    h = x @ p["w_in"]
    if "b_in" in p:
        h = h + p["b_in"]
    if glu:
        h = act(x @ p["w_gate"]) * h
    else:
        h = act(h)
    out = h @ p["w_out"]
    if "b_out" in p:
        out = out + p["b_out"]
    return out
