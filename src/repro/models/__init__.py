from repro.models.common import ApplyOptions, DEFAULT_OPTS
from repro.models import model

__all__ = ["ApplyOptions", "DEFAULT_OPTS", "model"]
