"""Unified model interface + dry-run input specs.

``init / loss_fn / prefill / decode / init_cache`` dispatch on
``cfg.arch_type`` so launchers, the FL runtime and the dry-run never
branch on model family themselves.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.diffusion import ddpm_loss, linear_schedule
from repro.models import transformer as tfm
from repro.models import unet as unet_lib
from repro.models.common import ApplyOptions, DEFAULT_OPTS, dtype_of

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def init(rng, cfg: ModelConfig) -> Params:
    if cfg.arch_type == "unet":
        return unet_lib.init_unet(rng, cfg)
    return tfm.init_params(rng, cfg)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------
def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            rng, opts: ApplyOptions = DEFAULT_OPTS, *,
            masks=None) -> jnp.ndarray:
    """``masks``: optional sparse-phase prune masks (PruneGroup name ->
    0/1 row); the U-Net forward then routes its GEMMs through the
    backend's masked matmul instead of training on pre-zeroed weights
    (transformer archs ignore it — their sparse phase is mask-free)."""
    if cfg.arch_type == "unet":
        schedule = linear_schedule(cfg.diffusion_steps)
        eps_fn = lambda x_t, t: unet_lib.apply_unet(params, cfg, x_t, t,
                                                    masks=masks)
        return ddpm_loss(eps_fn, schedule, batch["images"], rng)
    hidden, aux = tfm.forward(params, cfg, batch, opts)
    return tfm.chunked_xent(params, cfg, hidden, batch["labels"],
                            opts=opts) + aux


# ---------------------------------------------------------------------------
# Inference
# ---------------------------------------------------------------------------
def prefill(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            opts: ApplyOptions = DEFAULT_OPTS):
    """Full-sequence forward; returns last-position logits (B, V)."""
    hidden, _ = tfm.forward(params, cfg, batch, opts)
    last = hidden[:, -1, :]
    return tfm.logits_from_hidden(params, cfg, last[:, None, :])[:, 0, :]


def init_cache(params: Params, cfg: ModelConfig, batch: int, seq_len: int,
               *, opts: ApplyOptions = DEFAULT_OPTS):
    enc_out = None
    if cfg.arch_type == "encdec":
        enc_out = jnp.zeros((batch, cfg.encoder_seq_len, cfg.d_model),
                            dtype_of(cfg.dtype))
    return tfm.init_cache(params, cfg, batch, seq_len, enc_out=enc_out,
                          opts=opts)


def decode(params: Params, cache, cfg: ModelConfig, tokens,
           opts: ApplyOptions = DEFAULT_OPTS):
    return tfm.decode_step(params, cache, cfg, tokens, opts)


def reset_cache_slots(cache, fresh, reset):
    """Per-slot cache reset for continuous-batching refill — see
    :func:`repro.models.transformer.reset_cache_slots`."""
    return tfm.reset_cache_slots(cache, fresh, reset)


# ---------------------------------------------------------------------------
# Input specs for the dry-run (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of a given shape.

    The modality frontends (whisper mel+conv, InternViT) are STUBS: their
    outputs (frame / patch embeddings) are inputs here, per the assignment.
    """
    f32 = jnp.float32
    i32 = jnp.int32
    act = dtype_of(cfg.dtype)
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct

    if cfg.arch_type == "unet":
        return {"images": sds((B, cfg.image_size, cfg.image_size,
                               cfg.in_channels), f32),
                "labels": sds((B,), i32)}

    if shape.mode == "decode":
        return {"tokens": sds((B, 1), i32)}

    specs: Dict[str, Any] = {}
    s_text = S
    if cfg.arch_type == "vlm":
        s_text = S - cfg.num_image_tokens
        specs["image_embeds"] = sds((B, cfg.num_image_tokens, cfg.d_model), act)
    if cfg.arch_type == "encdec":
        specs["frames"] = sds((B, cfg.encoder_seq_len, cfg.d_model), act)
    specs["tokens"] = sds((B, s_text), i32)
    if shape.mode == "train":
        specs["labels"] = sds((B, S), i32)  # VLM: image positions = -1 (masked)
    return specs


def make_inputs(rng, cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """Concrete random inputs matching ``input_specs`` (smoke tests)."""
    specs = input_specs(cfg, shape)
    out = {}
    for k, spec in specs.items():
        rng, sub = jax.random.split(rng)
        if spec.dtype == jnp.int32:
            hi = cfg.vocab_size if cfg.arch_type != "unet" else max(cfg.num_classes, 1)
            out[k] = jax.random.randint(sub, spec.shape, 0, max(hi, 2), jnp.int32)
        else:
            out[k] = jax.random.normal(sub, spec.shape, jnp.float32).astype(spec.dtype)
    return out
