"""Pluggable compute-backend dispatch for the FedPhD hot path.

Every tensor-core op the training path executes — matmul, conv (im2col
-> matmul), attention, the Eq. 17 group reductions, and the
sparse-phase masked matmul — routes through ONE of three backends:

  ``xla``     today's einsum/dot formulations (the numerical default —
              the exact expressions the round engine compiled before
              this layer existed);
  ``pallas``  the Pallas TPU kernels under :mod:`repro.kernels`
              (``interpret=True`` off-TPU, so CPU CI exercises the real
              BlockSpec tiling), with the pure-jnp oracle as fallback
              on non-tile-aligned shapes — the same contract the kernel
              ``ops.py`` wrappers already enforce;
  ``ref``     the kernels' pure-jnp oracles (``ref.py``) — the
              slow-but-obvious reference the other two are locked
              against (atol 1e-5, ``tests/test_ops_backends.py``).

Selection: an explicit ``backend=`` argument wins; ``""``/``None``
falls back to ``$FEDPHD_BACKEND`` (the CI matrix knob, mirroring
``$FEDPHD_ENGINE``) and finally ``"xla"``.  The per-run route is the
``backend`` field threaded ``ExperimentSpec -> ModelConfig -> make_
round_engine -> make_local_step``: trainers resolve it once at
construction (``FedPhD``/``FlatTrainer`` bake the resolved name into
``cfg.backend``), so engine memoization and checkpoint manifests pin a
concrete backend and a mid-process env change cannot alias a stale
compiled round program.

Autodiff: ``pallas_call`` has no transpose rule, so every pallas route
that sits on the loss path carries a ``custom_vjp`` whose backward
reuses the kernels where the sparsity survives transposition (the
masked matmul's dx is itself a block-masked matmul with the masks
swapped) and the reference math elsewhere — flash-attention backward
is the standard recompute-from-residuals formulation.

All three backends of one op agree to atol 1e-5 on fp32 — including
under ``vmap`` (the engine's client axis) and inside ``lax.scan`` (the
engine's step axis); pallas_call's batching rule turns the client axis
into an outer grid dimension, so the kernels stay on the hot path of
the vectorized round program.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

# the single $FEDPHD_* precedence code path; resolve_backend /
# resolve_precision below are its back-compat wrappers (safe at module
# scope: repro.experiment re-exports lazily, resolve.py is a leaf)
from repro.experiment.resolve import BACKENDS, PRECISIONS, resolve_backend, \
    resolve_precision
from repro.kernels.block_masked_matmul.ops import masked_matmul as _bmm_kernel
from repro.kernels.block_masked_matmul.ref import block_masked_matmul_ref
from repro.kernels.flash_attention.ops import flash_attention as _flash_kernel
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.group_l2_norms.ops import group_sq_norms_kernel
from repro.kernels.group_l2_norms.ref import group_l2_norms_ref

# compute-precision axis, resolved exactly like the backend: fp32 keeps
# today's numerics; bf16 runs the GEMMs/attention in bfloat16 while
# aggregation, Adam moments, and the master weights stay fp32 (the cast
# lives in make_train_one/make_local_step — see repro.fl.engine)
_COMPUTE_DTYPE = {"fp32": jnp.float32, "bf16": jnp.bfloat16}


def compute_dtype(precision: str):
    """The jnp dtype a resolved precision computes in."""
    return _COMPUTE_DTYPE[resolve_precision(precision)]


def cast_floats(tree, dtype):
    """Cast every floating leaf of a pytree to ``dtype`` (int/bool
    leaves — masks, step counters — pass through untouched).

    On the loss path this is the mixed-precision boundary: grads of the
    cast tree transpose back through ``astype`` to the original (fp32
    master) dtype, so Adam and aggregation never see low precision."""
    return jax.tree.map(
        lambda x: x.astype(dtype)
        if jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating) else x, tree)


def pallas_interpret() -> bool:
    """Kernels run interpreted everywhere but real TPU."""
    return jax.default_backend() != "tpu"


_LOW_PRECISION = (jnp.bfloat16, jnp.float16)


def _gemm_cast(x, w):
    """GEMM-boundary activation cast: when the weights run a reduced
    compute dtype (the loss path casts params, not inputs — images,
    x_t, timestep embeddings arrive fp32) the activations follow, so
    the GEMM inputs stay homogeneous and the reduced-precision compute
    actually sticks on every backend.  Grads transpose back through the
    ``astype``.  Full-precision weights leave activations alone."""
    if w.dtype in _LOW_PRECISION and x.dtype != w.dtype:
        return x.astype(w.dtype)
    return x


# ---------------------------------------------------------------------------
# masked matmul (and plain matmul as its all-ones special case)
# ---------------------------------------------------------------------------

def _masked_wm(w, col_mask, row_mask):
    wm = w
    if col_mask is not None:
        wm = wm * col_mask[None, :].astype(w.dtype)
    if row_mask is not None:
        wm = wm * row_mask[:, None].astype(w.dtype)
    return wm


@jax.custom_vjp
def _masked_matmul_pallas(x, w, col_mask, row_mask):
    # the kernel wrapper handles tile-alignment fallback to the oracle
    return _bmm_kernel(x, w, col_mask, row_mask,
                       interpret=pallas_interpret())


def _masked_matmul_pallas_fwd(x, w, col_mask, row_mask):
    return _masked_matmul_pallas(x, w, col_mask, row_mask), \
        (x, w, col_mask, row_mask)


def _masked_matmul_pallas_bwd(res, g):
    x, w, col_mask, row_mask = res
    # dx = g @ (w*cm*rm).T — itself a block-masked matmul with the
    # masks swapped, so pruned tiles are skipped in the backward too
    dx = _masked_matmul_pallas(g, w.T, row_mask, col_mask).astype(x.dtype)
    dw = (jnp.dot(x.T.astype(jnp.float32), g.astype(jnp.float32))
          * row_mask[:, None] * col_mask[None, :]).astype(w.dtype)
    return dx, dw, jnp.zeros_like(col_mask), jnp.zeros_like(row_mask)


_masked_matmul_pallas.defvjp(_masked_matmul_pallas_fwd,
                             _masked_matmul_pallas_bwd)


def is_static_mask(m) -> bool:
    """Host-constant (numpy) masks trigger trace-time sparsity
    specialization; device/traced masks keep the exact training path."""
    return isinstance(m, np.ndarray)


def _static_masks(col_mask, row_mask) -> bool:
    if col_mask is None and row_mask is None:
        return False
    return (col_mask is None or is_static_mask(col_mask)) and \
        (row_mask is None or is_static_mask(row_mask))


def _masked_matmul_static(x2, w, col_mask, row_mask, b: str):
    """Serve-time masked matmul with *host-constant* masks: the pruned
    channels are known at trace time, so instead of multiplying by zero
    we gather the kept rows/columns, run a smaller GEMM, and scatter
    back — the compiled program genuinely shrinks with sparsity.

    Gathers are element-granular (kept channels need not be contiguous
    — the U-Net's GroupNorm between conv1 and conv2 forbids the
    function-preserving repack that would make top-k masks contiguous),
    except on the pallas backend when element granularity would knock a
    tile-aligned GEMM off the kernel: there the gather falls back to
    128-block granularity, dropping only whole all-pruned MXU tiles and
    keeping partial blocks' element masks inside the kernel.  Zero kept
    rows or columns short-circuits to zeros.  Matches the dynamic-mask
    path to fp32 reduction-order tolerance (the dropped terms are exact
    zeros).
    """
    K, N = w.shape
    rm = np.ones((K,), np.float32) if row_mask is None \
        else np.asarray(row_mask, np.float32)
    cm = np.ones((N,), np.float32) if col_mask is None \
        else np.asarray(col_mask, np.float32)
    out_dtype = jnp.promote_types(x2.dtype, w.dtype)
    bs = 128
    ridx = np.nonzero(rm)[0]
    cidx = np.nonzero(cm)[0]
    if b == "pallas":
        M = x2.shape[0]
        kernel_full = M % bs == 0 and K % bs == 0 and N % bs == 0
        kernel_elem = M % bs == 0 and ridx.size % bs == 0 \
            and cidx.size % bs == 0
        if kernel_full and not kernel_elem:
            # block-granular: keep any 128-block with a live unit
            rkeep = rm.reshape(-1, bs).max(axis=1) != 0
            ckeep = cm.reshape(-1, bs).max(axis=1) != 0
            ridx = np.nonzero(np.repeat(rkeep, bs))[0]
            cidx = np.nonzero(np.repeat(ckeep, bs))[0]
    if ridx.size == 0 or cidx.size == 0:
        return jnp.zeros((x2.shape[0], N), out_dtype)
    xr = x2 if ridx.size == K else x2[:, ridx]
    wr = w if ridx.size == K and cidx.size == N else w[np.ix_(ridx, cidx)]
    if b == "pallas":
        out_r = _masked_matmul_pallas(xr, wr, jnp.asarray(cm[cidx]),
                                      jnp.asarray(rm[ridx]))
    elif b == "ref":
        out_r = block_masked_matmul_ref(
            xr, wr, jnp.ones((cidx.size,), jnp.float32),
            jnp.ones((ridx.size,), jnp.float32))
    else:
        out_r = xr @ wr
    if cidx.size == N:
        return out_r
    return jnp.zeros((x2.shape[0], N), out_r.dtype).at[:, cidx].set(out_r)


def masked_matmul(x, w, col_mask=None, row_mask=None, *, backend: str = ""):
    """``x @ (w * col_mask[None] * row_mask[:, None])`` — the structured-
    pruning sparse-phase matmul.  x: (M, K) or (..., K); w: (K, N);
    masks are 0/1 fp32 vectors (``None`` = all ones).

    Mask *type* selects the strategy: device/traced masks run the exact
    training-time formulation (multiply by zero; pallas skips all-zero
    tiles via ``pl.when``), while host ``np.ndarray`` masks are serving
    constants and specialize the compiled program itself — see
    :func:`_masked_matmul_static`.
    """
    b = resolve_backend(backend)
    x = _gemm_cast(x, w)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if _static_masks(col_mask, row_mask):
        out = _masked_matmul_static(x2, w, col_mask, row_mask, b)
        return out.reshape(lead + (w.shape[1],))
    if b == "pallas":
        cm = jnp.ones((w.shape[1],), jnp.float32) if col_mask is None \
            else col_mask
        rm = jnp.ones((w.shape[0],), jnp.float32) if row_mask is None \
            else row_mask
        out = _masked_matmul_pallas(x2, w, cm, rm)
    elif b == "ref":
        cm = jnp.ones((w.shape[1],), jnp.float32) if col_mask is None \
            else col_mask
        rm = jnp.ones((w.shape[0],), jnp.float32) if row_mask is None \
            else row_mask
        out = block_masked_matmul_ref(x2, w, cm, rm)
    else:
        out = x2 @ _masked_wm(w, col_mask, row_mask)
    return out.reshape(lead + (w.shape[1],))


def matmul(x, w, *, backend: str = ""):
    """Plain dense matmul ``x @ w`` (masked_matmul's all-ones case)."""
    if resolve_backend(backend) == "xla":
        return x @ w            # today's path, verbatim
    return masked_matmul(x, w, backend=backend)


# ---------------------------------------------------------------------------
# dense / conv (im2col -> matmul)
# ---------------------------------------------------------------------------

def dense(p, x, *, backend: str = "", col_mask=None):
    """``x @ p["w"] + p["b"]``; ``col_mask`` prunes output features
    (weight columns AND bias — exactly ``apply_masks``' pre-zeroing)."""
    b = p["b"] if col_mask is None else p["b"] * jnp.asarray(col_mask)
    if resolve_backend(backend) == "xla" and not _static_masks(col_mask, None):
        w = p["w"] if col_mask is None else p["w"] * col_mask[None, :]
        return _gemm_cast(x, w) @ w + b
    return masked_matmul(x, p["w"], col_mask, None, backend=backend) + b


def _same_pads(size: int, k: int, stride: int):
    out = -(-size // stride)
    pad = max((out - 1) * stride + k - size, 0)
    return out, (pad // 2, pad - pad // 2)


def conv(p, x, *, stride: int = 1, padding: str = "SAME",
         backend: str = "", col_mask=None, row_mask=None):
    """SAME conv lowered as im2col + matmul (matches lax.conv numerics
    to fp32 tolerance).

    The matmul formulation matters twice over: under the round engine's
    vmap the conv WEIGHTS carry a client axis, which XLA:CPU executes
    as a pathologically slow batched-filter convolution (and conv
    thunks inside lax.scan additionally lose the runtime thread pool)
    — as a GEMM it batches cleanly; and a GEMM is exactly what the
    Pallas backends accept, so one lowering serves every backend.

    ``col_mask`` (cout,) prunes output channels — weight columns and
    bias; ``row_mask`` (cin,) prunes input channels (tiled across the
    kh*kw patch positions of the im2col K axis).  With masks this
    computes the ``apply_masks``-pre-zeroed forward exactly, but the
    pallas backend skips whole all-masked MXU tiles instead of
    multiplying by zero.
    """
    if padding != "SAME":
        raise ValueError(f"im2col conv supports SAME padding only, "
                         f"got {padding!r}")
    b = resolve_backend(backend)
    w = p["w"]
    kh, kw, cin, cout = w.shape
    bias = p["b"] if col_mask is None else p["b"] * jnp.asarray(col_mask)
    # host-constant serving masks take the GEMM route on every backend
    # so the static gather/scatter specialization can engage
    static = _static_masks(col_mask, row_mask)

    x = _gemm_cast(x, w)
    if kh == kw == 1 and stride == 1:
        w2 = w[0, 0]
        if b == "xla" and not static:
            w2 = _masked_wm(w2, col_mask, row_mask)
            return jnp.einsum("bhwc,cd->bhwd", x, w2) + bias
        out = masked_matmul(x.reshape(-1, cin), w2, col_mask, row_mask,
                            backend=b)
        return out.reshape(x.shape[:-1] + (cout,)) + bias

    H, W = x.shape[1], x.shape[2]
    oh, (ph0, ph1) = _same_pads(H, kh, stride)
    ow, (pw0, pw1) = _same_pads(W, kw, stride)
    xp = jnp.pad(x, ((0, 0), (ph0, ph1), (pw0, pw1), (0, 0)))
    cols = [xp[:, di:di + stride * (oh - 1) + 1:stride,
               dj:dj + stride * (ow - 1) + 1:stride, :]
            for di in range(kh) for dj in range(kw)]
    patches = jnp.stack(cols, axis=3)            # (B, oh, ow, kh*kw, cin)
    wk = w.reshape(kh * kw, cin, cout)
    if b == "xla" and not static:
        if col_mask is not None:
            wk = wk * col_mask[None, None, :]
        if row_mask is not None:
            wk = wk * row_mask[None, :, None]
        y = jnp.einsum("bhwkc,kcd->bhwd", patches, wk)
        return y + bias
    # flatten the patch axis into K; the cin row mask tiles across the
    # kh*kw patch positions (im2col K index = patch * cin + c).  np.tile
    # for host masks — jnp.tile would device-commit them and silently
    # drop the static specialization.
    rm = None if row_mask is None else \
        (np.tile(row_mask, kh * kw) if is_static_mask(row_mask)
         else jnp.tile(row_mask, kh * kw))
    flat = patches.reshape(-1, kh * kw * cin)
    y = masked_matmul(flat, wk.reshape(kh * kw * cin, cout), col_mask, rm,
                      backend=b)
    return y.reshape(x.shape[0], oh, ow, cout) + bias


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _attention_dense(qf, kf, vf, causal: bool, window: int):
    """Dense-softmax attention on flattened (B*H, S, hd) — the pre-ops
    U-Net formulation, generalized with the flash kernel's masking."""
    hd = qf.shape[-1]
    s = jnp.einsum("bqc,bkc->bqk", qf, kf) * (hd ** -0.5)
    if causal or window > 0:
        qpos = jnp.arange(qf.shape[1])[:, None]
        kpos = jnp.arange(kf.shape[1])[None, :]
        ok = jnp.ones(s.shape[1:], bool)
        if causal:
            ok &= kpos <= qpos
        if window > 0:
            ok &= (qpos - kpos) < window
        s = jnp.where(ok[None], s, -1e30)
    probs = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkc->bqc", probs, vf)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _attention_pallas(q, k, v, causal, window):
    return _flash_kernel(q, k, v, causal=causal, window=window,
                         interpret=pallas_interpret())


def _attention_pallas_fwd(q, k, v, causal, window):
    return _attention_pallas(q, k, v, causal, window), (q, k, v)


def _attention_pallas_bwd(causal, window, res, g):
    q, k, v = res                 # flash-style recompute from residuals
    _, vjp = jax.vjp(
        lambda q_, k_, v_: attention(q_, k_, v_, causal=causal,
                                     window=window, backend="xla"), q, k, v)
    return vjp(g)


_attention_pallas.defvjp(_attention_pallas_fwd, _attention_pallas_bwd)


def attention(q, k, v, *, causal: bool = False, window: int = 0,
              backend: str = ""):
    """q: (B, Sq, H, hd); k, v: (B, Skv, Hkv, hd) -> (B, Sq, H, hd).

    The U-Net attention blocks call this with H = 1, hd = channels;
    the transformer stack with its model head layout (GQA expanded by
    the pallas wrapper).
    """
    b = resolve_backend(backend)
    if b == "pallas":
        return _attention_pallas(q, k, v, causal, window)
    B, Sq, H, hd = q.shape
    if k.shape[2] != H:                    # expand GQA groups, as the
        rep = H // k.shape[2]              # pallas wrapper does
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, -1, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, -1, hd)
    if b == "ref":
        out = flash_attention_ref(qf, kf, vf, causal=causal, window=window)
    else:
        out = _attention_dense(qf, kf, vf, causal, window)
    return out.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
# group sum-of-squares reductions (Eq. 17)
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(1,))
def _group_sq_pallas(w2d, num_groups):
    return group_sq_norms_kernel(w2d, num_groups,
                                 interpret=pallas_interpret())


def _group_sq_pallas_fwd(w2d, num_groups):
    return _group_sq_pallas(w2d, num_groups), w2d


def _group_sq_pallas_bwd(num_groups, w2d, g):
    chunk = w2d.shape[1] // num_groups
    return (2.0 * w2d * jnp.repeat(g, chunk)[None, :],)


_group_sq_pallas.defvjp(_group_sq_pallas_fwd, _group_sq_pallas_bwd)


def group_sq_norms_2d(w2d, num_groups: int, *, backend: str = ""):
    """(K, G*C) -> (G,) per-group sum of squares over contiguous column
    chunks — the layout :func:`repro.core.pruning.criteria.member_unit_sq`
    produces for any non-scan-stacked group member."""
    b = resolve_backend(backend)
    if b == "pallas":
        return _group_sq_pallas(w2d, num_groups)
    if b == "ref":
        return group_l2_norms_ref(w2d, num_groups)
    K = w2d.shape[0]
    # fp32 accumulation regardless of compute dtype — the kernel and
    # ref oracle already upcast internally; the xla path must match
    w3 = w2d.astype(jnp.float32).reshape(K, num_groups, -1)
    return jnp.sum(w3 * w3, axis=(0, 2))
