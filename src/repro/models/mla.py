"""DeepSeek-V3 Multi-head Latent Attention (MLA).

Train/prefill reconstructs per-head K/V from the shared KV latent
(naive form); decode uses the matrix-absorbed form so the KV cache is
only the latent c_kv (kv_lora_rank) + the shared RoPE key — the whole
point of MLA (cache bytes independent of num_heads).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig
from repro.models.common import apply_rope, dense_init, rms_norm
from repro.models.attention import attend


def init_mla(key, d_model: int, num_heads: int, mla: MLAConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 8)
    H = num_heads
    qk = mla.qk_nope_head_dim + mla.qk_rope_head_dim
    p = {
        "wq_a": dense_init(ks[0], d_model, mla.q_lora_rank, dtype),
        "q_norm": jnp.zeros((mla.q_lora_rank,), dtype),
        "wq_b": dense_init(ks[1], mla.q_lora_rank, H * qk, dtype),
        "wkv_a": dense_init(ks[2], d_model, mla.kv_lora_rank + mla.qk_rope_head_dim, dtype),
        "kv_norm": jnp.zeros((mla.kv_lora_rank,), dtype),
        "wkv_b": dense_init(ks[3], mla.kv_lora_rank,
                            H * (mla.qk_nope_head_dim + mla.v_head_dim), dtype),
        "wo": dense_init(ks[4], H * mla.v_head_dim, d_model, dtype),
    }
    return p


def _project_q(p, x, mla: MLAConfig, num_heads: int, positions, rope_theta):
    B, S, _ = x.shape
    H = num_heads
    qk = mla.qk_nope_head_dim + mla.qk_rope_head_dim
    q = rms_norm(x @ p["wq_a"], p["q_norm"]) @ p["wq_b"]
    q = q.reshape(B, S, H, qk)
    q_nope = q[..., : mla.qk_nope_head_dim]
    q_rope = apply_rope(q[..., mla.qk_nope_head_dim:], positions, rope_theta)
    return q_nope, q_rope


def _latent_kv(p, x, mla: MLAConfig, positions, rope_theta):
    kv_a = x @ p["wkv_a"]
    c_kv = rms_norm(kv_a[..., : mla.kv_lora_rank], p["kv_norm"])
    k_rope = kv_a[..., mla.kv_lora_rank:][:, :, None, :]      # (B,S,1,rope)
    k_rope = apply_rope(k_rope, positions, rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def apply_mla(p, x, mla: MLAConfig, num_heads: int, positions, *,
              rope_theta: float, chunk: int = 0, window: int = 0):
    """Full-sequence MLA (train / prefill).  x: (B, S, d)."""
    B, S, _ = x.shape
    H = num_heads
    q_nope, q_rope = _project_q(p, x, mla, H, positions, rope_theta)
    c_kv, k_rope = _latent_kv(p, x, mla, positions, rope_theta)

    kv = (c_kv @ p["wkv_b"]).reshape(B, S, H, mla.qk_nope_head_dim + mla.v_head_dim)
    k_nope = kv[..., : mla.qk_nope_head_dim]
    v = kv[..., mla.qk_nope_head_dim:]

    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (B, S, H, mla.qk_rope_head_dim))], axis=-1)
    out = attend(q, k, v, q_positions=positions, kv_positions=positions,
                 causal=True, window=window, chunk=chunk)
    return out.reshape(B, S, H * mla.v_head_dim) @ p["wo"]


def mla_decode(p, x, cache_c, cache_kr, pos, mla: MLAConfig, num_heads: int, *,
               rope_theta: float, window: int = 0):
    """Matrix-absorbed single-token decode.

    x: (B, 1, d); cache_c: (B, S, L); cache_kr: (B, S, rope); pos: (B,).
    Returns (out (B,1,d), new_cache_c, new_cache_kr).
    """
    B, _, d = x.shape
    H, L = num_heads, mla.kv_lora_rank
    positions = pos[:, None]
    q_nope, q_rope = _project_q(p, x, mla, H, positions, rope_theta)

    c_new, kr_new = _latent_kv(p, x, mla, positions, rope_theta)
    bidx = jnp.arange(B)
    cache_c = cache_c.at[bidx, pos].set(c_new[:, 0].astype(cache_c.dtype))
    cache_kr = cache_kr.at[bidx, pos].set(kr_new[:, 0].astype(cache_kr.dtype))

    wkv_b = p["wkv_b"].reshape(L, H, mla.qk_nope_head_dim + mla.v_head_dim)
    w_uk = wkv_b[..., : mla.qk_nope_head_dim]                 # (L, H, nope)
    w_uv = wkv_b[..., mla.qk_nope_head_dim:]                  # (L, H, v)

    # absorb W_uk into the query: q_abs (B,1,H,L)
    q_abs = jnp.einsum("bqhn,lhn->bqhl", q_nope, w_uk)
    scale = (mla.qk_nope_head_dim + mla.qk_rope_head_dim) ** -0.5
    logits = (jnp.einsum("bqhl,bsl->bhqs", q_abs, cache_c.astype(q_abs.dtype))
              + jnp.einsum("bqhr,bsr->bhqs", q_rope, cache_kr.astype(q_rope.dtype)))
    logits = logits.astype(jnp.float32) * scale

    S = cache_c.shape[1]
    kv_pos = jnp.arange(S, dtype=jnp.int32)[None, :]
    ok = kv_pos <= pos[:, None]
    if window > 0:
        ok &= (pos[:, None] - kv_pos) < window
    logits = jnp.where(ok[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)

    lat = jnp.einsum("bhqs,bsl->bqhl", probs, cache_c.astype(probs.dtype))
    out = jnp.einsum("bqhl,lhv->bqhv", lat, w_uv)
    out = out.reshape(B, 1, H * mla.v_head_dim) @ p["wo"]
    return out, cache_c, cache_kr
