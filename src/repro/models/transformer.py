"""Unified decoder stack for all assigned architectures.

Layer layout = ``head`` (unstacked, e.g. deepseek's leading dense-FFN
layers) + ``cycles`` (the layer pattern, param-stacked over repetitions and
driven by ``lax.scan`` so the HLO stays compact for 512-way SPMD compiles)
+ ``tail`` (pattern remainder, unstacked).  Mixed layer kinds (gemma2
local/global, recurrentgemma rec/rec/attn) are positions *within* the
pattern — no ``lax.switch`` needed and no wasted parameters.

Supports: dense GQA / MQA, sliding windows, gemma2 softcaps, command-r
parallel blocks, MoE (+shared experts, leading dense layers), DeepSeek MLA,
RG-LRU recurrent layers, RWKV6 layers, whisper-style encoder-decoder with
cross-attention, and VLM patch-embedding prefix.  Single-token decode with
per-kind caches: full KV for global attention, ring-buffer KV for windowed
attention, latent cache for MLA, O(1) states for RG-LRU / RWKV.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (ModelConfig, ATTN_GLOBAL, ATTN_LOCAL,
                                RECURRENT, RWKV)
from repro.models import attention as attn_lib
from repro.models import mla as mla_lib
from repro.models import ops
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import rwkv6 as rwkv_lib
from repro.models.common import (ApplyOptions, DEFAULT_OPTS, apply_rope,
                                 constrain_activation, constrain_heads,
                                 dense_init, dtype_of, embed_init, rms_norm,
                                 softcap)
from repro.models.ffn import apply_ffn, init_ffn

Params = Dict[str, Any]


# ===========================================================================
# Stack plan
# ===========================================================================
@dataclasses.dataclass(frozen=True)
class StackPlan:
    n_head: int                 # leading unstacked layers (dense-FFN for MoE)
    n_cycles: int               # scanned repetitions of the pattern
    pattern: Tuple[int, ...]
    tail_kinds: Tuple[int, ...]


def stack_plan(cfg: ModelConfig) -> StackPlan:
    n_head = cfg.moe.first_dense_layers if cfg.moe else 0
    if n_head:
        assert len(cfg.layer_pattern) == 1, "head layers need uniform pattern"
    rem = cfg.num_layers - n_head
    plen = len(cfg.layer_pattern)
    return StackPlan(
        n_head=n_head,
        n_cycles=rem // plen,
        pattern=cfg.layer_pattern,
        tail_kinds=cfg.layer_pattern[: rem % plen],
    )


# ===========================================================================
# Per-layer init
# ===========================================================================
def init_attn_params(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 4)
    d, hd = cfg.d_model, cfg.head_dim
    p = {
        "wq": dense_init(ks[0], d, cfg.num_heads * hd, dtype),
        "wk": dense_init(ks[1], d, cfg.num_kv_heads * hd, dtype),
        "wv": dense_init(ks[2], d, cfg.num_kv_heads * hd, dtype),
        "wo": dense_init(ks[3], cfg.num_heads * hd, d, dtype),
    }
    if cfg.use_qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
    if cfg.use_attn_out_bias:
        p["bo"] = jnp.zeros((d,), dtype)
    return p


def init_layer(key, cfg: ModelConfig, kind: int, *, is_moe: bool,
               cross_attn: bool = False, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    p: Params = {"ln1": jnp.zeros((d,), dtype)}
    if kind in (ATTN_GLOBAL, ATTN_LOCAL):
        if cfg.mla is not None:
            p["mla"] = mla_lib.init_mla(ks[0], d, cfg.num_heads, cfg.mla, dtype)
        else:
            p["attn"] = init_attn_params(ks[0], cfg, dtype)
        if cross_attn:
            p["ln_cross"] = jnp.zeros((d,), dtype)
            p["cross"] = init_attn_params(ks[1], cfg, dtype)
        p["ln2"] = jnp.zeros((d,), dtype)
        if is_moe:
            p["moe"] = moe_lib.init_moe(ks[2], d, cfg.moe,
                                        activation=cfg.activation, dtype=dtype)
        else:
            p["ffn"] = init_ffn(ks[2], d, cfg.d_ff, glu=cfg.glu,
                                bias=cfg.use_ffn_bias, dtype=dtype)
    elif kind == RECURRENT:
        p["rec"] = rglru_lib.init_rglru(ks[0], d, cfg.lru_width,
                                        cfg.conv1d_width, dtype)
        p["ln2"] = jnp.zeros((d,), dtype)
        p["ffn"] = init_ffn(ks[2], d, cfg.d_ff, glu=cfg.glu,
                            bias=cfg.use_ffn_bias, dtype=dtype)
    elif kind == RWKV:
        p["tmix"] = rwkv_lib.init_rwkv_tmix(ks[0], d, cfg.num_heads,
                                            cfg.head_dim, dtype)
        p["ln2"] = jnp.zeros((d,), dtype)
        p["cmix"] = rwkv_lib.init_rwkv_cmix(ks[2], d, cfg.d_ff, dtype)
    else:
        raise ValueError(f"unknown layer kind {kind}")
    return p


def _layer_is_moe(cfg: ModelConfig, kind: int) -> bool:
    return cfg.moe is not None and kind in (ATTN_GLOBAL, ATTN_LOCAL)


def init_params(key, cfg: ModelConfig) -> Params:
    """Initialize the full model parameter pytree."""
    dtype = dtype_of(cfg.param_dtype)
    plan = stack_plan(cfg)
    keys = jax.random.split(key, 8)
    params: Params = {
        "embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], cfg.d_model, cfg.vocab_size, dtype)

    cross = cfg.arch_type == "encdec"
    # head layers (always dense-FFN)
    params["head"] = [
        init_layer(jax.random.fold_in(keys[2], i), cfg, cfg.layer_pattern[0],
                   is_moe=False, cross_attn=cross, dtype=dtype)
        for i in range(plan.n_head)
    ]
    # scanned cycles: one stacked param tree per pattern position
    cyc = []
    for pos, kind in enumerate(plan.pattern):
        if plan.n_cycles == 0:
            cyc.append(None)
            continue
        pos_keys = jax.random.split(jax.random.fold_in(keys[3], pos), plan.n_cycles)
        stacked = jax.vmap(
            lambda k: init_layer(k, cfg, kind, is_moe=_layer_is_moe(cfg, kind),
                                 cross_attn=cross, dtype=dtype))(pos_keys)
        cyc.append(stacked)
    params["cycles"] = cyc
    params["tail"] = [
        init_layer(jax.random.fold_in(keys[4], 1000 + i), cfg, kind,
                   is_moe=_layer_is_moe(cfg, kind), cross_attn=cross, dtype=dtype)
        for i, kind in enumerate(plan.tail_kinds)
    ]

    if cfg.arch_type == "encdec":
        enc_keys = jax.random.split(keys[5], max(cfg.num_encoder_layers, 1))
        params["encoder"] = {
            "blocks": jax.vmap(
                lambda k: init_layer(k, cfg, ATTN_GLOBAL, is_moe=False,
                                     dtype=dtype))(enc_keys),
            "final_norm": jnp.zeros((cfg.d_model,), dtype),
        }
    return params


# ===========================================================================
# Full-sequence layer application (train / prefill)
# ===========================================================================
def _self_attention(ap, h, positions, cfg: ModelConfig, *, window: int,
                    opts: ApplyOptions, causal: bool = True):
    B, S, d = h.shape
    Hq, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = h @ ap["wq"]
    k = h @ ap["wk"]
    v = h @ ap["wv"]
    if "bq" in ap:
        q, k, v = q + ap["bq"], k + ap["bk"], v + ap["bv"]
    q = q.reshape(B, S, Hq, hd)
    k = k.reshape(B, S, Hkv, hd)
    v = v.reshape(B, S, Hkv, hd)
    # GQA + TP: when the model axes divide Hq but not Hkv, expand KV to
    # per-q-head layout so attention shards cleanly by q-head (MaxText's
    # "kv head replication"); never shard across head_dim.
    sizes = dict(opts.mesh_axis_sizes)
    mprod = 1
    for a in opts.act_model_axes:
        mprod *= sizes.get(a, 1)
    if mprod > 1 and Hkv % mprod != 0 and Hq % mprod == 0:
        rep = Hq // Hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    q = constrain_heads(q, opts, seq_fallback=True)
    k = constrain_heads(k, opts)
    v = constrain_heads(v, opts)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    backend = ops.resolve_backend(opts.backend or cfg.backend)
    if ((opts.use_flash or backend == "pallas") and causal
            and cfg.attn_softcap == 0.0):
        # the one-off flash import now rides the shared dispatch layer:
        # use_flash is a legacy alias for backend="pallas" on attention
        out = ops.attention(q, k, v, causal=True, window=window,
                            backend="pallas")
    else:
        out = attn_lib.attend(q, k, v, q_positions=positions,
                              kv_positions=positions, causal=causal,
                              window=window, attn_softcap=cfg.attn_softcap,
                              chunk=opts.attn_chunk)
    out = out.reshape(B, S, Hq * hd) @ ap["wo"]
    if "bo" in ap:
        out = out + ap["bo"]
    return out


def _cross_attention(ap, h, enc_out, cfg: ModelConfig, opts: ApplyOptions):
    """Cross attention: queries from decoder h, keys/values from enc_out."""
    B, S, d = h.shape
    Se = enc_out.shape[1]
    Hq, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (h @ ap["wq"]).reshape(B, S, Hq, hd)
    k = (enc_out @ ap["wk"]).reshape(B, Se, Hkv, hd)
    v = (enc_out @ ap["wv"]).reshape(B, Se, Hkv, hd)
    if "bq" in ap:
        q = q + ap["bq"].reshape(Hq, hd)
        k = k + ap["bk"].reshape(Hkv, hd)
        v = v + ap["bv"].reshape(Hkv, hd)
    qp = jnp.arange(S, dtype=jnp.int32)
    kp = jnp.arange(Se, dtype=jnp.int32)
    out = attn_lib.attend(q, k, v, q_positions=qp, kv_positions=kp,
                          causal=False, window=0, chunk=opts.attn_chunk)
    out = out.reshape(B, S, Hq * hd) @ ap["wo"]
    if "bo" in ap:
        out = out + ap["bo"]
    return out


def _cast_layer(lp, dtype):
    """Cast a layer's floating-point params to the activation dtype
    (MaxText-style cast-at-use; master copies stay fp32 for Adam)."""
    def cast(a):
        if jnp.issubdtype(a.dtype, jnp.floating) and a.dtype != dtype:
            return a.astype(dtype)
        return a
    return jax.tree.map(cast, lp)


def apply_layer_full(lp: Params, x, kind: int, cfg: ModelConfig,
                     positions, opts: ApplyOptions, *,
                     enc_out=None, causal: bool = True):
    """One layer over a full sequence.  Returns (x, aux_loss)."""
    lp = _cast_layer(lp, x.dtype)
    aux = jnp.zeros((), jnp.float32)
    window = cfg.sliding_window if kind == ATTN_LOCAL else 0
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)

    if kind in (ATTN_GLOBAL, ATTN_LOCAL):
        if "mla" in lp:
            attn_out = mla_lib.apply_mla(lp["mla"], h, cfg.mla, cfg.num_heads,
                                         positions, rope_theta=cfg.rope_theta,
                                         chunk=opts.attn_chunk, window=window)
        else:
            attn_out = _self_attention(lp["attn"], h, positions, cfg,
                                       window=window, opts=opts, causal=causal)
        if cfg.parallel_block:
            ffn_out = apply_ffn(lp["ffn"], h, activation=cfg.activation,
                                glu=cfg.glu)
            return x + attn_out + ffn_out, aux
        x = x + attn_out
        if "cross" in lp:
            hc = rms_norm(x, lp["ln_cross"], cfg.norm_eps)
            x = x + _cross_attention(lp["cross"], hc, enc_out, cfg, opts)
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        if "moe" in lp:
            if opts.moe_ep and opts.ep_mesh is not None:
                from repro.launch.expert_parallel import apply_moe_ep
                moe_out, aux = apply_moe_ep(
                    lp["moe"], h2, cfg.moe, mesh=opts.ep_mesh,
                    ep_axes=opts.ep_axes, token_axes=opts.ep_token_axes,
                    activation=cfg.activation)
            else:
                moe_out, aux = moe_lib.apply_moe(lp["moe"], h2, cfg.moe,
                                                 activation=cfg.activation)
            x = x + moe_out
        else:
            x = x + apply_ffn(lp["ffn"], h2, activation=cfg.activation,
                              glu=cfg.glu)
        return x, aux

    if kind == RECURRENT:
        x = x + rglru_lib.apply_rglru(lp["rec"], h, conv_width=cfg.conv1d_width)
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + apply_ffn(lp["ffn"], h2, activation=cfg.activation, glu=cfg.glu)
        return x, aux

    if kind == RWKV:
        tm_out, _ = rwkv_lib.apply_tmix(lp["tmix"], h, cfg.num_heads,
                                        cfg.head_dim,
                                        wkv_chunk=opts.wkv_chunk)
        x = x + tm_out
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        cm_out, _ = rwkv_lib.apply_cmix(lp["cmix"], h2)
        return x + cm_out, aux

    raise ValueError(f"unknown kind {kind}")


# ===========================================================================
# Encoder (whisper) — bidirectional stacked blocks over frame embeddings
# ===========================================================================
def apply_encoder(params: Params, frames, cfg: ModelConfig, opts: ApplyOptions):
    enc = params["encoder"]
    B, Se, d = frames.shape
    positions = jnp.arange(Se, dtype=jnp.int32)   # shared across batch
    x = constrain_activation(frames, opts)

    def body(carry, lp):
        x = carry
        x, _ = apply_layer_full(lp, x, ATTN_GLOBAL, cfg, positions, opts,
                                causal=False)
        return x, None

    body_fn = jax.checkpoint(body) if opts.remat else body
    x, _ = jax.lax.scan(body_fn, x, enc["blocks"])
    return rms_norm(x, enc["final_norm"], cfg.norm_eps)


# ===========================================================================
# Forward (train / prefill)
# ===========================================================================
def embed_tokens(params, cfg: ModelConfig, tokens):
    x = params["embed"][tokens].astype(dtype_of(cfg.dtype))
    if cfg.tie_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def forward(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            opts: ApplyOptions = DEFAULT_OPTS):
    """Full-sequence forward.  Returns (hidden (B,S,d), aux_loss).

    batch keys: "tokens" (B, S_text); VLM adds "image_embeds"
    (B, Nimg, d); encdec adds "frames" (B, Se, d).
    """
    plan = stack_plan(cfg)
    tokens = batch["tokens"]
    x = embed_tokens(params, cfg, tokens)
    enc_out = None
    if cfg.arch_type == "vlm":
        img = batch["image_embeds"].astype(x.dtype)
        x = jnp.concatenate([img, x], axis=1)
    elif cfg.arch_type == "encdec":
        enc_out = apply_encoder(params, batch["frames"].astype(x.dtype), cfg, opts)

    x = constrain_activation(x, opts)
    B, S, _ = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)    # shared across batch
    aux = jnp.zeros((), jnp.float32)

    for lp, kind in zip(params["head"], cfg.layer_kinds()[: plan.n_head]):
        x, a = apply_layer_full(lp, x, cfg.layer_pattern[0], cfg, positions,
                                opts, enc_out=enc_out)
        aux = aux + a

    if plan.n_cycles > 0:
        def cycle_body(carry, cyc_params):
            x, aux = carry
            x = constrain_activation(x, opts)
            for pos, kind in enumerate(plan.pattern):
                x, a = apply_layer_full(cyc_params[pos], x, kind, cfg,
                                        positions, opts, enc_out=enc_out)
                x = constrain_activation(x, opts)
                aux = aux + a
            return (x, aux), None

        body_fn = jax.checkpoint(cycle_body) if opts.remat else cycle_body
        (x, aux), _ = jax.lax.scan(body_fn, (x, aux), tuple(params["cycles"]))

    for lp, kind in zip(params["tail"], plan.tail_kinds):
        x, a = apply_layer_full(lp, x, kind, cfg, positions, opts,
                                enc_out=enc_out)
        aux = aux + a

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux


def lm_head_weight(params, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


def logits_from_hidden(params, cfg: ModelConfig, hidden):
    logits = hidden @ lm_head_weight(params, cfg).astype(hidden.dtype)
    return softcap(logits, cfg.logit_softcap)


def chunked_xent(params, cfg: ModelConfig, hidden, labels, *,
                 chunk: int = 512, opts: ApplyOptions = DEFAULT_OPTS):
    """Cross-entropy without materializing (B, S, V) logits.

    hidden: (B, S, d); labels: (B, S) int32, -1 = ignore.
    Returns mean loss over non-ignored positions.
    """
    B, S, d = hidden.shape
    w = lm_head_weight(params, cfg)
    chunk = min(chunk, S)
    while S % chunk:
        chunk -= 1
    n = S // chunk
    hb = hidden.reshape(B, n, chunk, d).transpose(1, 0, 2, 3)
    lb = labels.reshape(B, n, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        h, y = xs
        # logits stay in the activation dtype (bf16) so the cotangent
        # into the backbone stays bf16; only the reductions are fp32.
        logits = softcap(h @ w.astype(h.dtype), cfg.logit_softcap)
        lmax = jax.lax.stop_gradient(
            jnp.max(logits.astype(jnp.float32), axis=-1, keepdims=True))
        shifted = logits - lmax.astype(logits.dtype)
        lse = jnp.log(jnp.sum(jnp.exp(shifted).astype(jnp.float32),
                              axis=-1)) + lmax[..., 0]
        yc = jnp.clip(y, 0, cfg.vocab_size - 1)
        correct = jnp.take_along_axis(
            logits, yc[..., None], axis=-1)[..., 0].astype(jnp.float32)
        mask = (y >= 0).astype(jnp.float32)
        loss_sum, count = carry
        return (loss_sum + jnp.sum((lse - correct) * mask),
                count + jnp.sum(mask)), None

    body_fn = jax.checkpoint(body) if opts.remat else body
    (loss_sum, count), _ = jax.lax.scan(
        body_fn, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hb, lb))
    return loss_sum / jnp.maximum(count, 1.0)


# ===========================================================================
# Decode: caches + single-token step
# ===========================================================================
def _attn_cache(cfg: ModelConfig, kind: int, batch: int, seq_len: int, dtype):
    if cfg.mla is not None:
        return {
            "c": jnp.zeros((batch, seq_len, cfg.mla.kv_lora_rank), dtype),
            "kr": jnp.zeros((batch, seq_len, cfg.mla.qk_rope_head_dim), dtype),
        }
    size = seq_len if kind == ATTN_GLOBAL else min(cfg.sliding_window, seq_len)
    return {
        "k": jnp.zeros((batch, size, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, size, cfg.num_kv_heads, cfg.head_dim), dtype),
        "kv_pos": jnp.full((batch, size), -1, jnp.int32),
    }


def _layer_state(cfg: ModelConfig, kind: int, batch: int, seq_len: int, dtype):
    if kind in (ATTN_GLOBAL, ATTN_LOCAL):
        return _attn_cache(cfg, kind, batch, seq_len, dtype)
    if kind == RECURRENT:
        return rglru_lib.init_rglru_state(batch, cfg.lru_width,
                                          cfg.conv1d_width, dtype)
    if kind == RWKV:
        return rwkv_lib.init_rwkv_state(batch, cfg.d_model, cfg.num_heads,
                                        cfg.head_dim, dtype)
    raise ValueError(kind)


def init_cache(params: Params, cfg: ModelConfig, batch: int, seq_len: int,
               *, enc_out=None, opts: ApplyOptions = DEFAULT_OPTS) -> Params:
    """Decode cache pytree matching the stack plan."""
    plan = stack_plan(cfg)
    dtype = dtype_of(cfg.dtype)
    cache: Params = {"pos": jnp.zeros((batch,), jnp.int32)}
    cache["head"] = [
        _layer_state(cfg, cfg.layer_pattern[0], batch, seq_len, dtype)
        for _ in range(plan.n_head)
    ]
    cyc = []
    for pos, kind in enumerate(plan.pattern):
        if plan.n_cycles == 0:
            cyc.append(None)
            continue
        one = _layer_state(cfg, kind, batch, seq_len, dtype)
        cyc.append(jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (plan.n_cycles,) + a.shape), one))
    cache["cycles"] = cyc
    cache["tail"] = [
        _layer_state(cfg, kind, batch, seq_len, dtype)
        for kind in plan.tail_kinds
    ]
    if cfg.arch_type == "encdec":
        if enc_out is None:
            raise ValueError("encdec decode cache needs enc_out")
        cache["enc_out"] = enc_out
    return cache


def reset_cache_slots(cache: Params, fresh: Params, reset) -> Params:
    """Blend freshly-initialized state into the cache rows of reset slots.

    ``fresh`` is an :func:`init_cache` output of the same shape (NOT
    necessarily all-zeros: ring caches start ``kv_pos = -1``); ``reset``
    is a ``(B,)`` bool vector.  Slot state is data, so a continuous-
    batching server calls this under jit on every refill without
    recompiling — and without this, a refilled slot decodes against the
    *previous* request's KV rows.
    """
    def blend(axis):
        def f(a, b):
            shape = [1] * a.ndim
            shape[axis] = -1
            return jnp.where(reset.reshape(shape), b, a)
        return f

    out: Params = {"pos": jnp.where(reset, fresh["pos"], cache["pos"])}
    out["head"] = jax.tree.map(blend(0), cache["head"], fresh["head"])
    out["tail"] = jax.tree.map(blend(0), cache["tail"], fresh["tail"])
    # cycle-stacked layer states carry (n_cycles, B, ...) leaves
    out["cycles"] = jax.tree.map(blend(1), cache["cycles"], fresh["cycles"])
    if "enc_out" in cache:
        out["enc_out"] = blend(0)(cache["enc_out"], fresh["enc_out"])
    return out


def _decode_self_attention(ap, cache, h, pos, cfg: ModelConfig, kind: int):
    """h: (B,1,d). Updates ring/full KV cache, returns (out, new_cache)."""
    B = h.shape[0]
    Hq, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = h @ ap["wq"]
    k = h @ ap["wk"]
    v = h @ ap["wv"]
    if "bq" in ap:
        q, k, v = q + ap["bq"], k + ap["bk"], v + ap["bv"]
    q = q.reshape(B, 1, Hq, hd)
    k = k.reshape(B, 1, Hkv, hd)
    v = v.reshape(B, 1, Hkv, hd)
    positions = pos[:, None]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    size = cache["k"].shape[1]
    slot = pos % size                                  # ring for windowed
    bidx = jnp.arange(B)
    k_cache = cache["k"].at[bidx, slot].set(k[:, 0].astype(cache["k"].dtype))
    v_cache = cache["v"].at[bidx, slot].set(v[:, 0].astype(cache["v"].dtype))
    kv_pos = cache["kv_pos"].at[bidx, slot].set(pos)

    window = cfg.sliding_window if kind == ATTN_LOCAL else 0
    # valid entries have kv_pos >= 0; attend() masks via positions
    big = jnp.where(kv_pos >= 0, kv_pos, jnp.iinfo(jnp.int32).max)
    out = attn_lib.attend(q, k_cache, v_cache, q_positions=positions,
                          kv_positions=big, causal=True, window=window,
                          attn_softcap=cfg.attn_softcap, chunk=0)
    out = out.reshape(B, 1, Hq * hd) @ ap["wo"]
    if "bo" in ap:
        out = out + ap["bo"]
    return out, {"k": k_cache, "v": v_cache, "kv_pos": kv_pos}


def _decode_cross_attention(ap, h, enc_out, cfg: ModelConfig):
    B = h.shape[0]
    Se = enc_out.shape[1]
    Hq, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (h @ ap["wq"]).reshape(B, 1, Hq, hd)
    k = (enc_out @ ap["wk"]).reshape(B, Se, Hkv, hd)
    v = (enc_out @ ap["wv"]).reshape(B, Se, Hkv, hd)
    qp = jnp.zeros((1,), jnp.int32)
    kp = jnp.arange(Se, dtype=jnp.int32)
    out = attn_lib.attend(q, k, v, q_positions=qp, kv_positions=kp,
                          causal=False, window=0, chunk=0)
    out = out.reshape(B, 1, Hq * hd) @ ap["wo"]
    if "bo" in ap:
        out = out + ap["bo"]
    return out


def apply_layer_decode(lp: Params, state: Params, x, kind: int,
                       cfg: ModelConfig, pos, *, enc_out=None):
    """One layer, one token.  x: (B,1,d).  Returns (x, new_state)."""
    lp = _cast_layer(lp, x.dtype)
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    window = cfg.sliding_window if kind == ATTN_LOCAL else 0

    if kind in (ATTN_GLOBAL, ATTN_LOCAL):
        if "mla" in lp:
            out, c, kr = mla_lib.mla_decode(lp["mla"], h, state["c"],
                                            state["kr"], pos, cfg.mla,
                                            cfg.num_heads,
                                            rope_theta=cfg.rope_theta,
                                            window=window)
            new_state = {"c": c, "kr": kr}
            attn_out = out
        else:
            attn_out, new_state = _decode_self_attention(lp["attn"], state, h,
                                                         pos, cfg, kind)
        if cfg.parallel_block:
            ffn_out = apply_ffn(lp["ffn"], h, activation=cfg.activation,
                                glu=cfg.glu)
            return x + attn_out + ffn_out, new_state
        x = x + attn_out
        if "cross" in lp:
            hc = rms_norm(x, lp["ln_cross"], cfg.norm_eps)
            x = x + _decode_cross_attention(lp["cross"], hc, enc_out, cfg)
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        if "moe" in lp:
            moe_out, _ = moe_lib.apply_moe(lp["moe"], h2, cfg.moe,
                                           activation=cfg.activation)
            x = x + moe_out
        else:
            x = x + apply_ffn(lp["ffn"], h2, activation=cfg.activation,
                              glu=cfg.glu)
        return x, new_state

    if kind == RECURRENT:
        out, new_state = rglru_lib.rglru_decode(lp["rec"], h, state,
                                                conv_width=cfg.conv1d_width)
        x = x + out
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + apply_ffn(lp["ffn"], h2, activation=cfg.activation, glu=cfg.glu)
        return x, new_state

    if kind == RWKV:
        tstate = {"S": state["S"], "shift": state["shift_t"]}
        tm_out, tnew = rwkv_lib.apply_tmix(lp["tmix"], h, cfg.num_heads,
                                           cfg.head_dim, state=tstate)
        x = x + tm_out
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        cstate = {"shift": state["shift_c"]}
        cm_out, cnew = rwkv_lib.apply_cmix(lp["cmix"], h2, state=cstate)
        x = x + cm_out
        new_state = {"S": tnew["S"], "shift_t": tnew["shift"],
                     "shift_c": cnew["shift"]}
        return x, new_state

    raise ValueError(kind)


def decode_step(params: Params, cache: Params, cfg: ModelConfig,
                tokens, opts: ApplyOptions = DEFAULT_OPTS):
    """One decode step.  tokens: (B, 1) int32.  Returns (logits, new_cache)."""
    plan = stack_plan(cfg)
    pos = cache["pos"]
    x = embed_tokens(params, cfg, tokens)
    enc_out = cache.get("enc_out")

    new_cache: Params = dict(cache)
    new_head = []
    for lp, st in zip(params["head"], cache["head"]):
        x, st2 = apply_layer_decode(lp, st, x, cfg.layer_pattern[0], cfg, pos,
                                    enc_out=enc_out)
        new_head.append(st2)
    new_cache["head"] = new_head

    if plan.n_cycles > 0:
        def cycle_body(x, xs):
            cyc_params, cyc_state = xs
            new_states = []
            for p_idx, kind in enumerate(plan.pattern):
                x, st2 = apply_layer_decode(cyc_params[p_idx],
                                            cyc_state[p_idx], x, kind, cfg,
                                            pos, enc_out=enc_out)
                new_states.append(st2)
            return x, tuple(new_states)

        x, new_cyc = jax.lax.scan(
            cycle_body, x, (tuple(params["cycles"]), tuple(cache["cycles"])))
        new_cache["cycles"] = list(new_cyc)

    new_tail = []
    for lp, st, kind in zip(params["tail"], cache["tail"], plan.tail_kinds):
        x, st2 = apply_layer_decode(lp, st, x, kind, cfg, pos, enc_out=enc_out)
        new_tail.append(st2)
    new_cache["tail"] = new_tail

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(params, cfg, x)
    new_cache["pos"] = pos + 1
    return logits, new_cache
