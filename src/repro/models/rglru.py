"""RecurrentGemma / Griffin recurrent block: conv1d + RG-LRU + output gate.

Training uses an associative scan (parallel prefix) over the diagonal
linear recurrence h_t = a_t * h_{t-1} + b_t; decode carries (h, conv
window) state — O(1) per token, which is why long_500k runs natively.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init

_C = 8.0  # RG-LRU temperature


def init_rglru(key, d_model: int, lru_width: int, conv_width: int,
               dtype=jnp.float32):
    ks = jax.random.split(key, 8)
    W = lru_width
    p = {
        "w_x": dense_init(ks[0], d_model, W, dtype),         # recurrent branch in
        "w_y": dense_init(ks[1], d_model, W, dtype),         # gate branch in
        "conv_w": (jax.random.normal(ks[2], (conv_width, W)) * 0.02).astype(dtype),
        "conv_b": jnp.zeros((W,), dtype),
        "w_a": dense_init(ks[3], W, W, dtype),               # recurrence gate
        "b_a": jnp.zeros((W,), dtype),
        "w_i": dense_init(ks[4], W, W, dtype),               # input gate
        "b_i": jnp.zeros((W,), dtype),
        # Lambda parametrized so a = exp(-c*softplus(L)) starts near 0.9..0.999
        "log_lambda": (jax.random.uniform(ks[5], (W,), minval=-4.3, maxval=-1.0)
                       ).astype(jnp.float32),
        "w_out": dense_init(ks[6], W, d_model, dtype),
    }
    return p


def _gates(p, xc):
    """RG-LRU gate computation from conv output xc: (..., W)."""
    r = jax.nn.sigmoid(xc @ p["w_a"] + p["b_a"]).astype(jnp.float32)
    i = jax.nn.sigmoid(xc @ p["w_i"] + p["b_i"]).astype(jnp.float32)
    log_a = -_C * jax.nn.softplus(p["log_lambda"]) * r       # (..., W)
    a = jnp.exp(log_a)
    gated_x = i * xc.astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6)) * gated_x
    return a, b


def _conv1d(p, x, conv_width: int):
    """Causal temporal conv via shifted adds.  x: (B, S, W)."""
    out = jnp.zeros_like(x)
    for i in range(conv_width):
        xi = x if i == 0 else jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        out = out + xi * p["conv_w"][conv_width - 1 - i]
    return out + p["conv_b"]


def apply_rglru(p, x, *, conv_width: int):
    """Full-sequence recurrent block.  x: (B, S, d) -> (B, S, d)."""
    xr = x @ p["w_x"]
    xc = _conv1d(p, xr, conv_width)
    a, b = _gates(p, xc)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    gate = jax.nn.gelu(x @ p["w_y"], approximate=True)
    return (h.astype(x.dtype) * gate) @ p["w_out"]


def init_rglru_state(batch: int, lru_width: int, conv_width: int, dtype):
    return {
        "h": jnp.zeros((batch, lru_width), jnp.float32),
        "conv": jnp.zeros((batch, conv_width - 1, lru_width), dtype),
    }


def rglru_decode(p, x, state, *, conv_width: int):
    """Single-step decode.  x: (B, 1, d) -> (out (B,1,d), new state)."""
    xr = (x @ p["w_x"])[:, 0]                                 # (B, W)
    window = jnp.concatenate([state["conv"], xr[:, None, :]], axis=1)  # (B,cw,W)
    xc = jnp.einsum("bcw,cw->bw", window, p["conv_w"]) + p["conv_b"]
    a, b = _gates(p, xc)
    h = a * state["h"] + b
    gate = jax.nn.gelu(x[:, 0] @ p["w_y"], approximate=True)
    out = (h.astype(x.dtype) * gate) @ p["w_out"]
    new_state = {"h": h, "conv": window[:, 1:]}
    return out[:, None, :], new_state
