"""RWKV-6 (Finch) block: time-mix with data-dependent decay + channel-mix.

Attention-free: training scans the WKV linear recurrence over time
(state (B, H, K, V) per layer); decode is O(1) per token, so long_500k
runs natively.  Token-shift is the RWKV ddlerp (LoRA-modulated
interpolation with the previous token).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init

_LORA_R = 32
_DECAY_R = 64
_MIX = 5  # r, k, v, w, g


def init_rwkv_tmix(key, d_model: int, num_heads: int, head_dim: int,
                   dtype=jnp.float32):
    ks = jax.random.split(key, 12)
    d = d_model
    dh = num_heads * head_dim
    p = {
        "mu_base": (jax.random.uniform(ks[0], (d,)) * 0.5).astype(dtype),
        "mu": (jax.random.uniform(ks[1], (_MIX, d)) * 0.5).astype(dtype),
        "lora_a": (jax.random.normal(ks[2], (_MIX, d, _LORA_R)) * 0.01).astype(dtype),
        "lora_b": (jax.random.normal(ks[3], (_MIX, _LORA_R, d)) * 0.01).astype(dtype),
        "w0": (jax.random.uniform(ks[4], (dh,), minval=-7.0, maxval=-4.0)
               ).astype(jnp.float32),
        "decay_a": (jax.random.normal(ks[5], (d, _DECAY_R)) * 0.01).astype(dtype),
        "decay_b": (jax.random.normal(ks[6], (_DECAY_R, dh)) * 0.01).astype(dtype),
        "u": (jax.random.normal(ks[7], (num_heads, head_dim)) * 0.1).astype(jnp.float32),
        "w_r": dense_init(ks[8], d, dh, dtype),
        "w_k": dense_init(ks[9], d, dh, dtype),
        "w_v": dense_init(ks[10], d, dh, dtype),
        "w_g": dense_init(ks[11], d, dh, dtype),
        "w_o": dense_init(jax.random.fold_in(key, 99), dh, d, dtype),
        "ln_scale": jnp.ones((dh,), dtype),
    }
    return p


def init_rwkv_cmix(key, d_model: int, d_ff: int, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    return {
        "mu_k": (jax.random.uniform(ks[0], (d_model,)) * 0.5).astype(dtype),
        "mu_r": (jax.random.uniform(ks[1], (d_model,)) * 0.5).astype(dtype),
        "w_k": dense_init(ks[2], d_model, d_ff, dtype),
        "w_v": dense_init(ks[3], d_ff, d_model, dtype),
        "w_r": dense_init(jax.random.fold_in(key, 7), d_model, d_model, dtype),
    }


def _ddlerp(p, x, x_prev):
    """Data-dependent token-shift interpolation -> (r,k,v,w,g) inputs."""
    dx = x_prev - x
    xx = x + dx * p["mu_base"]
    # two-step lora: tanh(xx @ A_m) @ B_m  per mix channel m
    t = jnp.tanh(jnp.einsum("...d,mdr->m...r", xx, p["lora_a"]))
    delta = jnp.einsum("m...r,mrd->m...d", t, p["lora_b"])
    mixed = x[None] + dx[None] * (p["mu"][:, None, None, :] + delta)
    return mixed  # (5, B, S, d)


def _wkv_scan(r, k, v, w, u, state):
    """WKV recurrence. r,k,w: (B,S,H,K); v: (B,S,H,V); state: (B,H,K,V)."""
    def step(S_prev, xs):
        rt, kt, vt, wt = xs                                  # (B,H,K/V)
        kv = kt[..., :, None] * vt[..., None, :]             # (B,H,K,V)
        out = jnp.einsum("bhk,bhkv->bhv", rt,
                         S_prev + u[None, :, :, None] * kv)
        S = wt[..., :, None] * S_prev + kv
        return S, out

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    state, out = jax.lax.scan(step, state, xs)
    return state, jnp.moveaxis(out, 0, 1)                    # (B,S,H,V)


_LOG_CLAMP = 25.0


def _wkv_chunked(r, k, v, w, u, state, chunk: int):
    """Chunk-parallel WKV (flash-linear-attention form) — §Perf hillclimb.

    The per-step scan round-trips the (B,H,K,V) state through HBM once
    per token (the dominant roofline term for rwkv6 training).  Here the
    recurrence is evaluated in L-length chunks with two MXU matmuls per
    chunk — the state crosses HBM once per CHUNK, an S/L-fold reduction
    in state traffic.

    Within a chunk (1-based t, C_t = prod_{s<=t} w_s):
      y_t   = (r_t . C_{t-1}) @ S_0
              + sum_{s<t} [(r_t . C_{t-1}) @ (k_s / C_s)] v_s
              + (r_t . u . k_t) v_t
      S_out = diag(C_L) (S_0 + sum_s (k_s / C_s) v_s^T)
    Log-cumulative decays are clamped at +/-25 (contributions beyond
    e^-25 are numerically zero) — exact for moderate decay, documented.
    """
    B, S, H, K = k.shape
    assert S % chunk == 0
    L = S // chunk

    def resh(x):
        return x.reshape(B, L, chunk, H, -1).transpose(1, 0, 2, 3, 4)

    rb, kb, vb, wb = resh(r), resh(k), resh(v), resh(w)

    def body(S0, xs):
        rc, kc, vc, wc = xs                   # (B, chunk, H, K/V)
        lw = jnp.log(jnp.maximum(wc, 1e-38))  # (B, chunk, H, K), <= 0
        cum = jnp.cumsum(lw, axis=1)          # C_t in log space
        cum_prev = cum - lw                   # C_{t-1}
        r_t = rc * jnp.exp(jnp.maximum(cum_prev, -_LOG_CLAMP))
        k_t = kc * jnp.exp(jnp.minimum(-cum, _LOG_CLAMP))
        A = jnp.einsum("bthk,bshk->bhts", r_t, k_t)          # (B,H,c,c)
        tri = jnp.tril(jnp.ones((chunk, chunk), A.dtype), k=-1)
        diag = jnp.einsum("bthk,bthk->bht", rc * u[None, None], kc)
        A = A * tri[None, None] + \
            diag[..., None] * jnp.eye(chunk, dtype=A.dtype)[None, None]
        y = jnp.einsum("bhts,bshv->bthv", A, vc)             # intra-chunk
        y = y + jnp.einsum("bthk,bhkv->bthv", r_t, S0)       # state term
        kv = jnp.einsum("bshk,bshv->bhkv", k_t, vc)
        S_new = jnp.exp(jnp.maximum(cum[:, -1], -_LOG_CLAMP)
                        )[..., None] * (S0 + kv)
        return S_new, y

    state, yb = jax.lax.scan(body, state.astype(jnp.float32),
                             (rb.astype(jnp.float32), kb.astype(jnp.float32),
                              vb.astype(jnp.float32), wb))
    out = yb.transpose(1, 0, 2, 3, 4).reshape(B, S, H, -1)
    return state, out


def apply_tmix(p, x, num_heads: int, head_dim: int, *, state=None,
               wkv_chunk: int = 0):
    """Time-mix over a full sequence.  x: (B, S, d).

    state: optional {"S": (B,H,K,V) fp32, "shift": (B, d)} for chunked /
    decode continuation.  Returns (out, new_state).
    """
    B, S, d = x.shape
    H, K = num_heads, head_dim
    shift_in = state["shift"] if state is not None else jnp.zeros((B, d), x.dtype)
    x_prev = jnp.concatenate([shift_in[:, None, :], x[:, :-1]], axis=1)
    mixed = _ddlerp(p, x, x_prev)
    lr, lk, lv, lw, lg = [mixed[i] for i in range(_MIX)]

    r = (lr @ p["w_r"]).reshape(B, S, H, K)
    k = (lk @ p["w_k"]).reshape(B, S, H, K)
    v = (lv @ p["w_v"]).reshape(B, S, H, K)
    g = jax.nn.silu(lg @ p["w_g"])
    decay = p["w0"] + (jnp.tanh(lw @ p["decay_a"]) @ p["decay_b"]).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(decay)).reshape(B, S, H, K)

    S0 = state["S"] if state is not None else jnp.zeros((B, H, K, K), jnp.float32)
    if wkv_chunk and S % wkv_chunk == 0 and S > wkv_chunk:
        S_new, wkv = _wkv_chunked(r, k, v, w, p["u"].astype(jnp.float32),
                                  S0, wkv_chunk)
    else:
        S_new, wkv = _wkv_scan(r.astype(jnp.float32), k.astype(jnp.float32),
                               v.astype(jnp.float32), w, p["u"], S0)

    # per-head group norm
    mu = jnp.mean(wkv, axis=-1, keepdims=True)
    var = jnp.var(wkv, axis=-1, keepdims=True)
    wkv = (wkv - mu) * jax.lax.rsqrt(var + 1e-5)
    out = (wkv.reshape(B, S, H * K).astype(x.dtype) * p["ln_scale"]) * g
    new_state = {"S": S_new, "shift": x[:, -1, :]}
    return out @ p["w_o"], new_state


def apply_cmix(p, x, *, state=None):
    """Channel-mix.  x: (B, S, d); state: {"shift": (B, d)}."""
    B, S, d = x.shape
    shift_in = state["shift"] if state is not None else jnp.zeros((B, d), x.dtype)
    x_prev = jnp.concatenate([shift_in[:, None, :], x[:, :-1]], axis=1)
    dx = x_prev - x
    xk = x + dx * p["mu_k"]
    xr = x + dx * p["mu_r"]
    k = jnp.square(jax.nn.relu(xk @ p["w_k"]))
    out = jax.nn.sigmoid(xr @ p["w_r"]) * (k @ p["w_v"])
    return out, {"shift": x[:, -1, :]}


def init_rwkv_state(batch: int, d_model: int, num_heads: int, head_dim: int, dtype):
    return {
        "S": jnp.zeros((batch, num_heads, head_dim, head_dim), jnp.float32),
        "shift_t": jnp.zeros((batch, d_model), dtype),
        "shift_c": jnp.zeros((batch, d_model), dtype),
    }
