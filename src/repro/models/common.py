"""Shared model building blocks: norms, RoPE, initializers, apply options."""
from __future__ import annotations

import dataclasses
import warnings
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class ApplyOptions:
    """Runtime options for model application (not part of the model config)."""
    attn_chunk: int = 1024          # q-block size for chunked attention; 0 = dense
    # compute backend for ops-routed tensor ops ("" = cfg.backend /
    # $FEDPHD_BACKEND / "xla" — see repro.models.ops.resolve_backend)
    backend: str = ""
    # DEPRECATED alias for backend="pallas" on attention: warns at
    # construction, removed after one release
    use_flash: bool = False
    remat: bool = True              # activation checkpointing over layer blocks
    deterministic: bool = True      # disable dropout
    # activation-sharding constraints (mesh axis names; () = unconstrained).
    # Without these XLA propagates the FSDP param sharding onto activations
    # (feature-dim sharded, batch replicated) — catastrophic for attention
    # logits.  Set by the launch layer; smoke tests leave them empty.
    act_batch_axes: tuple = ()      # (B, ...) dims of activations
    act_model_axes: tuple = ()      # head/ffn dims where applicable
    mesh_axis_sizes: tuple = ()     # (("data",16),("model",16)) for checks
    # expert-parallel MoE (shard_map all-to-all dispatch; §Perf hillclimb)
    moe_ep: bool = False
    ep_mesh: object = None          # jax Mesh (trace-time only)
    ep_axes: tuple = ()             # mesh axes the expert dim shards over
    ep_token_axes: tuple = ()       # mesh axes flat tokens shard over
    wkv_chunk: int = 0              # chunk-parallel WKV (0 = exact scan)

    def __post_init__(self):
        if self.use_flash:
            warnings.warn(
                "ApplyOptions.use_flash is deprecated; use "
                "backend=\"pallas\" (routes attention through the same "
                "flash kernel)", DeprecationWarning, stacklevel=3)


DEFAULT_OPTS = ApplyOptions()


def constrain_activation(x, opts: "ApplyOptions", *, batch_dim: int = 0):
    """Constrain an activation's batch dim to the data axes (no-op when
    opts.act_batch_axes is empty or outside an active mesh)."""
    if not opts.act_batch_axes:
        return x
    from jax.sharding import PartitionSpec as P
    spec = [None] * x.ndim
    spec[batch_dim] = tuple(opts.act_batch_axes) \
        if len(opts.act_batch_axes) > 1 else opts.act_batch_axes[0]
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (ValueError, RuntimeError):
        return x


def constrain_heads(x, opts: "ApplyOptions", *, seq_fallback: bool = False):
    """Constrain a (B, S, H, hd) tensor: batch over data axes, heads over
    the model axes when H divides — and NEVER shard across head_dim.

    Without this, a flat (B, S, H*hd) column-parallel projection reshaped
    to heads leaves head_dim partially sharded, and QK^T turns into
    partial-sum all-reduces of full logit tensors.

    seq_fallback: when heads do NOT divide the model axes (gemma2: 8
    heads on 16-way TP), shard the SEQUENCE dim over "model" instead —
    sequence-parallel attention: each model rank attends its own query
    slice against the (batch-sharded, model-replicated) KV, so attention
    compute still splits 16 ways and no logits collectives appear
    (§Perf hillclimb #2).
    """
    if not opts.act_batch_axes or x.ndim != 4:
        return x
    from jax.sharding import PartitionSpec as P
    sizes = dict(opts.mesh_axis_sizes)
    batch = tuple(opts.act_batch_axes)
    model = tuple(a for a in opts.act_model_axes if a in sizes)
    mprod = 1
    for a in model:
        mprod *= sizes[a]
    head_entry = None
    seq_entry = None
    if model and x.shape[2] % mprod == 0:
        head_entry = model if len(model) > 1 else model[0]
    elif seq_fallback and model and x.shape[1] % mprod == 0:
        seq_entry = model if len(model) > 1 else model[0]
    spec = P(batch if len(batch) > 1 else batch[0], seq_entry, head_entry,
             None)
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------
def dense_init(key, in_dim: int, out_dim: int, dtype=jnp.float32, scale: float = 1.0):
    std = scale / (in_dim ** 0.5)
    return (jax.random.normal(key, (in_dim, out_dim)) * std).astype(dtype)


def stacked_dense_init(key, n: int, in_dim: int, out_dim: int, dtype=jnp.float32,
                       scale: float = 1.0):
    std = scale / (in_dim ** 0.5)
    return (jax.random.normal(key, (n, in_dim, out_dim)) * std).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
from functools import partial as _partial


def _sumsq_f32(a, b):
    """sum(a*b) over the last axis with fp32 accumulation and NO
    convert op (a dot with preferred_element_type) — a convert(x) here
    gets hoisted by XLA onto whole remat-saved stacks (observed: a
    72 GiB fp32 copy of the 48-layer saved carries)."""
    return jnp.einsum("...d,...d->...", a, b,
                      preferred_element_type=jnp.float32)[..., None]


@_partial(jax.custom_vjp, nondiff_argnums=(2,))
def _rms_core(x, scale, eps):
    var = _sumsq_f32(x, x) / x.shape[-1]
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * (1.0 + scale).astype(x.dtype)


def _rms_fwd(x, scale, eps):
    return _rms_core(x, scale, eps), (x, scale)


def _rms_bwd(eps, res, dy):
    # custom backward keeps cotangents in the activation dtype (reductions
    # in fp32, fused) — without this the fp32 d(x^2) path poisons every
    # downstream cotangent to fp32, doubling all-reduce and remat bytes.
    x, scale = res
    D = x.shape[-1]
    var = _sumsq_f32(x, x) / D
    inv = jax.lax.rsqrt(var + eps)                            # f32 (...,1)
    s1 = (1.0 + scale).astype(x.dtype)
    dys = dy * s1
    t = _sumsq_f32(dys, x)                                    # f32, fused
    coef = (inv ** 3 * t / D).astype(x.dtype)
    dx = dys * inv.astype(x.dtype) - x * coef
    dscale = jnp.einsum("...d,...->d", dy * x, inv[..., 0],
                        preferred_element_type=jnp.float32)
    return dx, dscale.astype(scale.dtype)


_rms_core.defvjp(_rms_fwd, _rms_bwd)


def rms_norm(x, scale, eps: float = 1e-6):
    return _rms_core(x, scale, eps)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def group_norm(x, scale, bias, num_groups: int = 32, eps: float = 1e-5):
    """GroupNorm over NHWC activations (U-Net)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    n, h, w, c = x.shape
    g = min(num_groups, c)
    while c % g != 0:
        g -= 1
    xg = x.reshape(n, h, w, g, c // g)
    mu = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + eps)
    y = xg.reshape(n, h, w, c) * scale + bias
    return y.astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., seq, heads, head_dim); positions: (..., seq).

    Trig tables are computed in fp32 (they are position-sized, tiny) but
    the rotation runs in the activation dtype — upcasting x here creates
    program-level fp32 copies of every q/k tensor (forward AND backward),
    ~10 TB/step of phantom HBM traffic at internlm2-20b scale.
    """
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                      # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., :, None, :].astype(x.dtype)   # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :].astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                           axis=-1)


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------
def softcap(x, cap: float):
    """Gemma-2 style logit soft-capping."""
    if cap <= 0.0:
        return x
    return cap * jnp.tanh(x / cap)


def activation_fn(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": lambda x: jax.nn.gelu(x, approximate=True),
        "relu": jax.nn.relu,
        "relu2": lambda x: jnp.square(jax.nn.relu(x)),
    }[name]


def sinusoidal_embedding(t, dim: int, max_period: float = 10000.0):
    """Timestep embedding for diffusion models. t: (B,) -> (B, dim)."""
    half = dim // 2
    freqs = jnp.exp(-jnp.log(max_period) * jnp.arange(half, dtype=jnp.float32) / half)
    args = t.astype(jnp.float32)[:, None] * freqs[None, :]
    emb = jnp.concatenate([jnp.cos(args), jnp.sin(args)], axis=-1)
    if dim % 2:
        emb = jnp.pad(emb, ((0, 0), (0, 1)))
    return emb


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]
