"""Grouped-query attention: dense, chunked (memory-bounded), windowed, cached.

Shapes (batch-major, seq-second):
  q: (B, Sq, Hq, hd)   k/v: (B, Skv, Hkv, hd)   with Hq = G * Hkv.

The chunked path scans over query blocks so the (Sq x Skv) logit tensor is
never materialized — required for prefill_32k and the memory baseline the
Pallas flash kernel is later benchmarked against.  Sliding-window attention
slices the KV range per query block, making windowed prefill compute
sub-quadratic (not just masked).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import softcap as _softcap

NEG_INF = -1e30


def _mask_bias(q_pos, kv_pos, *, causal: bool, window: int):
    """Additive bias from positions.

    Positions may be 1-D (shared across batch — train/prefill) giving a
    batch-free (Sq, Skv) bias, or 2-D (B, S) (decode ring buffers) giving
    (B, Sq, Skv).  Keeping the bias batch-free avoids materializing a
    replicated (B, S, S) tensor (16 GiB/device at B=256, S=4k).
    """
    d = q_pos[..., :, None] - kv_pos[..., None, :]
    ok = jnp.ones(d.shape, bool)
    if causal:
        ok &= d >= 0
    if window > 0:
        ok &= d < window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _attend_block(q, k, v, q_pos, kv_pos, *, causal, window, attn_softcap, scale):
    """Dense attention for one q block. q: (B,Sq,Hkv,G,hd), k/v: (B,Skv,Hkv,hd).

    Logits stay in the activation dtype (softmax reductions upcast to
    fp32) — keeping the cotangent chain bf16; an fp32 logits tensor would
    poison every upstream gradient to fp32 (2x HBM + 2x all-reduce).
    """
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", q, k) * jnp.asarray(scale, q.dtype)
    if attn_softcap > 0.0:
        logits = _softcap(logits, attn_softcap)
    bias = _mask_bias(q_pos, kv_pos, causal=causal, window=window)
    bias = bias.astype(logits.dtype)
    if bias.ndim == 2:
        logits = logits + bias[None, None, None, :, :]
    else:
        logits = logits + bias[:, None, None, :, :]
    lmax = jax.lax.stop_gradient(
        jnp.max(logits.astype(jnp.float32), axis=-1, keepdims=True))
    unnorm = jnp.exp(logits - lmax.astype(logits.dtype))
    denom = jnp.sum(unnorm.astype(jnp.float32), axis=-1,
                    keepdims=True).astype(logits.dtype)
    probs = unnorm / denom
    return jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)


def attend(q, k, v, *, q_positions, kv_positions, causal: bool = True,
           window: int = 0, attn_softcap: float = 0.0, chunk: int = 0,
           remat_chunks: bool = True):
    """Generic GQA attention.

    q: (B, Sq, Hq, hd); k, v: (B, Skv, Hkv, hd)
    q_positions: (Sq,) shared across batch, or (B, Sq) int32
    kv_positions: (Skv,) or (B, Skv) int32
    chunk: q-block size for the scanned path (0 or >= Sq -> dense).
    """
    B, Sq, Hq, hd = q.shape
    Hkv = k.shape[2]
    hd_v = v.shape[-1]                    # may differ from hd (MLA)
    G = Hq // Hkv
    scale = hd ** -0.5
    qg = q.reshape(B, Sq, Hkv, G, hd)

    if chunk <= 0 or Sq <= chunk or Sq % chunk != 0:
        # dense path (also the fallback for non-chunk-aligned lengths,
        # e.g. whisper's 1500 encoder frames)
        out = _attend_block(qg, k, v, q_positions, kv_positions,
                            causal=causal, window=window,
                            attn_softcap=attn_softcap, scale=scale)
        return out.reshape(B, Sq, Hq, hd_v)

    assert q_positions.ndim == 1 and kv_positions.ndim == 1, \
        "chunked attention expects shared (1-D) positions"
    n_blocks = Sq // chunk
    qb = qg.reshape(B, n_blocks, chunk, Hkv, G, hd).transpose(1, 0, 2, 3, 4, 5)
    pb = q_positions.reshape(n_blocks, chunk)

    Skv = k.shape[1]
    # For windowed causal attention with aligned positions we can slice the
    # KV range touched by each query block: [blk_end - window - chunk, blk_end).
    kv_span = 0
    if window > 0 and causal:
        kv_span = min(Skv, ((window + chunk + chunk - 1) // chunk) * chunk)

    def body(_, xs):
        qi, pi, idx = xs
        if kv_span and kv_span < Skv:
            start = jnp.clip((idx + 1) * chunk - kv_span, 0, Skv - kv_span)
            ks = jax.lax.dynamic_slice_in_dim(k, start, kv_span, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(v, start, kv_span, axis=1)
            kp = jax.lax.dynamic_slice_in_dim(kv_positions, start, kv_span,
                                              axis=0)
        else:
            ks, vs, kp = k, v, kv_positions
        out = _attend_block(qi, ks, vs, pi, kp, causal=causal, window=window,
                            attn_softcap=attn_softcap, scale=scale)
        return None, out

    # remat each q-block: backward recomputes block logits instead of
    # stashing per-block softmax residuals for every block at once.
    body_fn = jax.checkpoint(body) if remat_chunks else body
    _, ob = jax.lax.scan(body_fn, None,
                         (qb, pb, jnp.arange(n_blocks, dtype=jnp.int32)))
    out = ob.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, Hq, hd_v)
    return out


def decode_attend(q, k_cache, v_cache, pos, *, window: int = 0,
                  attn_softcap: float = 0.0):
    """Single-token decode attention against a (B, S, Hkv, hd) cache.

    pos: (B,) int32 — index of the new token; cache entries > pos are invalid.
    """
    B, S, Hkv, hd = k_cache.shape
    q_positions = pos[:, None]                                  # (B, 1)
    kv_positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    return attend(q, k_cache, v_cache, q_positions=q_positions,
                  kv_positions=kv_positions, causal=True, window=window,
                  attn_softcap=attn_softcap, chunk=0)
