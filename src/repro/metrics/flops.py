"""Analytic #Params / MACs accounting (paper Tables III/IV) and
MODEL_FLOPS = 6*N*D for the roofline's useful-compute ratio."""
from __future__ import annotations

from typing import Dict

import jax

from repro.configs.base import InputShape, ModelConfig


def count_params(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def count_params_analytic(cfg: ModelConfig) -> int:
    """Parameter count from the config (matches models.model.init)."""
    if cfg.arch_type == "unet":
        raise ValueError("unet params counted from the pytree")
    d, hd = cfg.d_model, cfg.head_dim
    Hq, Hkv = cfg.num_heads, cfg.num_kv_heads
    n = cfg.vocab_size * d                     # embed
    if not cfg.tie_embeddings:
        n += d * cfg.vocab_size                # lm head
    n += d                                     # final norm

    def attn():
        if cfg.mla is not None:
            m = cfg.mla
            qk = m.qk_nope_head_dim + m.qk_rope_head_dim
            return (d * m.q_lora_rank + m.q_lora_rank
                    + m.q_lora_rank * Hq * qk
                    + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    + m.kv_lora_rank
                    + m.kv_lora_rank * Hq * (m.qk_nope_head_dim + m.v_head_dim)
                    + Hq * m.v_head_dim * d)
        a = d * Hq * hd + 2 * d * Hkv * hd + Hq * hd * d
        if cfg.use_qkv_bias:
            a += Hq * hd + 2 * Hkv * hd
        if cfg.use_attn_out_bias:
            a += d
        return a

    def ffn(d_ff):
        f = d * d_ff * (3 if cfg.glu else 2)
        if cfg.use_ffn_bias:
            f += d_ff + d
        return f

    def moe_layer():
        m = cfg.moe
        e = d * m.num_experts                   # router
        e += m.num_experts * (3 * d * m.d_expert)
        if m.num_shared_experts:
            e += 3 * d * m.d_shared
        return e

    def rglru():
        W = cfg.lru_width
        return (2 * d * W + cfg.conv1d_width * W + W
                + 2 * (W * W + W) + W + W * d)

    def rwkv_layer():
        dh = Hq * hd
        tm = (d + 5 * d + 5 * d * 32 + 5 * 32 * d      # mus + loras
              + dh + d * 64 + 64 * dh + Hq * hd         # decay + u
              + 4 * d * dh + dh * d + dh)               # r,k,v,g,o, ln
        cm = 2 * d + d * cfg.d_ff + cfg.d_ff * d + d * d
        return tm + cm

    from repro.configs.base import ATTN_GLOBAL, ATTN_LOCAL, RECURRENT, RWKV
    n_head_layers = cfg.moe.first_dense_layers if cfg.moe else 0
    for i, kind in enumerate(cfg.layer_kinds()):
        n += d  # ln1
        if kind in (ATTN_GLOBAL, ATTN_LOCAL):
            n += attn()
            n += d  # ln2
            if cfg.moe is not None and i >= n_head_layers:
                n += moe_layer()
            else:
                n += ffn(cfg.d_ff)
        elif kind == RECURRENT:
            n += rglru() + d + ffn(cfg.d_ff)
        elif kind == RWKV:
            n += rwkv_layer() + d
    if cfg.arch_type == "encdec":
        per_enc = d + attn() + d + ffn(cfg.d_ff)
        n += cfg.num_encoder_layers * per_enc + d
        # decoder cross-attention (one per decoder layer)
        n += cfg.num_layers * (d + attn())
    return int(n)


def active_params(cfg: ModelConfig) -> int:
    """Activated parameters per token (MoE: only routed experts count)."""
    total = count_params_analytic(cfg)
    if cfg.moe is None:
        return total
    m = cfg.moe
    per_expert = 3 * cfg.d_model * m.d_expert
    n_moe_layers = cfg.num_layers - m.first_dense_layers
    inactive = n_moe_layers * (m.num_experts - m.experts_per_token) * per_expert
    return int(total - inactive)


def model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """MODEL_FLOPS = 6*N_active*D (train: fwd+bwd) or 2*N_active*D
    (prefill/decode: fwd only) — the roofline's useful-compute basis."""
    tokens = shape.global_batch * (1 if shape.mode == "decode" else shape.seq_len)
    factor = 6.0 if shape.mode == "train" else 2.0
    return factor * active_params(cfg) * tokens


def unet_macs(params, image_size: int, masks=None) -> float:
    """Analytic MACs of one U-Net forward pass (Table III/IV accounting).

    Convolutions dominate; dense layers + attention included.

    ``masks``: optional sparse-phase prune masks keyed by PruneGroup
    name (the ``apply_unet(masks=)`` contract) — the count then reflects
    the *served* compute of the masked forward: each ResBlock's
    conv1/temb output and conv2 input shrink to the group's kept-channel
    count, and each attention block's qkv/proj GEMMs likewise.  The
    attention score/value einsums stay full-width (pruned channels are
    zeroed, not removed, there), so masked MACs are the honest cost of
    the static-sparsity serving path, not a naive ``(1-ratio)`` scaling.
    """
    import numpy as np

    def kept(name: str, size: int) -> int:
        if masks is None or name not in masks:
            return size
        return int(np.sum(np.asarray(masks[name]) != 0))

    def conv_macs(w, res, cin_kept=None, cout_kept=None):
        kh, kw, cin, cout = w.shape
        cin = cin if cin_kept is None else cin_kept
        cout = cout if cout_kept is None else cout_kept
        return kh * kw * cin * cout * res * res

    def resblock_macs(rp, res, name):
        k = kept(name, rp["conv1"]["w"].shape[-1])
        m = conv_macs(rp["conv1"]["w"], res, cout_kept=k)
        m += conv_macs(rp["conv2"]["w"], res, cin_kept=k)
        if "skip" in rp:
            m += conv_macs(rp["skip"]["w"], res)
        m += rp["temb"]["w"].shape[0] * k
        return m

    def attnblock_macs(ap, res, name):
        c = ap["proj"]["w"].shape[2]
        k = kept(name, c)
        m = conv_macs(ap["qkv"]["w"], res, cout_kept=3 * k)
        m += conv_macs(ap["proj"]["w"], res, cin_kept=k)
        m += 2 * (res * res) ** 2 * c
        return m

    # Explicit traversal mirroring apply_unet resolution changes.
    total = 0.0
    res = image_size
    total += conv_macs(params["conv_in"]["w"], res)
    for lvl, lvl_p in enumerate(params["down"]):
        for bi, blk in enumerate(lvl_p["blocks"]):
            total += resblock_macs(blk["res"], res,
                                   f"down/{lvl}/blocks/{bi}/res")
            if "attn" in blk:
                total += attnblock_macs(blk["attn"], res,
                                        f"down/{lvl}/blocks/{bi}/attn")
        if "down" in lvl_p:
            res //= 2
            total += conv_macs(lvl_p["down"]["w"], res)
    total += resblock_macs(params["mid"]["res1"], res, "mid/res1")
    total += attnblock_macs(params["mid"]["attn"], res, "mid/attn")
    total += resblock_macs(params["mid"]["res2"], res, "mid/res2")
    for lvl, lvl_p in enumerate(params["up"]):
        for bi, blk in enumerate(lvl_p["blocks"]):
            total += resblock_macs(blk["res"], res,
                                   f"up/{lvl}/blocks/{bi}/res")
            if "attn" in blk:
                total += attnblock_macs(blk["attn"], res,
                                        f"up/{lvl}/blocks/{bi}/attn")
        if "up" in lvl_p:
            res *= 2
            total += conv_macs(lvl_p["up"]["w"], res)
    total += conv_macs(params["conv_out"]["w"], res)
    return total
