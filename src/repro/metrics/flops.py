"""Analytic #Params / MACs accounting (paper Tables III/IV) and
MODEL_FLOPS = 6*N*D for the roofline's useful-compute ratio."""
from __future__ import annotations

from typing import Dict

import jax

from repro.configs.base import InputShape, ModelConfig


def count_params(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def count_params_analytic(cfg: ModelConfig) -> int:
    """Parameter count from the config (matches models.model.init)."""
    if cfg.arch_type == "unet":
        raise ValueError("unet params counted from the pytree")
    d, hd = cfg.d_model, cfg.head_dim
    Hq, Hkv = cfg.num_heads, cfg.num_kv_heads
    n = cfg.vocab_size * d                     # embed
    if not cfg.tie_embeddings:
        n += d * cfg.vocab_size                # lm head
    n += d                                     # final norm

    def attn():
        if cfg.mla is not None:
            m = cfg.mla
            qk = m.qk_nope_head_dim + m.qk_rope_head_dim
            return (d * m.q_lora_rank + m.q_lora_rank
                    + m.q_lora_rank * Hq * qk
                    + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    + m.kv_lora_rank
                    + m.kv_lora_rank * Hq * (m.qk_nope_head_dim + m.v_head_dim)
                    + Hq * m.v_head_dim * d)
        a = d * Hq * hd + 2 * d * Hkv * hd + Hq * hd * d
        if cfg.use_qkv_bias:
            a += Hq * hd + 2 * Hkv * hd
        if cfg.use_attn_out_bias:
            a += d
        return a

    def ffn(d_ff):
        f = d * d_ff * (3 if cfg.glu else 2)
        if cfg.use_ffn_bias:
            f += d_ff + d
        return f

    def moe_layer():
        m = cfg.moe
        e = d * m.num_experts                   # router
        e += m.num_experts * (3 * d * m.d_expert)
        if m.num_shared_experts:
            e += 3 * d * m.d_shared
        return e

    def rglru():
        W = cfg.lru_width
        return (2 * d * W + cfg.conv1d_width * W + W
                + 2 * (W * W + W) + W + W * d)

    def rwkv_layer():
        dh = Hq * hd
        tm = (d + 5 * d + 5 * d * 32 + 5 * 32 * d      # mus + loras
              + dh + d * 64 + 64 * dh + Hq * hd         # decay + u
              + 4 * d * dh + dh * d + dh)               # r,k,v,g,o, ln
        cm = 2 * d + d * cfg.d_ff + cfg.d_ff * d + d * d
        return tm + cm

    from repro.configs.base import ATTN_GLOBAL, ATTN_LOCAL, RECURRENT, RWKV
    n_head_layers = cfg.moe.first_dense_layers if cfg.moe else 0
    for i, kind in enumerate(cfg.layer_kinds()):
        n += d  # ln1
        if kind in (ATTN_GLOBAL, ATTN_LOCAL):
            n += attn()
            n += d  # ln2
            if cfg.moe is not None and i >= n_head_layers:
                n += moe_layer()
            else:
                n += ffn(cfg.d_ff)
        elif kind == RECURRENT:
            n += rglru() + d + ffn(cfg.d_ff)
        elif kind == RWKV:
            n += rwkv_layer() + d
    if cfg.arch_type == "encdec":
        per_enc = d + attn() + d + ffn(cfg.d_ff)
        n += cfg.num_encoder_layers * per_enc + d
        # decoder cross-attention (one per decoder layer)
        n += cfg.num_layers * (d + attn())
    return int(n)


def active_params(cfg: ModelConfig) -> int:
    """Activated parameters per token (MoE: only routed experts count)."""
    total = count_params_analytic(cfg)
    if cfg.moe is None:
        return total
    m = cfg.moe
    per_expert = 3 * cfg.d_model * m.d_expert
    n_moe_layers = cfg.num_layers - m.first_dense_layers
    inactive = n_moe_layers * (m.num_experts - m.experts_per_token) * per_expert
    return int(total - inactive)


def model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """MODEL_FLOPS = 6*N_active*D (train: fwd+bwd) or 2*N_active*D
    (prefill/decode: fwd only) — the roofline's useful-compute basis."""
    tokens = shape.global_batch * (1 if shape.mode == "decode" else shape.seq_len)
    factor = 6.0 if shape.mode == "train" else 2.0
    return factor * active_params(cfg) * tokens


def unet_macs(params, image_size: int) -> float:
    """Analytic MACs of one U-Net forward pass (Table III/IV accounting).

    Convolutions dominate; dense layers + attention included.
    """
    import numpy as np
    total = 0.0

    def walk(p, res_hint):
        nonlocal total
        # heuristic: handled explicitly below
        pass

    # Explicit traversal mirroring apply_unet resolution changes.
    def conv_macs(w, res):
        kh, kw, cin, cout = w.shape
        return kh * kw * cin * cout * res * res

    res = image_size
    total += conv_macs(params["conv_in"]["w"], res)
    for lvl_p in params["down"]:
        for blk in lvl_p["blocks"]:
            rp = blk["res"]
            total += conv_macs(rp["conv1"]["w"], res)
            total += conv_macs(rp["conv2"]["w"], res)
            if "skip" in rp:
                total += conv_macs(rp["skip"]["w"], res)
            total += rp["temb"]["w"].size
            if "attn" in blk:
                ap = blk["attn"]
                total += conv_macs(ap["qkv"]["w"], res)
                total += conv_macs(ap["proj"]["w"], res)
                c = ap["proj"]["w"].shape[2]
                total += 2 * (res * res) ** 2 * c
        if "down" in lvl_p:
            res //= 2
            total += conv_macs(lvl_p["down"]["w"], res)
    for key in ("res1", "res2"):
        rp = params["mid"][key]
        total += conv_macs(rp["conv1"]["w"], res)
        total += conv_macs(rp["conv2"]["w"], res)
        total += rp["temb"]["w"].size
    ap = params["mid"]["attn"]
    total += conv_macs(ap["qkv"]["w"], res)
    total += conv_macs(ap["proj"]["w"], res)
    total += 2 * (res * res) ** 2 * ap["proj"]["w"].shape[2]
    for lvl_p in params["up"]:
        for blk in lvl_p["blocks"]:
            rp = blk["res"]
            total += conv_macs(rp["conv1"]["w"], res)
            total += conv_macs(rp["conv2"]["w"], res)
            if "skip" in rp:
                total += conv_macs(rp["skip"]["w"], res)
            total += rp["temb"]["w"].size
            if "attn" in blk:
                apb = blk["attn"]
                total += conv_macs(apb["qkv"]["w"], res)
                total += conv_macs(apb["proj"]["w"], res)
                total += 2 * (res * res) ** 2 * apb["proj"]["w"].shape[2]
        if "up" in lvl_p:
            res *= 2
            total += conv_macs(lvl_p["up"]["w"], res)
    total += conv_macs(params["conv_out"]["w"], res)
    return total
