from repro.metrics.fid import fid_proxy, inception_score_proxy, features
from repro.metrics.flops import (count_params, count_params_analytic,
                                 active_params, model_flops, unet_macs)

__all__ = ["fid_proxy", "inception_score_proxy", "features", "count_params",
           "count_params_analytic", "active_params", "model_flops",
           "unet_macs"]
