"""Proxy-FID and proxy-IS (hardware/data-gate substitute for Inception-v3).

True FID embeds images with a pretrained Inception network — unavailable
offline.  We use a FIXED random-feature CNN (weights from PRNGKey(42),
never trained): random convolutional features preserve distributional
geometry (random-projection/ELM literature), so the Fréchet distance in
this feature space ranks generative models consistently for *relative*
comparison — which is all the paper's tables do.  Absolute values are NOT
comparable to Inception-FID (DESIGN.md §8).
"""
from __future__ import annotations

from functools import lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np

_FEAT_DIM = 64
_NUM_CLASSES_HEAD = 10


@lru_cache(maxsize=4)
def _feature_params(channels: int = 3):
    rng = jax.random.PRNGKey(42)
    ks = jax.random.split(rng, 4)
    def conv_w(key, cin, cout):
        return jax.random.normal(key, (3, 3, cin, cout)) / (9 * cin) ** 0.5
    return {
        "c1": conv_w(ks[0], channels, 32),
        "c2": conv_w(ks[1], 32, 64),
        "c3": conv_w(ks[2], 64, _FEAT_DIM),
        "head": jax.random.normal(ks[3], (_FEAT_DIM, _NUM_CLASSES_HEAD))
                 / _FEAT_DIM ** 0.5,
    }


def _features(x):
    """x: (B, H, W, C) in [-1, 1] -> (B, FEAT_DIM).

    NOT jitted: _feature_params is lru-cached and jitting would cache
    tracers on first in-trace use (UnexpectedTracerError).
    """
    p = _feature_params(x.shape[-1])
    def conv(x, w, stride):
        return jax.lax.conv_general_dilated(
            x, w, (stride, stride), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
    h = jax.nn.relu(conv(x, p["c1"], 2))
    h = jax.nn.relu(conv(h, p["c2"], 2))
    h = jax.nn.relu(conv(h, p["c3"], 2))
    return jnp.mean(h, axis=(1, 2))


def features(x: np.ndarray, batch: int = 256) -> np.ndarray:
    out = []
    for i in range(0, len(x), batch):
        out.append(np.asarray(_features(jnp.asarray(x[i:i + batch]))))
    return np.concatenate(out)


def _sqrtm_psd(a: np.ndarray) -> np.ndarray:
    """Matrix square root of a symmetric PSD matrix via eigendecomposition."""
    w, v = np.linalg.eigh((a + a.T) / 2)
    w = np.maximum(w, 0.0)
    return (v * np.sqrt(w)) @ v.T


def frechet_distance(mu1, sig1, mu2, sig2) -> float:
    diff = mu1 - mu2
    s1h = _sqrtm_psd(sig1)
    covmean = _sqrtm_psd(s1h @ sig2 @ s1h)
    return float(diff @ diff + np.trace(sig1) + np.trace(sig2)
                 - 2.0 * np.trace(covmean))


def fid_proxy(real: np.ndarray, fake: np.ndarray) -> float:
    """Proxy-FID between two image sets (both (N,H,W,C) in [-1,1])."""
    fr = features(real)
    ff = features(fake)
    mu1, mu2 = fr.mean(0), ff.mean(0)
    s1 = np.cov(fr, rowvar=False) + 1e-6 * np.eye(fr.shape[1])
    s2 = np.cov(ff, rowvar=False) + 1e-6 * np.eye(ff.shape[1])
    return frechet_distance(mu1, s1, mu2, s2)


def inception_score_proxy(fake: np.ndarray, splits: int = 4) -> float:
    """Proxy-IS: exp(E_x KL(p(y|x) || p(y))) with the fixed random head."""
    p = _feature_params(fake.shape[-1])
    f = features(fake)
    logits = f @ np.asarray(p["head"])
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    scores = []
    for part in np.array_split(probs, splits):
        py = part.mean(0, keepdims=True)
        kl = (part * (np.log(part + 1e-10) - np.log(py + 1e-10))).sum(-1)
        scores.append(np.exp(kl.mean()))
    return float(np.mean(scores))
