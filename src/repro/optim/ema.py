"""Exponential moving average of parameters (Ho et al. 2020).

The paper uses EMA only in centralized training (frequent cross-node
sync is too expensive in FL — §Appendix C); ``ema_in_fl`` exposes their
"future agenda" knob.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ema_init(params):
    return jax.tree.map(lambda p: p.astype(jnp.float32), params)


def ema_update(ema, params, decay: float = 0.9999):
    return jax.tree.map(
        lambda e, p: decay * e + (1.0 - decay) * p.astype(jnp.float32),
        ema, params)
