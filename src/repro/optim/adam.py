"""Adam / AdamW — minimal optax-style (init/update) pure-pytree optimizer."""
from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any
    master: Any = None   # fp32 master copy when params are bf16 (ZeRO-1)


def adam_init(params, *, use_master: bool = False) -> AdamState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params) \
        if use_master else None
    return AdamState(step=jnp.zeros((), jnp.int32),
                     mu=jax.tree.map(zeros, params),
                     nu=jax.tree.map(zeros, params),
                     master=master)


def adam_from_tree(t) -> AdamState | None:
    """Rebuild an ``AdamState`` from a plain ``(step, mu, nu[, master])``
    tuple pytree — checkpoint loading flattens NamedTuples to tuples."""
    if t is None:
        return None
    if isinstance(t, AdamState):
        return t
    step, mu, nu, *rest = tuple(t)
    master = rest[0] if rest else None
    to_dev = lambda x: jax.tree.map(jnp.asarray, x)
    return AdamState(step=jnp.asarray(step), mu=to_dev(mu), nu=to_dev(nu),
                     master=None if master is None else to_dev(master))


def adam_update(grads, state: AdamState, params, *, lr, b1: float = 0.9,
                b2: float = 0.999, eps: float = 1e-8,
                weight_decay: float = 0.0, grad_clip: float = 0.0):
    """One Adam(W) step.  Returns (new_params, new_state).

    With a master copy (bf16 params), the update runs on the fp32 master
    and the returned params are the bf16 cast — the ZeRO-1 pattern: XLA
    reduce-scatters grads onto the sharded master/moments and all-gathers
    the fresh bf16 params.
    """
    if grad_clip > 0.0:
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    step = state.step + 1
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                      state.mu, grads)
    nu = jax.tree.map(
        lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        state.nu, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay > 0.0:
            delta = delta + weight_decay * p.astype(jnp.float32)
        return p.astype(jnp.float32) - lr * delta

    if state.master is not None:
        new_master = jax.tree.map(upd, state.master, mu, nu)
        new_params = jax.tree.map(
            lambda nm, p: nm.astype(p.dtype), new_master, params)
        return new_params, AdamState(step=step, mu=mu, nu=nu,
                                     master=new_master)
    new_params = jax.tree.map(
        lambda p, m, v: upd(p, m, v).astype(p.dtype), params, mu, nu)
    return new_params, AdamState(step=step, mu=mu, nu=nu, master=None)
