from repro.optim.adam import (AdamState, adam_from_tree, adam_init,
                              adam_update)
from repro.optim.sgd import SGDState, sgd_init, sgd_update
from repro.optim.ema import ema_init, ema_update
from repro.optim.schedules import constant, cosine_decay

__all__ = ["AdamState", "adam_from_tree", "adam_init", "adam_update",
           "SGDState", "sgd_init", "sgd_update", "ema_init", "ema_update",
           "constant", "cosine_decay"]
