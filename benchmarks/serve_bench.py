"""Serving bench: continuous-batching sampler throughput/latency and the
dense-vs-masked (44%-pruned) A/B per backend.

Two scales:

- **step A/B** runs on a tile-aligned single-level U-Net (1024-wide
  ResBlock groups, 8x8 images, 2 slots -> every spatial GEMM is
  128-aligned) where the static sparsity specialization genuinely
  shrinks the compiled program — kept counts at ratio 0.44 round to 512
  of 1024, so masked serving drops half of every 128-block grid.  The
  smoke U-Net's 32-wide groups are too small for tile effects; paper
  widths (base 128) are exactly where FedPhD claims the payoff.
- **end-to-end throughput** serves 8 requests through the full
  :class:`repro.serve.DiffusionServer` loop (refills included) on the
  smoke U-Net, reporting req/s and p50/p99 per-step latency.

Rows join the ``regression_gate.py`` flow via ``BENCH_serve.json``; the
masked pallas row carries a ``speedup=<x>x`` tag so a regression that
stops exploiting sparsity (e.g. masks silently device-committed) fails
the gate.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import dump_bench_json, emit
from repro.configs import SMOKE_UNET
from repro.configs.base import ModelConfig
from repro.models.unet import init_unet
from repro.serve import DiffusionServer, Request, masks_for_ratio

PRUNE_RATIO = 0.44

# single-level 1024-wide U-Net: all spatial GEMMs 128-aligned with
# 2 slots at 8x8 (M = 2*8*8 = 128), group width 1024 -> kept 512 at
# ratio 0.44 (kept counts for >=1024-wide groups round to 128s)
SERVE_BENCH_UNET = ModelConfig(
    name="ddpm-unet-serve-bench",
    arch_type="unet",
    source="serve_bench tile-aligned A/B variant",
    image_size=8,
    in_channels=3,
    base_channels=1024,
    channel_mults=(1,),
    num_res_blocks=1,
    attn_resolutions=(8,),
    num_classes=0,
    dropout=0.0,
    diffusion_steps=100,
    dtype="float32",
    param_dtype="float32",
)


def _steady_step_us(params, cfg, masks, *, slots: int, iters: int = 2
                    ) -> float:
    """Median per-tick latency with every slot occupied and no slot ever
    finishing inside the timed window (num_steps >> iters)."""
    srv = DiffusionServer(params, cfg, slots=slots,
                          num_steps=cfg.diffusion_steps, eta=0.0,
                          masks=masks)
    for s in range(slots):
        srv.submit(Request(rid=s, seed=s))
    srv.step()                                   # compile + warm
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        srv.step()
        times.append(time.perf_counter() - t0)
    assert srv.compile_count() == 1
    return float(np.median(times) * 1e6)


def step_ab(backend: str, precision: str = "fp32") -> None:
    cfg = SERVE_BENCH_UNET.replace(backend=backend, precision=precision)
    params = init_unet(jax.random.PRNGKey(0), cfg)
    masks = masks_for_ratio(params, cfg, PRUNE_RATIO)
    slots = 2
    dense_us = _steady_step_us(params, cfg, None, slots=slots)
    masked_us = _steady_step_us(params, cfg, masks, slots=slots)
    speedup = dense_us / masked_us
    # fp32 rows keep their pre-precision names (committed baselines)
    suffix = "" if precision == "fp32" else f"_{precision}"
    emit(f"serve/{backend}/dense_step{suffix}", dense_us, f"slots={slots}")
    emit(f"serve/{backend}/masked_step{suffix}", masked_us,
         f"slots={slots};ratio={PRUNE_RATIO};speedup={speedup:.2f}x")
    if backend == "pallas" and precision == "fp32":
        # the acceptance bar: pruned serving must not be slower than
        # dense on the kernel backend — if it is, the static
        # specialization fell off the serve path
        assert masked_us <= dense_us, \
            f"masked serving slower than dense on pallas: " \
            f"{masked_us:.0f}us > {dense_us:.0f}us"


def end_to_end() -> None:
    cfg = SMOKE_UNET.replace(backend="xla")
    params = init_unet(jax.random.PRNGKey(0), cfg)
    requests, slots, steps = 8, 4, 5
    srv = DiffusionServer(params, cfg, slots=slots, num_steps=steps)
    srv.run([Request(rid=-1, seed=0)])           # compile outside the clock
    res = srv.run([Request(rid=r, seed=r) for r in range(requests)])
    assert len(res.images) == requests and not res.faults
    p50 = res.latency_percentile(50) * 1e3
    p99 = res.latency_percentile(99) * 1e3
    emit("serve/requests", res.seconds / requests * 1e6,
         f"n={requests};slots={slots};steps={steps};"
         f"req_s={res.requests_per_s:.2f};p50_ms={p50:.1f};p99_ms={p99:.1f}")


def main() -> None:
    for backend in ("xla", "pallas"):
        step_ab(backend)
    # precision axis: dense-vs-masked again under bf16 serving (weights
    # cast once at server construction, activations at each GEMM —
    # repro.models.ops).  xla only: the pallas rows run the interpreter
    # on CPU and the bf16 leg would double an already-slow A/B for no
    # extra coverage (the kernels are precision-parameterized either
    # way and tested in tests/test_precision.py).
    step_ab("xla", "bf16")
    end_to_end()
    dump_bench_json("serve")


if __name__ == "__main__":
    import benchmarks.common  # noqa: F401  (ROWS shared via import)
    print("name,us_per_call,derived")
    main()
