"""Paper Table V (micro): scalability in the number of clients.

FedPhD vs FedAvg at N = 6 and N = 12 clients (scaled-down analogue of the
paper's 20/50/100); reports final-round training loss and proxy-FID.

The 2×2 grid is ONE ``SweepSpec`` (``method`` × ``fl.num_clients``)
through ``repro.experiment.sweep``, with FID landing through the unified
``eval_fn`` hook and the emitted numbers read out of ``sweep.report``'s
aggregation.  Output schema is unchanged:
``table5/<method>_n<N>,us_per_round,loss=..;fid=..``.
"""
from __future__ import annotations

from benchmarks.common import (emit, run_sweep_timed_eval, sample_images,
                               smoke_spec)
from repro.data import make_dataset
from repro.experiment import SweepSpec, dataset_spec
from repro.metrics import fid_proxy


def main(rounds: int = 4) -> None:
    base = smoke_spec(rounds=rounds).replace(name="table5", prune=False,
                                             eval_every=rounds)
    sweep = SweepSpec(name="table5", base=base,
                      axes={"method": ["fedphd", "fedavg"],
                            "fl.num_clients": [6, 12]},
                      group_by=("method", "fl.num_clients"))
    # the dataset (and so the FID reference) is num_clients-independent:
    # only its partition across clients changes with N
    images, _ = make_dataset(dataset_spec(base.data.dataset),
                             seed=base.seed)
    real = images[:256]

    def eval_fn(params, cfg, r):
        fake = sample_images(params, cfg, n=96, steps=10)
        return {"fid": float(fid_proxy(real, fake))}

    _, report, train_s = run_sweep_timed_eval(sweep, eval_fn)
    by_key = {(g["key"]["method"], g["key"]["fl.num_clients"]): g
              for g in report["groups"]}
    for n in (6, 12):
        for method in ("fedphd", "fedavg"):
            g = by_key[(method, n)]
            m = g["metrics"]
            (rid,) = g["runs"]
            emit(f"table5/{method}_n{n}",
                 train_s[rid] * 1e6 / rounds,
                 f"loss={m['loss']['mean']:.4f};"
                 f"fid={m['eval.fid']['mean']:.2f}")


if __name__ == "__main__":
    main()
