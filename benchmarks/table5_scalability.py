"""Paper Table V (micro): scalability in the number of clients.

FedPhD vs FedAvg at N = 6 and N = 12 clients (scaled-down analogue of the
paper's 20/50/100); reports final-round training loss and proxy-FID.
"""
from __future__ import annotations

import time

from benchmarks.common import (emit, sample_images, smoke_clients, smoke_fl)
from repro.configs import SMOKE_UNET
from repro.core.hfl import FedPhD
from repro.fl.baselines import run_flat_fl
from repro.metrics import fid_proxy


def main(rounds: int = 4) -> None:
    for n in (6, 12):
        clients, images, _ = smoke_clients(num_clients=n)
        fl = smoke_fl(rounds=rounds, num_clients=n)
        real = images[:256]

        t0 = time.perf_counter()
        trainer = FedPhD(SMOKE_UNET, fl, clients, rng_seed=0, prune=False)
        hist, _ = trainer.run(rounds)
        us = (time.perf_counter() - t0) * 1e6 / rounds
        fid = fid_proxy(real, sample_images(trainer.params, trainer.cfg,
                                            n=96, steps=10))
        emit(f"table5/fedphd_n{n}", us,
             f"loss={hist[-1].loss:.4f};fid={fid:.2f}")

        res = run_flat_fl("fedavg", SMOKE_UNET, fl, clients, rounds=rounds)
        fid = fid_proxy(real, sample_images(res.params, SMOKE_UNET,
                                            n=96, steps=10))
        emit(f"table5/fedavg_n{n}", us,
             f"loss={res.history[-1]['loss']:.4f};fid={fid:.2f}")


if __name__ == "__main__":
    main()
