"""Paper Table V (micro): scalability in the number of clients.

FedPhD vs FedAvg at N = 6 and N = 12 clients (scaled-down analogue of the
paper's 20/50/100); reports final-round training loss and proxy-FID.
Both methods run as points of one spec grid through
``repro.experiment.run_spec``.
"""
from __future__ import annotations

import dataclasses
import time

from benchmarks.common import emit, sample_images, smoke_spec
from repro.experiment import run_spec
from repro.metrics import fid_proxy


def main(rounds: int = 4) -> None:
    for n in (6, 12):
        base = smoke_spec(rounds=rounds, num_clients=n)
        real = None
        for method in ("fedphd", "fedavg"):
            spec = dataclasses.replace(base, method=method,
                                       name=f"table5-{method}-n{n}",
                                       prune=False)
            t0 = time.perf_counter()
            exp = run_spec(spec)
            us = (time.perf_counter() - t0) * 1e6 / rounds
            if real is None:
                real = exp.images[:256]
            fid = fid_proxy(real, sample_images(exp.params, exp.cfg,
                                                n=96, steps=10))
            emit(f"table5/{method}_n{n}", us,
                 f"loss={exp.history[-1].loss:.4f};fid={fid:.2f}")


if __name__ == "__main__":
    main()
