"""Benchmark regression gate: fresh medians vs committed baselines.

The engine benches (``round_engine_bench.py``, ``baseline_engine_bench
.py``) dump their per-row medians as ``BENCH_<name>.json`` into
``$BENCH_OUT_DIR``; this gate compares them against the committed
baselines in ``benchmarks/baselines/`` and fails CI on a regression:

- **latency rows**: fail when ``fresh_us > baseline_us * tolerance``
  (default 3.0 — the 2-core CI box's run-to-run medians swing ~2x, so
  only a real regression like per-batch dispatch creeping back into the
  round hot path clears 3x);
- **speedup rows** (a ``speedup=<x>x`` tag in the derived column): fail
  when the fresh speedup drops under ``baseline / speedup_tolerance``
  (default 3.0 — the round-engine speedup has been observed anywhere in
  3.4-17.5x on that box); the in-bench absolute floors (>= 2x) still
  apply first.  ``overlap=..x`` tags are informational (pinned ~1.0 on
  the shared-core CI box by construction) and are not gated;
- **bytes rows** (a ``bytes=<n>`` tag, emitted via
  ``common.emit_bytes`` with ``us=0``): byte accounting is
  deterministic, so the gate fails on ANY fresh count above the
  baseline (and on a dropped tag).  Zero-latency rows skip the
  latency check;
- **recompile rows** (a ``recompiles=<n>`` tag from an obs-traced
  bench leg): jit-cache growth beyond the declared compile boundaries
  is deterministic — "zero steady-state recompiles" is a ROADMAP
  invariant — so, like bytes, ANY increase over the baseline (or a
  dropped tag) fails the gate.

Updating a baseline is an explicit, reviewed act: copy the fresh
``BENCH_*.json`` over ``benchmarks/baselines/`` and append the new
medians to ``benchmarks/baselines/trajectory.json`` (the per-PR bench
trajectory) in the same commit as the change that moved them.

To make that act cheap, the gate AUTO-DRAFTS the trajectory entry:
when any matched median moves more than ``--draft-threshold`` (25%
either way — far inside the 3x failure tolerance), it prints the
per-row diff and writes the fully-formed proposed entry to
``<fresh>/trajectory_draft.json``.  CI uploads the fresh-medians dir as
an artifact, so the draft rides along; review it, fill in ``pr``/
``note``, and append it to ``trajectory.json``.  Drafting never fails
the gate.

Usage::

    BENCH_OUT_DIR=out/bench python benchmarks/round_engine_bench.py
    BENCH_OUT_DIR=out/bench python benchmarks/baseline_engine_bench.py
    python benchmarks/regression_gate.py --fresh out/bench
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys
from typing import Dict, List, Optional

TOLERANCE = 3.0
SPEEDUP_TOLERANCE = 3.0
# bytes-on-wire rows are deterministic (host-computed from static
# shapes) — any fresh byte count above the baseline is a regression
BYTES_TOLERANCE = 1.0
DRAFT_THRESHOLD = 0.25

_SPEEDUP = re.compile(r"(?:^|;)speedup=([0-9.]+)x")
_BYTES = re.compile(r"(?:^|;)bytes=([0-9]+)")
_RECOMPILES = re.compile(r"(?:^|;)recompiles=([0-9]+)")


def _load(path: str) -> Dict[str, dict]:
    with open(path) as f:
        return json.load(f)["rows"]


def _speedup(row: dict) -> Optional[float]:
    m = _SPEEDUP.search(row.get("derived", ""))
    return float(m.group(1)) if m else None


def _bytes(row: dict) -> Optional[int]:
    m = _BYTES.search(row.get("derived", ""))
    return int(m.group(1)) if m else None


def _recompiles(row: dict) -> Optional[int]:
    m = _RECOMPILES.search(row.get("derived", ""))
    return int(m.group(1)) if m else None


def compare(baseline: Dict[str, dict], fresh: Dict[str, dict], *,
            tolerance: float = TOLERANCE,
            speedup_tolerance: float = SPEEDUP_TOLERANCE
            ) -> List[str]:
    """Failure messages for every baseline row the fresh run regressed
    on (or dropped — renamed rows must update the baseline file)."""
    failures = []
    for name, base in sorted(baseline.items()):
        if name not in fresh:
            failures.append(f"{name}: missing from the fresh run "
                            "(renamed/dropped rows must update the "
                            "committed baseline)")
            continue
        row = fresh[name]
        limit = base["us"] * tolerance
        verdict = "ok"
        # bytes-only rows carry us=0 — no latency to gate
        if base["us"] > 0 and row["us"] > limit:
            verdict = "REGRESSED"
            failures.append(f"{name}: {row['us']:.0f}us > "
                            f"{limit:.0f}us (baseline {base['us']:.0f}us "
                            f"* {tolerance}x)")
        b_sp, f_sp = _speedup(base), _speedup(row)
        if b_sp is not None and f_sp is not None \
                and f_sp < b_sp / speedup_tolerance:
            verdict = "REGRESSED"
            failures.append(f"{name}: speedup {f_sp:.2f}x < "
                            f"{b_sp:.2f}x / {speedup_tolerance}")
        b_by, f_by = _bytes(base), _bytes(row)
        if b_by is not None:
            if f_by is None:
                verdict = "REGRESSED"
                failures.append(f"{name}: baseline carries bytes={b_by} "
                                "but the fresh row has no bytes= tag")
            elif f_by > b_by * BYTES_TOLERANCE:
                verdict = "REGRESSED"
                failures.append(f"{name}: bytes {f_by} > baseline {b_by} "
                                "(byte accounting is deterministic — any "
                                "increase is a regression)")
        b_rc, f_rc = _recompiles(base), _recompiles(row)
        if b_rc is not None:
            if f_rc is None:
                verdict = "REGRESSED"
                failures.append(f"{name}: baseline carries "
                                f"recompiles={b_rc} but the fresh row "
                                "has no recompiles= tag")
            elif f_rc > b_rc:
                verdict = "REGRESSED"
                failures.append(f"{name}: recompiles {f_rc} > baseline "
                                f"{b_rc} (compile counting is "
                                "deterministic — any unexpected jit-cache "
                                "growth is a regression)")
        print(f"  {verdict:>9}  {name}: {row['us']:.0f}us "
              f"(baseline {base['us']:.0f}us)"
              + (f" speedup {f_sp:.2f}x (baseline {b_sp:.2f}x)"
                 if b_sp is not None and f_sp is not None else "")
              + (f" bytes {f_by} (baseline {b_by})"
                 if b_by is not None and f_by is not None else "")
              + (f" recompiles {f_rc} (baseline {b_rc})"
                 if b_rc is not None and f_rc is not None else ""))
    return failures


def trajectory_rows(fresh: Dict[str, dict]) -> Dict[str, float]:
    """Flatten fresh bench rows into trajectory.json's row schema:
    ``<row>_us`` per latency, ``<bench...>/speedup`` per tagged row."""
    rows: Dict[str, float] = {}
    for name, row in sorted(fresh.items()):
        if row["us"] > 0:               # bytes-only rows have no latency
            rows[f"{name}_us"] = float(row["us"])
        sp = _speedup(row)
        if sp is not None:
            rows[name.rsplit("/", 1)[0] + "/speedup"] = sp
        by = _bytes(row)
        if by is not None:
            rows[f"{name}/bytes"] = float(by)
        rc = _recompiles(row)
        if rc is not None:
            rows[f"{name}/recompiles"] = float(rc)
    return rows


def maybe_draft(baseline: Dict[str, dict], fresh: Dict[str, dict],
                out_dir: str, threshold: float = DRAFT_THRESHOLD
                ) -> Optional[str]:
    """Compare matched medians; when any moved more than ``threshold``
    (relative, either direction), print the diff and write a proposed
    trajectory entry to ``<out_dir>/trajectory_draft.json``.  Returns
    the draft path, or None when nothing moved enough."""
    base_rows = trajectory_rows(baseline)
    fresh_rows = trajectory_rows(fresh)
    moved = []
    for key in sorted(base_rows):
        if key not in fresh_rows or base_rows[key] == 0:
            continue
        pct = (fresh_rows[key] - base_rows[key]) / base_rows[key]
        if abs(pct) > threshold:
            moved.append((key, base_rows[key], fresh_rows[key], pct))
    if not moved:
        return None

    print(f"\nmedians moved > {threshold:.0%} vs the committed "
          "baselines (NOT a gate failure — propose a trajectory "
          "update):")
    for key, b, f, pct in moved:
        print(f"  {key}: {b:g} -> {f:g} ({pct:+.0%})")
    import datetime
    draft = {
        "pr": None,
        "date": datetime.date.today().isoformat(),
        "note": "AUTO-DRAFT by regression_gate.py: fresh medians moved "
                f"past the {threshold:.0%} draft threshold. Review, fill "
                "in pr/note, and append to "
                "benchmarks/baselines/trajectory.json in the commit "
                "that moved them.",
        "rows": fresh_rows,
    }
    path = os.path.join(out_dir, "trajectory_draft.json")
    with open(path, "w") as fh:
        json.dump(draft, fh, indent=2)
        fh.write("\n")
    print(f"proposed trajectory entry -> {path}")
    return path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fresh", default="out/bench",
                    help="dir with this run's BENCH_*.json "
                         "(written via $BENCH_OUT_DIR)")
    ap.add_argument("--baseline",
                    default=os.path.join(os.path.dirname(__file__),
                                         "baselines"),
                    help="dir with the committed BENCH_*.json baselines")
    ap.add_argument("--tolerance", type=float, default=TOLERANCE)
    ap.add_argument("--speedup-tolerance", type=float,
                    default=SPEEDUP_TOLERANCE)
    ap.add_argument("--draft-threshold", type=float,
                    default=DRAFT_THRESHOLD,
                    help="relative median move (either direction) that "
                         "triggers a proposed trajectory.json entry in "
                         "the fresh dir (never fails the gate)")
    args = ap.parse_args(argv)

    baseline_files = sorted(glob.glob(os.path.join(args.baseline,
                                                   "BENCH_*.json")))
    if not baseline_files:
        print(f"no committed baselines under {args.baseline}",
              file=sys.stderr)
        return 2
    failures = []
    all_base: Dict[str, dict] = {}
    all_fresh: Dict[str, dict] = {}
    for bpath in baseline_files:
        fname = os.path.basename(bpath)
        fpath = os.path.join(args.fresh, fname)
        print(f"{fname}:")
        if not os.path.exists(fpath):
            failures.append(f"{fname}: no fresh medians at {fpath} "
                            "(did the bench run with $BENCH_OUT_DIR?)")
            print(f"  MISSING  {fpath}")
            continue
        base, fresh = _load(bpath), _load(fpath)
        all_base.update(base)
        all_fresh.update(fresh)
        failures += compare(base, fresh,
                            tolerance=args.tolerance,
                            speedup_tolerance=args.speedup_tolerance)
    maybe_draft(all_base, all_fresh, args.fresh,
                threshold=args.draft_threshold)
    if failures:
        print("\nBENCH REGRESSION GATE FAILED:", file=sys.stderr)
        for msg in failures:
            print(f"  - {msg}", file=sys.stderr)
        return 1
    print("\nbench regression gate: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
