"""§Roofline report.

Two parts:

- **backend x precision GEMM roofline**: lower the repo's masked-GEMM
  workhorse (``repro.models.ops.masked_matmul`` at the serve-bench tile
  shape) per compute backend (xla, pallas) and precision (fp32, bf16),
  run ``repro.roofline.analyze_hlo`` over the compiled HLO for the
  *predicted* FLOPs / HBM bytes / arithmetic intensity, and time the
  call for the *measured* wall-clock and achieved FLOP/s.  Rows emit as
  ``roofline/<backend>/<precision>`` with the predicted-vs-measured
  numbers in the derived column and land in ``BENCH_roofline.json``
  (``dump_bench_json``) — uploaded as a CI artifact, NOT committed as a
  baseline: wall-clock on the shared CI box is too noisy to gate, the
  value is the trend across PRs.
- **dry-run render**: the original per-(arch x shape) three-term table
  from the launch dry-run JSON, when present.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import dump_bench_json, emit, time_fn

# serve-bench tile shape: M=128 rows against a 1024x1024 weight — every
# dimension 128-aligned so the pallas grid has no masked remainder
M, K, N = 128, 1024, 1024
PRUNE_KEEP = 0.5


def _gemm_case(backend: str, precision: str):
    """(jitted fn, args) for one backend x precision point."""
    from repro.models.ops import compute_dtype, masked_matmul

    dt = compute_dtype(precision)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((K, N)), dt)
    col = jnp.asarray(np.arange(N) < int(N * PRUNE_KEEP), jnp.float32)

    def f(x, w, col):
        return masked_matmul(x, w, col_mask=col, backend=backend)

    return jax.jit(f), (x, w, col)


def gemm_roofline() -> None:
    from repro.roofline.analysis import analyze_hlo

    for backend in ("xla", "pallas"):
        for precision in ("fp32", "bf16"):
            fn, args = _gemm_case(backend, precision)
            compiled = fn.lower(*args).compile()
            terms = analyze_hlo(compiled.as_text())
            us = time_fn(lambda: jax.block_until_ready(fn(*args)),
                         warmup=2, iters=5)
            pred_ai = terms.flops / max(terms.hbm_bytes, 1.0)
            achieved = terms.flops / max(us * 1e-6, 1e-12)
            emit(f"roofline/{backend}/{precision}", us,
                 f"M={M};K={K};N={N};keep={PRUNE_KEEP};"
                 f"pred_flops={terms.flops:.3g};"
                 f"pred_hbm_bytes={terms.hbm_bytes:.3g};"
                 f"pred_intensity={pred_ai:.2f};"
                 f"achieved_gflops={achieved / 1e9:.2f}")


def render(path: str = "results_dryrun_single_pod.json") -> None:
    if not os.path.exists(path):
        emit("roofline/missing", 0.0, f"run dryrun --all --out {path}")
        return
    with open(path) as f:
        records = json.load(f)
    for r in records:
        rf = r["roofline"]
        emit(f"roofline/{r['arch']}/{r['shape']}",
             max(rf["compute_s"], rf["memory_s"], rf["collective_s"]) * 1e6,
             f"dominant={rf['dominant']};compute_ms={rf['compute_s']*1e3:.2f};"
             f"memory_ms={rf['memory_s']*1e3:.2f};"
             f"collective_ms={rf['collective_s']*1e3:.2f};"
             f"useful={r['useful_flops_ratio'] if r['useful_flops_ratio'] is None else round(r['useful_flops_ratio'],3)}")


def main() -> None:
    gemm_roofline()
    render()
    # artifact only — no committed baseline (the gate only reads names
    # present under benchmarks/baselines/, so this file rides the CI
    # artifact without being gated)
    dump_bench_json("roofline")


if __name__ == "__main__":
    main()
