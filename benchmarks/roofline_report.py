"""§Roofline report: render the dry-run JSON into the per-(arch x shape)
three-term table (also emitted as benchmark rows)."""
from __future__ import annotations

import json
import os

from benchmarks.common import emit


def render(path: str = "results_dryrun_single_pod.json") -> None:
    if not os.path.exists(path):
        emit("roofline/missing", 0.0, f"run dryrun --all --out {path}")
        return
    with open(path) as f:
        records = json.load(f)
    for r in records:
        rf = r["roofline"]
        emit(f"roofline/{r['arch']}/{r['shape']}",
             max(rf["compute_s"], rf["memory_s"], rf["collective_s"]) * 1e6,
             f"dominant={rf['dominant']};compute_ms={rf['compute_s']*1e3:.2f};"
             f"memory_ms={rf['memory_s']*1e3:.2f};"
             f"collective_ms={rf['collective_s']*1e3:.2f};"
             f"useful={r['useful_flops_ratio'] if r['useful_flops_ratio'] is None else round(r['useful_flops_ratio'],3)}")


def main() -> None:
    render()


if __name__ == "__main__":
    main()
