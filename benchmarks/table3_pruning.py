"""Paper Table III: pruning-ratio sweep — #Params / MACs / quality-loss.

Params and MACs are exact (they reproduce the paper's accounting: at the
paper's full 35.7M U-Net the 44% row gives 20.3M params / 3.42G MACs);
quality here is the DDPM loss delta at smoke scale.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, time_fn
from repro.configs import CIFAR10_UNET, SMOKE_UNET
from repro.configs.base import InputShape
from repro.core import pruning as P
from repro.metrics.flops import unet_macs
from repro.models import model


def main() -> None:
    rng = jax.random.PRNGKey(0)
    # exact accounting on the paper's FULL 35.7M U-Net (init on CPU is fine)
    full_params = model.init(rng, CIFAR10_UNET)
    n_dense = sum(x.size for x in jax.tree.leaves(full_params))
    macs_dense = unet_macs(full_params, 32)
    emit("table3/ratio_0", 0.0,
         f"params_m={n_dense/1e6:.1f};macs_g={macs_dense/1e9:.2f}")

    groups = P.build_groups(CIFAR10_UNET, full_params)
    scores = P.l2_scores(full_params, groups)
    for ratio in (0.25, 0.44, 0.61, 0.74):
        masks = P.make_masks(scores, groups, ratio)
        pruned, cfg2, _ = P.compact(full_params, CIFAR10_UNET, groups, masks)
        n = sum(x.size for x in jax.tree.leaves(pruned))
        macs = unet_macs(pruned, 32)
        macs64 = unet_macs(pruned, 64)
        emit(f"table3/ratio_{int(ratio*100)}", 0.0,
             f"params_m={n/1e6:.1f};macs_g={macs/1e9:.2f};"
             f"macs_celeba_g={macs64/1e9:.2f}")

    # quality at smoke scale: loss of a briefly-trained dense vs 44%-pruned
    smoke = SMOKE_UNET
    sp = model.init(rng, smoke)
    batch = model.make_inputs(rng, smoke, InputShape("t", 0, 16, "train"))
    g2 = P.build_groups(smoke, sp)
    m2 = P.make_masks(P.l2_scores(sp, g2), g2, 0.44)
    pp, pcfg, _ = P.compact(sp, smoke, g2, m2)
    l_dense = float(model.loss_fn(sp, smoke, batch, rng))
    l_pruned = float(model.loss_fn(pp, pcfg, batch, rng))
    us = time_fn(lambda: model.loss_fn(pp, pcfg, batch, rng))
    emit("table3/quality_44", us,
         f"loss_dense={l_dense:.4f};loss_pruned={l_pruned:.4f}")


if __name__ == "__main__":
    main()
