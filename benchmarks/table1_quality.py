"""Paper Table I (micro): FID/IS quality of FedPhD vs baselines.

Reduced scale (smoke U-Net, synthetic 4-class data, few rounds, 10-step
DDIM, proxy-FID) — the paper's ordering claims, not its absolute values.

The whole table is ONE ``SweepSpec`` over the ``method`` axis through
``repro.experiment.sweep``: every row (hierarchical FedPhD variants and
flat baselines alike) runs via the sweep executor into a manifest, the
per-row FID/IS land through the unified ``eval_fn`` hook at the final
round, and the emitted numbers come out of ``sweep.report``'s
aggregation (one seed here, so mean == the value).  Output schema is
unchanged: ``table1/<method>,us_per_round,fid=..;is=..;params_m=..``.
"""
from __future__ import annotations

from benchmarks.common import (emit, run_sweep_timed_eval, sample_images,
                               smoke_spec)
from repro.data import make_dataset
from repro.experiment import SweepSpec, dataset_spec
from repro.metrics import fid_proxy, inception_score_proxy

METHODS = ("fedphd", "fedphd-os", "fedavg", "fedprox", "moon", "scaffold",
           "feddiffuse")


def main(rounds: int = 6) -> None:
    # eval_every=rounds: the hook fires exactly once, at the final round
    base = smoke_spec(rounds=rounds).replace(name="table1",
                                             eval_every=rounds)
    sweep = SweepSpec(name="table1", base=base,
                      axes={"method": list(METHODS)},
                      group_by=("method",))
    # the FID reference: the spec's own dataset at the spec's seed
    # (identical to what make_clients partitions across clients)
    images, _ = make_dataset(dataset_spec(base.data.dataset),
                             seed=base.seed)
    real = images[:256]

    def eval_fn(params, cfg, r):
        fake = sample_images(params, cfg, n=128, steps=10)
        return {"fid": float(fid_proxy(real, fake)),
                "is": float(inception_score_proxy(fake))}

    _, report, train_s = run_sweep_timed_eval(sweep, eval_fn)
    by_method = {g["key"]["method"]: g for g in report["groups"]}
    for method in METHODS:
        g = by_method[method]
        m = g["metrics"]
        (rid,) = g["runs"]
        emit(f"table1/{method.replace('-', '_')}",
             train_s[rid] * 1e6 / rounds,
             f"fid={m['eval.fid']['mean']:.2f};"
             f"is={m['eval.is']['mean']:.3f};"
             f"params_m={m['params_m']['mean']:.3f}")


if __name__ == "__main__":
    main()
