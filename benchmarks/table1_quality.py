"""Paper Table I (micro): FID/IS quality of FedPhD vs baselines.

Reduced scale (smoke U-Net, synthetic 4-class data, few rounds, 10-step
DDIM, proxy-FID) — the paper's ordering claims, not its absolute values.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import (emit, sample_images, smoke_clients, smoke_fl,
                               time_fn)
from repro.configs import SMOKE_UNET
from repro.core.hfl import FedPhD
from repro.fl.baselines import run_flat_fl
from repro.metrics import fid_proxy, inception_score_proxy


def main(rounds: int = 6) -> None:
    clients, images, labels = smoke_clients()
    fl = smoke_fl(rounds=rounds)
    real = images[:256]

    def evaluate(params, cfg, tag):
        fake = sample_images(params, cfg, n=128, steps=10)
        fid = fid_proxy(real, fake)
        is_ = inception_score_proxy(fake)
        return fid, is_

    # FedPhD
    t0 = time.perf_counter()
    trainer = FedPhD(SMOKE_UNET, fl, clients, rng_seed=0)
    trainer.run(rounds)
    dt = (time.perf_counter() - t0) * 1e6 / rounds
    fid, is_ = evaluate(trainer.params, trainer.cfg, "fedphd")
    emit("table1/fedphd", dt, f"fid={fid:.2f};is={is_:.3f};"
         f"params_m={trainer.history[-1].params_m:.3f}")

    # FedPhD-OS
    import dataclasses
    trainer = FedPhD(SMOKE_UNET, dataclasses.replace(
        fl, prune_mode="oneshot_l2"), clients, rng_seed=0)
    trainer.run(rounds)
    fid, is_ = evaluate(trainer.params, trainer.cfg, "fedphd-os")
    emit("table1/fedphd_os", dt, f"fid={fid:.2f};is={is_:.3f}")

    for method in ("fedavg", "fedprox", "moon", "scaffold", "feddiffuse"):
        t0 = time.perf_counter()
        res = run_flat_fl(method, SMOKE_UNET, fl, clients, rounds=rounds)
        dt = (time.perf_counter() - t0) * 1e6 / rounds
        fid, is_ = evaluate(res.params, SMOKE_UNET, method)
        emit(f"table1/{method}", dt, f"fid={fid:.2f};is={is_:.3f}")


if __name__ == "__main__":
    main()
