"""Paper Table I (micro): FID/IS quality of FedPhD vs baselines.

Reduced scale (smoke U-Net, synthetic 4-class data, few rounds, 10-step
DDIM, proxy-FID) — the paper's ordering claims, not its absolute values.

The whole table is ONE spec grid over ``method`` through the unified
experiment API: every row (hierarchical FedPhD variants and flat
baselines alike) runs via ``repro.experiment.run_spec`` and reports from
the same RoundRecord history schema.
"""
from __future__ import annotations

import time

from benchmarks.common import emit, sample_images, smoke_spec
from repro.experiment import run_spec
from repro.metrics import fid_proxy, inception_score_proxy

METHODS = ("fedphd", "fedphd-os", "fedavg", "fedprox", "moon", "scaffold",
           "feddiffuse")


def main(rounds: int = 6) -> None:
    real = None
    for method in METHODS:
        spec = smoke_spec(method, rounds=rounds)
        t0 = time.perf_counter()
        exp = run_spec(spec)
        dt = (time.perf_counter() - t0) * 1e6 / rounds
        if real is None:
            real = exp.images[:256]
        fake = sample_images(exp.params, exp.cfg, n=128, steps=10)
        fid = fid_proxy(real, fake)
        is_ = inception_score_proxy(fake)
        tag = method.replace("-", "_")
        emit(f"table1/{tag}", dt,
             f"fid={fid:.2f};is={is_:.3f};"
             f"params_m={exp.history[-1].params_m:.3f}")


if __name__ == "__main__":
    main()
