"""Flat-baseline round latency: sequential reference vs vectorized
engine (repro/fl/baselines.py FlatTrainer) for the two ctx-heavy
methods — FedProx (anchor-params prox term) and SCAFFOLD (control
variates + device-side c_i+ update) — on the acceptance config:
8 clients, CPU, dispatch-bound micro U-Net.

Same protocol as round_engine_bench: per-method trainers are stepped
round-by-round with the two engines interleaved, and medians compared,
so the ratio is robust to background CPU-throughput drift.  The flat
vectorized path runs the whole round (vmap clients x scan steps, fused
FedAvg einsum, SCAFFOLD delta mean on device) as ONE jitted program
with a single loss sync; the sequential path pays a jitted-call
dispatch + float(loss) host sync per batch and per-leaf Python
aggregation per round.  Expected speedup >= 2x (acceptance floor);
typically ~8-11x on the 2-core CI box.

Note the flat engines compile with unroll=1 (bit-stability with the
sequential reference — see fl/baselines.py), so this bench also guards
the scan-carried step cost on XLA:CPU.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import dump_bench_json, emit
from repro.configs import SMOKE_UNET
from repro.configs.base import FLConfig
from repro.data import ClientData, shards_per_client
from repro.data.synthetic import DatasetSpec, make_dataset
from repro.fl.baselines import FlatTrainer
from repro.fl.client import Client

NUM_CLIENTS = 8
BATCH = 1
TIMED_ROUNDS = 4
METHODS = ("fedprox", "scaffold")

MICRO_UNET = SMOKE_UNET.replace(name="ddpm-unet-micro", image_size=4,
                                base_channels=8, channel_mults=(1,),
                                num_res_blocks=1, attn_resolutions=())
MICRO_DATA = DatasetSpec("bench-micro", num_classes=4, image_size=4,
                         samples_per_class=64)


def _clients(seed: int = 0):
    images, labels = make_dataset(MICRO_DATA, seed=seed)
    parts = shards_per_client(labels, num_clients=NUM_CLIENTS,
                              classes_per_client=1, seed=seed)
    return [Client(i, ClientData(images[p], labels[p], batch_size=BATCH,
                                 seed=i), MICRO_DATA.num_classes)
            for i, p in enumerate(parts)]


def _fl() -> FLConfig:
    return FLConfig(num_clients=NUM_CLIENTS, num_edges=1, local_epochs=2,
                    edge_agg_every=1, cloud_agg_every=10 ** 6,
                    rounds=2 * TIMED_ROUNDS + 2, sh_a=1000.0)


def main() -> None:
    for method in METHODS:
        seq = FlatTrainer(method, MICRO_UNET, _fl(), _clients(),
                          rng_seed=0, engine="sequential")
        vec = FlatTrainer(method, MICRO_UNET, _fl(), _clients(),
                          rng_seed=0, engine="vectorized")
        seq.run_round(1)                   # warmup: jit compile
        vec.run_round(1)

        t_seq, t_vec = [], []
        r = 2
        for _ in range(TIMED_ROUNDS):      # interleave against CPU drift
            t0 = time.perf_counter()
            seq.run_round(r)
            t_seq.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            vec.run_round(r + 1)
            t_vec.append(time.perf_counter() - t0)
            r += 2

        us_seq = float(np.median(t_seq)) * 1e6
        us_vec = float(np.median(t_vec)) * 1e6
        speedup = us_seq / max(us_vec, 1e-9)
        shape = f"C={NUM_CLIENTS};B={BATCH}"
        emit(f"baseline_engine/{method}/sequential", us_seq, shape)
        emit(f"baseline_engine/{method}/vectorized", us_vec,
             f"{shape};speedup={speedup:.2f}x")

    # medians -> $BENCH_OUT_DIR/BENCH_baselines.json for the CI
    # regression gate (benchmarks/regression_gate.py)
    dump_bench_json("baselines")


if __name__ == "__main__":
    main()
