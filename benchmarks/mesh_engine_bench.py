"""Mesh-sharded round A/B: the vectorized engine with the client axis
laid over an 8-device ``{"data": 8, "model": 1}`` mesh vs the same
engine unsharded, identical config and numerics (asserted in-child to
atol 1e-5).

XLA reads ``--xla_force_host_platform_device_count`` once, at backend
init, so the A/B runs in a subprocess under ``repro.launch.env
.child_env(8)`` (the same pattern as tests/test_mesh_engine.py); the
child prints the timing rows and this wrapper re-emits them into the
harness CSV / $BENCH_OUT_DIR medians.

Reading the rows: ``ratio=<x>x`` on the sharded row is sharded-over-
unsharded wall-clock and is INFORMATIONAL — on a shared-core CI box
eight fake devices time-slice the same cores and the shard_map's
collective permutes are pure overhead, so the ratio sits below 1.0 by
construction.  The row exists to pin the sharded path's latency (the
3x latency tolerance still gates it) and to report real scaling on
accelerator-backed meshes, where the client axis buys wall-clock.
"""
from __future__ import annotations

import subprocess
import sys

from benchmarks.common import dump_bench_json, emit
from repro.launch import env as launch_env

DEVICES = 8

_CHILD = r"""
from repro.launch import env
env.apply({devices})                  # before the first jax backend init

import time
import jax
import numpy as np
assert len(jax.devices()) == {devices}, jax.devices()

from repro.configs import SMOKE_UNET
from repro.configs.base import FLConfig
from repro.core.hfl import FedPhD
from repro.data import ClientData, shards_per_client
from repro.data.synthetic import DatasetSpec, make_dataset
from repro.fl.client import Client
from repro.launch.mesh import make_spec_mesh

NUM_CLIENTS = {devices}
NUM_EDGES = 2
BATCH = 1
TIMED_ROUNDS = 3

MICRO_UNET = SMOKE_UNET.replace(name='ddpm-unet-micro-mesh', image_size=4,
                                base_channels=8, channel_mults=(1,),
                                num_res_blocks=1, attn_resolutions=())
MICRO_DATA = DatasetSpec('bench-micro-mesh', num_classes=4, image_size=4,
                         samples_per_class=64)


def clients(seed=0):
    images, labels = make_dataset(MICRO_DATA, seed=seed)
    parts = shards_per_client(labels, num_clients=NUM_CLIENTS,
                              classes_per_client=1, seed=seed)
    return [Client(i, ClientData(images[p], labels[p], batch_size=BATCH,
                                 seed=i), MICRO_DATA.num_classes)
            for i, p in enumerate(parts)]


def fl():
    return FLConfig(num_clients=NUM_CLIENTS, num_edges=NUM_EDGES,
                    local_epochs=2, edge_agg_every=1,
                    cloud_agg_every=10 ** 6,
                    rounds=2 * TIMED_ROUNDS + 2, sh_a=1000.0)


mesh = make_spec_mesh({{'data': {devices}, 'model': 1}})
plain = FedPhD(MICRO_UNET, fl(), clients(), rng_seed=0,
               engine='vectorized', prune=False)
shard = FedPhD(MICRO_UNET, fl(), clients(), rng_seed=0,
               engine='vectorized', prune=False, mesh=mesh)
plain.run_round(1)                    # warmup: jit compile
shard.run_round(1)

t_plain, t_shard = [], []
r = 2
for _ in range(TIMED_ROUNDS):         # interleave against CPU drift
    t0 = time.perf_counter()
    plain.run_round(r)
    t_plain.append(time.perf_counter() - t0)
    t0 = time.perf_counter()
    shard.run_round(r + 1)
    t_shard.append(time.perf_counter() - t0)
    r += 2

# the A/B is only meaningful if the two paths agree numerically
for a, b in zip(plain.history, shard.history):
    assert abs(a.loss - b.loss) < 1e-5, (a.round, a.loss, b.loss)
    assert a.comm_gb == b.comm_gb
for x, y in zip(jax.tree.leaves(plain.params),
                jax.tree.leaves(shard.params)):
    np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-5)

us_plain = float(np.median(t_plain)) * 1e6
us_shard = float(np.median(t_shard)) * 1e6
ratio = us_plain / max(us_shard, 1e-9)
shape = f'C={{NUM_CLIENTS}};E={{NUM_EDGES}};B={{BATCH}};devices={devices}'
print(f'ROW mesh_engine/unsharded/round,{{us_plain:.1f}},{{shape}}')
print(f'ROW mesh_engine/sharded/round,{{us_shard:.1f}},'
      f'{{shape}};ratio={{ratio:.2f}}x')
"""


def main() -> None:
    script = _CHILD.format(devices=DEVICES)
    res = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=1800,
                         env=launch_env.child_env(DEVICES))
    if res.returncode != 0:
        raise RuntimeError("mesh_engine A/B child failed:\n"
                           + res.stdout + res.stderr)
    rows = [ln[len("ROW "):] for ln in res.stdout.splitlines()
            if ln.startswith("ROW ")]
    assert len(rows) == 2, res.stdout
    for row in rows:
        name, us, derived = row.split(",", 2)
        emit(name, float(us), derived)
    dump_bench_json("mesh_engine")


if __name__ == "__main__":
    main()
