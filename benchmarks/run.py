"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks.common.emit).
Scale note: quality benchmarks run reduced configs on CPU (synthetic
data + proxy-FID — DESIGN.md §1); the params/MACs/comm accounting runs
at FULL paper scale and reproduces Tables III/IV exactly.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module names (e.g. table4,fig1)")
    ap.add_argument("--skip-slow", action="store_true",
                    help="skip the FL-training quality tables")
    args = ap.parse_args()

    from benchmarks import (baseline_engine_bench, fig1_divergence,
                            fig5_selection, kernels_bench, mesh_engine_bench,
                            roofline_report, round_engine_bench, serve_bench,
                            table1_quality, table3_pruning, table4_efficiency,
                            table5_scalability)

    modules = {
        "table4": table4_efficiency,    # fast, exact accounting first
        "table3": table3_pruning,
        "fig5": fig5_selection,
        "kernels": kernels_bench,
        "round_engine": round_engine_bench,
        "baseline_engine": baseline_engine_bench,
        "mesh_engine": mesh_engine_bench,   # subprocess: 8 fake devices
        "serve": serve_bench,
        "roofline": roofline_report,
        "fig1": fig1_divergence,        # FL training (slow) last
        "table1": table1_quality,
        "table5": table5_scalability,
    }
    slow = {"fig1", "table1", "table5"}
    selected = (set(args.only.split(",")) if args.only else set(modules))
    if args.skip_slow:
        selected -= slow

    print("name,us_per_call,derived")
    failures = []
    for name, mod in modules.items():
        if name not in selected:
            continue
        try:
            mod.main()
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            failures.append(name)
    if failures:
        print(f"FAILED benchmarks: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
