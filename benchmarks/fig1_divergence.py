"""Paper Fig. 1 (micro): IID vs non-IID FedAvg divergence + the effect of
aggregation frequency (the motivation for HFL)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, smoke_clients, smoke_fl
from repro.configs import SMOKE_UNET
from repro.fl.baselines import FlatTrainer


def main(rounds: int = 4) -> None:
    fl = smoke_fl(rounds=rounds)

    for tag, iid_split in (("noniid", False), ("iid", True)):
        clients, images, _ = smoke_clients(iid_split=iid_split)
        t0 = time.perf_counter()
        res = FlatTrainer("fedavg", SMOKE_UNET, fl, clients, rng_seed=0)
        res.run(rounds)
        us = (time.perf_counter() - t0) * 1e6 / rounds
        losses = [h["loss"] for h in res.history]
        # the divergence shows up in sample quality (the paper's Fig. 1
        # metric), not in the partition-insensitive DDPM loss
        from benchmarks.common import sample_images
        from repro.metrics import fid_proxy
        fid = fid_proxy(images[:256],
                        sample_images(res.params, SMOKE_UNET, n=96, steps=10))
        emit(f"fig1/fedavg_{tag}", us,
             f"fid={fid:.2f};first={losses[0]:.4f};last={losses[-1]:.4f}")

    # aggregation frequency: E=2 local epochs vs E=1 (paper: E=5 vs 1)
    import dataclasses
    clients, _, _ = smoke_clients()
    for E in (1, 2):
        res = FlatTrainer("fedavg", SMOKE_UNET,
                          dataclasses.replace(fl, local_epochs=E), clients,
                          rng_seed=0)
        res.run(rounds)
        emit(f"fig1/fedavg_E{E}", 0.0,
             f"last={res.history[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
