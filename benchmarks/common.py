"""Shared benchmark utilities."""
from __future__ import annotations

import json
import os
import time
from typing import Callable, List, Optional

import numpy as np

from repro.configs import SMOKE_UNET
from repro.configs.base import FLConfig
from repro.data import SMOKE_DATA, ClientData, make_dataset, shards_per_client
# re-export: sampling moved into the library (repro.diffusion) so
# examples don't need the repo root on sys.path; benches keep importing
# it from here
from repro.diffusion import sample_images  # noqa: F401
from repro.experiment import DataSpec, ExperimentSpec
from repro.fl.client import Client

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def emit_bytes(name: str, nbytes: int, derived: str = "") -> None:
    """Emit a bytes-on-wire row: ``us`` is pinned to 0 (there is no
    latency to gate) and the byte count rides the derived column as a
    ``bytes=<n>`` tag, which ``regression_gate.py`` gates exactly —
    byte accounting is deterministic, so ANY increase over the
    committed baseline fails the gate."""
    tag = f"bytes={int(nbytes)}"
    emit(name, 0.0, f"{tag};{derived}" if derived else tag)


def dump_bench_json(bench: str) -> Optional[str]:
    """Persist every row emitted so far as ``BENCH_<bench>.json`` under
    ``$BENCH_OUT_DIR`` (no-op when unset) — the machine-readable medians
    ``benchmarks/regression_gate.py`` compares against the committed
    baselines in ``benchmarks/baselines/``.  Rows from other modules in
    the same process (``run.py`` runs several) are harmless: the gate
    only reads the names present in the committed baseline."""
    out_dir = os.environ.get("BENCH_OUT_DIR")
    if not out_dir:
        return None
    rows = {}
    for row in ROWS:
        name, us, derived = row.split(",", 2)
        rows[name] = {"us": float(us), "derived": derived}
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{bench}.json")
    with open(path, "w") as f:
        json.dump({"bench": bench, "rows": rows}, f, indent=2,
                  sort_keys=True)
        f.write("\n")
    print(f"[bench-json] wrote {path}", flush=True)
    return path


def run_sweep_timed_eval(sweep, eval_fn: Callable):
    """Run a table bench's sweep with a self-timed eval hook.

    Returns ``(manifest, report, train_s)`` where ``train_s[run_id]``
    is the run's wall-clock minus its eval cost — so emitted per-round
    latencies stay *training* numbers even though the FID/IS hook fires
    inside the timed run.  Holds the pairing invariant in ONE place:
    the sequential executor runs in manifest insertion order and each
    bench spec fires its eval exactly once (``eval_every = rounds``),
    asserted below.  ``save_every=0`` keeps per-round checkpoint I/O
    out of the timed window (each run's single final save remains —
    negligible next to the training rounds).
    """
    import tempfile
    import time as _time

    from repro.experiment import run_sweep, write_report

    eval_s: List[float] = []

    def timed(params, cfg, r):
        t0 = _time.perf_counter()
        out = eval_fn(params, cfg, r)
        eval_s.append(_time.perf_counter() - t0)
        return out

    with tempfile.TemporaryDirectory(prefix=f"{sweep.name}-sweep-") as out:
        res = run_sweep(sweep, out, eval_fn=timed, save_every=0,
                        raise_on_error=True)
        report = write_report(res.manifest, out)
    runs = res.manifest["runs"]
    assert len(eval_s) == len(runs), \
        f"eval fired {len(eval_s)}x for {len(runs)} runs — set " \
        "eval_every=rounds so the positional pairing below holds"
    train_s = {rid: entry["wall_s"] - cost
               for (rid, entry), cost in zip(runs.items(), eval_s)}
    return res.manifest, report, train_s


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(*args)
    return (time.perf_counter() - t0) / iters * 1e6


def smoke_clients(num_clients: int = 6, classes_per_client: int = 1,
                  iid_split: bool = False, seed: int = 0):
    images, labels = make_dataset(SMOKE_DATA, seed=seed)
    if iid_split:
        from repro.data import iid
        parts = iid(labels, num_clients, seed=seed)
    else:
        parts = shards_per_client(labels, num_clients, classes_per_client,
                                  seed=seed)
    return [Client(i, ClientData(images[p], labels[p], batch_size=32, seed=i),
                   SMOKE_DATA.num_classes) for i, p in enumerate(parts)], \
        images, labels


def smoke_fl(rounds: int = 4, **kw) -> FLConfig:
    base = dict(num_clients=6, num_edges=2, local_epochs=1, edge_agg_every=1,
                cloud_agg_every=2, rounds=rounds, sparse_rounds=2,
                prune_ratio=0.44, sh_a=1000.0)
    base.update(kw)
    return FLConfig(**base)


def smoke_spec(method: str = "fedphd", rounds: int = 4,
               **fl_kw) -> ExperimentSpec:
    """The table benches' smoke setup as a declarative spec — same data
    population as ``smoke_clients()`` (spec-built clients reproduce it
    field-for-field)."""
    return ExperimentSpec(
        name=f"smoke-{method}", method=method, model="ddpm-unet-smoke",
        fl=smoke_fl(rounds=rounds, **fl_kw),
        data=DataSpec(dataset="smoke", partition="shards",
                      classes_per_client=1, batch_size=32))
