"""Shared benchmark utilities."""
from __future__ import annotations

import time
from typing import Callable, List

import numpy as np

from repro.configs import SMOKE_UNET
from repro.configs.base import FLConfig
from repro.data import SMOKE_DATA, ClientData, make_dataset, shards_per_client
# re-export: sampling moved into the library (repro.diffusion) so
# examples don't need the repo root on sys.path; benches keep importing
# it from here
from repro.diffusion import sample_images  # noqa: F401
from repro.experiment import DataSpec, ExperimentSpec
from repro.fl.client import Client

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(*args)
    return (time.perf_counter() - t0) / iters * 1e6


def smoke_clients(num_clients: int = 6, classes_per_client: int = 1,
                  iid_split: bool = False, seed: int = 0):
    images, labels = make_dataset(SMOKE_DATA, seed=seed)
    if iid_split:
        from repro.data import iid
        parts = iid(labels, num_clients, seed=seed)
    else:
        parts = shards_per_client(labels, num_clients, classes_per_client,
                                  seed=seed)
    return [Client(i, ClientData(images[p], labels[p], batch_size=32, seed=i),
                   SMOKE_DATA.num_classes) for i, p in enumerate(parts)], \
        images, labels


def smoke_fl(rounds: int = 4, **kw) -> FLConfig:
    base = dict(num_clients=6, num_edges=2, local_epochs=1, edge_agg_every=1,
                cloud_agg_every=2, rounds=rounds, sparse_rounds=2,
                prune_ratio=0.44, sh_a=1000.0)
    base.update(kw)
    return FLConfig(**base)


def smoke_spec(method: str = "fedphd", rounds: int = 4,
               **fl_kw) -> ExperimentSpec:
    """The table benches' smoke setup as a declarative spec — same data
    population as ``smoke_clients()`` (spec-built clients reproduce it
    field-for-field)."""
    return ExperimentSpec(
        name=f"smoke-{method}", method=method, model="ddpm-unet-smoke",
        fl=smoke_fl(rounds=rounds, **fl_kw),
        data=DataSpec(dataset="smoke", partition="shards",
                      classes_per_client=1, batch_size=32))
