"""Paper Table IV: #Params / MACs / standardized communication cost.

The communication model is the paper's own (ShapeFL): C_ne = 0.002 d_e V,
C_ce = 0.02 d_c V.  With the full 35.7M U-Net (136.53 MB fp32) and the
44%-pruned 20.3M model (77.93 MB), the reproduced costs match Table IV.

The accounting is driven off the "paper" experiment spec (the same spec
``repro.experiment.runner --preset paper`` trains): model config, client
count, edge count, and central-aggregation period all come from the spec
rather than hand-copied constants.
"""
from __future__ import annotations

import jax

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import pruning as P
from repro.experiment.runner import PRESETS
from repro.fl.comm import CommModel
from repro.metrics.flops import unet_macs
from repro.models import model


def main() -> None:
    spec = PRESETS["paper"]
    cfg = get_config(spec.model)
    rng = jax.random.PRNGKey(spec.seed)
    params = model.init(rng, cfg)
    n = sum(x.size for x in jax.tree.leaves(params))
    macs = unet_macs(params, cfg.image_size)
    V = n * 4  # fp32 bytes (136.53 MB)

    cm = CommModel()
    # paper setup from the spec: N=20 clients, kappa selects all per
    # round; one central-aggregation period = r_g=5 rounds.
    C = spec.fl.num_clients
    Ne = spec.fl.num_edges
    r_g = spec.fl.cloud_agg_every

    def flat_cost(vol, mult=1.0):
        # baselines aggregate at the cloud every round; per central-
        # aggregation period = r_g rounds of 2*C cloud transfers
        return r_g * cm.flat_fl_round(vol, C) * mult / 1e9

    def hfl_cost(vol):
        # FedPhD: r_g edge rounds + one cloud round per period
        c = sum(cm.hfl_round(vol, C, Ne, cloud_round=(r == r_g))
                for r in range(1, r_g + 1))
        return c / 1e9

    emit("table4/fedavg", 0.0, f"params_m={n/1e6:.1f};macs_g={macs/1e9:.2f};"
         f"comm_gb={flat_cost(V):.2f}")
    emit("table4/fedavg_e1", 0.0, f"comm_gb={flat_cost(V)*5:.2f}")
    emit("table4/fedprox", 0.0, f"comm_gb={flat_cost(V):.2f}")
    emit("table4/feddiffuse", 0.0, f"comm_gb={flat_cost(V, 2/3):.2f}")
    emit("table4/moon", 0.0, f"comm_gb={flat_cost(V):.2f}")
    emit("table4/scaffold", 0.0, f"comm_gb={flat_cost(V, 2.0):.2f}")

    groups = P.build_groups(cfg, params)
    masks = P.make_masks(P.l2_scores(params, groups), groups,
                         spec.fl.prune_ratio)
    pruned, _, _ = P.compact(params, cfg, groups, masks)
    n_p = sum(x.size for x in jax.tree.leaves(pruned))
    macs_p = unet_macs(pruned, cfg.image_size)
    Vp = n_p * 4
    emit("table4/fedphd", 0.0,
         f"params_m={n_p/1e6:.1f};macs_g={macs_p/1e9:.2f};"
         f"comm_gb={hfl_cost(Vp):.2f}")
    ratio = hfl_cost(Vp) / (flat_cost(V) * 5)
    emit("table4/comm_reduction_vs_fedavg_e1", 0.0,
         f"reduction={1-ratio:.1%}")


if __name__ == "__main__":
    main()
