"""Paper Figs. 5-8 (micro): homogeneity-aware vs random edge selection —
final edge SH scores and client-assignment variance."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, smoke_clients, smoke_fl
from repro.configs import SMOKE_UNET
from repro.core.hfl import FedPhD
from repro.core.selection import selection_probabilities
from repro.core.sh_score import AccumulatedDistribution


def main(rounds: int = 3) -> None:
    # paper Fig. 5 worked example: 4 clients, 2 edges, a=15000, b=0
    e0 = AccumulatedDistribution(3)
    e0.update(np.array([1 / 3] * 3), 7500)
    e1 = AccumulatedDistribution(3)
    e1.update(np.array([0.2, 0.4, 0.4]), 2500)
    q_client = np.array([1.0, 0.0, 0.0])
    t0 = time.perf_counter()
    p = selection_probabilities([e0, e1], q_client, 2500, a=15000.0, b=0.0)
    us = (time.perf_counter() - t0) * 1e6
    emit("fig5/worked_example", us, f"p_edge0={p[0]:.3f};p_edge1={p[1]:.3f}")

    for tag, sel in (("sh", "sh"), ("random", "random")):
        clients, _, _ = smoke_clients(num_clients=8)
        fl = smoke_fl(rounds=rounds, num_clients=8)
        trainer = FedPhD(SMOKE_UNET, fl, clients, rng_seed=0, prune=False,
                         selection=sel)
        hist, _ = trainer.run(rounds)
        sh_final = np.mean(hist[-1].edge_sh)
        emit(f"fig7/selection_{tag}", 0.0, f"mean_edge_sh={sh_final:.4f}")


if __name__ == "__main__":
    main()
