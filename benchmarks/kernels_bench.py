"""Kernel micro-benchmarks (interpret mode on CPU — correctness-scale
timings; the BlockSpec tiling is the TPU deliverable)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.kernels.block_masked_matmul.ops import masked_matmul
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.rglru_scan.ops import linear_recurrence


def main() -> None:
    rng = jax.random.PRNGKey(0)
    x = jax.random.normal(rng, (256, 512))
    w = jax.random.normal(jax.random.fold_in(rng, 1), (512, 512))
    for ratio in (0.0, 0.44):
        cm = (jax.random.uniform(jax.random.fold_in(rng, 2), (512,))
              >= ratio).astype(jnp.float32)
        rm = jnp.ones(512)
        fn = lambda: masked_matmul(x, w, cm, rm).block_until_ready()
        emit(f"kernels/masked_matmul_r{int(ratio*100)}", time_fn(fn),
             f"M=256;K=512;N=512")

    q = jax.random.normal(rng, (2, 256, 4, 64))
    k = jax.random.normal(jax.random.fold_in(rng, 3), (2, 256, 2, 64))
    v = jax.random.normal(jax.random.fold_in(rng, 4), (2, 256, 2, 64))
    fn = lambda: flash_attention(q, k, v, causal=True).block_until_ready()
    emit("kernels/flash_attention", time_fn(fn), "B=2;S=256;H=4;hd=64")

    a = jax.random.uniform(rng, (2, 512, 256), minval=0.5, maxval=0.99)
    b = jax.random.normal(jax.random.fold_in(rng, 5), (2, 512, 256))
    fn = lambda: linear_recurrence(a, b).block_until_ready()
    emit("kernels/rglru_scan", time_fn(fn), "B=2;S=512;W=256")


if __name__ == "__main__":
    main()
