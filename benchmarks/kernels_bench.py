"""Kernel micro-benchmarks (interpret mode on CPU — correctness-scale
timings; the BlockSpec tiling is the TPU deliverable), plus an
xla-vs-pallas A/B of the repro.models.ops dispatch layer on the real
CIFAR-10 U-Net shapes the FedPhD hot path executes."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.kernels.block_masked_matmul.ops import masked_matmul
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.rglru_scan.ops import linear_recurrence
from repro.models import ops


def _ab(name: str, fn, args, shape: str, backends=("xla", "pallas")) -> None:
    """Emit one ops-dispatch row per backend for the same call.

    ``fn(backend, *args)``; args stay jit arguments (a nullary closure
    would let XLA constant-fold the whole computation away).  On CPU
    the pallas leg runs interpret=True — timings quantify the
    interpreter overhead CI pays, not TPU performance; the xla rows
    are the ones the round-engine hot path executes by default.
    """
    for b in backends:
        jfn = jax.jit(partial(fn, b))
        emit(f"ops/{name}_{b}",
             time_fn(lambda: jfn(*args).block_until_ready()), shape)


def unet_ops_ab() -> None:
    """The paper U-Net's tensor-core ops at CIFAR-10 scale (base=128,
    attention at 16x16) — every shape tile-aligned so the pallas leg
    exercises the kernels, not the fallback oracles."""
    rng = jax.random.PRNGKey(0)
    ks = jax.random.split(rng, 8)
    B = 2

    # 3x3 res-conv 128 -> 256 at 16x16 (im2col GEMM: M=512, K=1152, N=256)
    p3 = {"w": jax.random.normal(ks[0], (3, 3, 128, 256)) * 0.05,
          "b": jnp.zeros((256,))}
    x3 = jax.random.normal(ks[1], (B, 16, 16, 128))
    _ab("conv3x3_128_256",
        lambda b, p, x: ops.conv(p, x, backend=b), (p3, x3),
        f"B={B};HW=16;K=1152;N=256")

    # 1x1 qkv conv 256 -> 768 (M=512, K=256, N=768)
    p1 = {"w": jax.random.normal(ks[2], (1, 1, 256, 768)) * 0.05,
          "b": jnp.zeros((768,))}
    x1 = jax.random.normal(ks[3], (B, 16, 16, 256))
    _ab("qkv1x1_256_768",
        lambda b, p, x: ops.conv(p, x, backend=b), (p1, x1),
        f"B={B};HW=16;K=256;N=768")

    # the same qkv GEMM at the paper's 44% sparse phase: block-masked
    cm = (jax.random.uniform(ks[4], (768,)) >= 0.44).astype(jnp.float32)
    rm = (jax.random.uniform(ks[5], (256,)) >= 0.44).astype(jnp.float32)
    _ab("qkv1x1_masked_r44",
        lambda b, p, x, c, r: ops.conv(p, x, backend=b, col_mask=c,
                                       row_mask=r), (p1, x1, cm, rm),
        f"B={B};HW=16;ratio=0.44")

    # attention block at 16x16, C=256 (S=256, single head of width C)
    q = jax.random.normal(ks[6], (B, 256, 1, 256))
    _ab("unet_attn_16x16_c256",
        lambda b, q_: ops.attention(q_, q_, q_, causal=False, backend=b),
        (q,), f"B={B};S=256;hd=256")

    # Eq. 17 group reduction over a conv1 member: (K=1152, G=256)
    w2d = jax.random.normal(ks[7], (1152, 256))
    _ab("group_sq_norms_1152x256",
        lambda b, w: ops.group_sq_norms_2d(w, 256, backend=b), (w2d,),
        "K=1152;G=256;C=1")


def main() -> None:
    rng = jax.random.PRNGKey(0)
    x = jax.random.normal(rng, (256, 512))
    w = jax.random.normal(jax.random.fold_in(rng, 1), (512, 512))
    for ratio in (0.0, 0.44):
        cm = (jax.random.uniform(jax.random.fold_in(rng, 2), (512,))
              >= ratio).astype(jnp.float32)
        rm = jnp.ones(512)
        fn = lambda: masked_matmul(x, w, cm, rm).block_until_ready()
        emit(f"kernels/masked_matmul_r{int(ratio*100)}", time_fn(fn),
             f"M=256;K=512;N=512")

    q = jax.random.normal(rng, (2, 256, 4, 64))
    k = jax.random.normal(jax.random.fold_in(rng, 3), (2, 256, 2, 64))
    v = jax.random.normal(jax.random.fold_in(rng, 4), (2, 256, 2, 64))
    fn = lambda: flash_attention(q, k, v, causal=True).block_until_ready()
    emit("kernels/flash_attention", time_fn(fn), "B=2;S=256;H=4;hd=64")

    a = jax.random.uniform(rng, (2, 512, 256), minval=0.5, maxval=0.99)
    b = jax.random.normal(jax.random.fold_in(rng, 5), (2, 512, 256))
    fn = lambda: linear_recurrence(a, b).block_until_ready()
    emit("kernels/rglru_scan", time_fn(fn), "B=2;S=512;W=256")

    unet_ops_ab()


if __name__ == "__main__":
    main()
