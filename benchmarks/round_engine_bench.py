"""Round-engine latency: sequential reference vs vectorized device-
resident engine (repro/fl/engine.py) on the acceptance config —
8 clients / 2 edges on CPU.

The sequential path pays, per batch, a jitted-call dispatch (pytree
flatten of ~300 leaves), a ``float(loss)`` host sync, and per-leaf
Python aggregation per round; the vectorized path runs the whole round
(vmap clients x scan batches + fused edge einsum) as ONE jitted
program with a single sync.  The config is dispatch-bound (micro U-Net,
batch 1, 64 local steps/client) — the regime the smoke suite and the
table benches live in, and the one the ISSUE targets: nearly all
sequential wall-clock is Python orchestration, which the engine
eliminates.  At compute-bound scale the two engines converge on CPU
(same flops, 2 cores); the engine's headroom there is the client-axis
shard_map onto real device meshes.

Rounds of the two engines are interleaved and medians compared so the
ratio is robust to background CPU-throughput drift; emits per-round
wall-clock for both plus the speedup (expected >= 3x).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import dump_bench_json, emit, emit_bytes
from repro.configs import SMOKE_UNET
from repro.configs.base import FLConfig
from repro.core.hfl import FedPhD
from repro.data import ClientData, shards_per_client
from repro.data.synthetic import DatasetSpec, make_dataset
from repro.fl.client import Client

NUM_CLIENTS = 8
NUM_EDGES = 2
BATCH = 1
TIMED_ROUNDS = 5

MICRO_UNET = SMOKE_UNET.replace(name="ddpm-unet-micro", image_size=4,
                                base_channels=8, channel_mults=(1,),
                                num_res_blocks=1, attn_resolutions=())
MICRO_DATA = DatasetSpec("bench-micro", num_classes=4, image_size=4,
                         samples_per_class=64)


def _clients(seed: int = 0):
    images, labels = make_dataset(MICRO_DATA, seed=seed)
    parts = shards_per_client(labels, num_clients=NUM_CLIENTS,
                              classes_per_client=1, seed=seed)
    return [Client(i, ClientData(images[p], labels[p], batch_size=BATCH,
                                 seed=i), MICRO_DATA.num_classes)
            for i, p in enumerate(parts)]


def _fl() -> FLConfig:
    # cloud_agg_every beyond the horizon: the cloud tier is identical
    # host-side work in both engines, and the interleaved timing below
    # would otherwise hit it only on one engine's round parity
    return FLConfig(num_clients=NUM_CLIENTS, num_edges=NUM_EDGES,
                    local_epochs=2, edge_agg_every=1,
                    cloud_agg_every=10 ** 6,
                    rounds=2 * TIMED_ROUNDS + 2, sh_a=1000.0)


def main() -> None:
    # prune=False keeps shapes static so timings measure the steady state
    seq = FedPhD(MICRO_UNET, _fl(), _clients(), rng_seed=0,
                 engine="sequential", prune=False)
    vec = FedPhD(MICRO_UNET, _fl(), _clients(), rng_seed=0,
                 engine="vectorized", prune=False)
    seq.run_round(1)                       # warmup: jit compile
    vec.run_round(1)

    t_seq, t_vec = [], []
    r = 2
    for _ in range(TIMED_ROUNDS):          # interleave against CPU drift
        t0 = time.perf_counter()
        seq.run_round(r)
        t_seq.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        vec.run_round(r + 1)
        t_vec.append(time.perf_counter() - t0)
        r += 2

    us_seq = float(np.median(t_seq)) * 1e6
    us_vec = float(np.median(t_vec)) * 1e6
    speedup = us_seq / max(us_vec, 1e-9)
    shape = f"C={NUM_CLIENTS};E={NUM_EDGES};B={BATCH}"
    emit("round_engine/sequential", us_seq, shape)
    emit("round_engine/vectorized", us_vec, f"{shape};speedup={speedup:.2f}x")
    # regression gate (donated-buffer change rides on this bench): the
    # observed range on the noisy 2-core CI box is 3.4-17.5x; dropping
    # under 2x means per-batch dispatch crept back into the hot path
    assert speedup >= 2.0, \
        f"vectorized round engine regressed: {speedup:.2f}x < 2x"

    precision_and_bytes(us_vec)
    pipelined_ab()
    traced_run()
    # medians -> $BENCH_OUT_DIR/BENCH_round_engine.json for the CI
    # regression gate (benchmarks/regression_gate.py)
    dump_bench_json("round_engine")


def precision_and_bytes(us_fp32: float) -> None:
    """The PR-9 axes on the same micro config: a bf16 vectorized round
    (fp32 master weights, bf16 GEMMs — repro.models.ops) and the
    bytes-on-wire uplink rows the gate pins exactly.  On this 2-core
    CPU box bf16 is emulated, so ``vs_fp32`` is informational (not
    asserted); the bytes rows ARE asserted — they are host-computed
    from static shapes and must not drift."""
    from repro.fl.compress import uplink_bytes

    bf = FedPhD(MICRO_UNET.replace(precision="bf16"), _fl(), _clients(),
                rng_seed=0, engine="vectorized", prune=False)
    bf.run_round(1)                        # warmup: jit compile
    ts = []
    for r in range(2, TIMED_ROUNDS + 2):
        t0 = time.perf_counter()
        bf.run_round(r)
        ts.append(time.perf_counter() - t0)
    us_bf16 = float(np.median(ts)) * 1e6
    shape = f"C={NUM_CLIENTS};E={NUM_EDGES};B={BATCH}"
    emit("round_engine/vectorized_bf16", us_bf16,
         f"{shape};vs_fp32={us_fp32 / max(us_bf16, 1e-9):.2f}x")

    # one client->edge upload of the micro model's round delta
    up_f = uplink_bytes(bf.params, "none")
    up_q = uplink_bytes(bf.params, "int8")
    emit_bytes("round_engine/uplink_fp32", up_f, "per-client delta")
    emit_bytes("round_engine/uplink_int8", up_q,
               f"ratio={up_f / up_q:.2f}x")
    # int8 payload: 1 byte/elem + one fp32 scale/leaf -> ~4x under fp32
    assert up_q * 3 < up_f, \
        f"int8 uplink not compressing: {up_q}B vs fp32 {up_f}B"


def pipelined_ab() -> None:
    """Double-buffered ``run()`` vs stepping ``run_round`` one at a
    time: the pipelined loop dispatches round r+1 (stacked_epochs
    shuffle/stack on the host + H2D copy + round-program dispatch)
    BEFORE syncing round r's losses, overlapping next-round data prep
    with device compute — the remaining H2D item from ROADMAP "Open
    items" (that buffer has no output to donate-alias into).  Identical
    numerics; only the sync point moves.

    Reading the rows: ``host_prep`` is the per-round data-prep cost the
    pipeline hides; ``overlap`` is the measured stepped/pipelined
    ratio.  On this CPU-only box host and "device" share the same
    cores, so overlap sits at ~1.0 by construction (the hidden work
    still occupies the cores) — the row exists to lock the pipelined
    driver's trajectory identity and to report real gains on
    accelerator-backed runs, where host prep is free wall-clock.
    """
    rounds = 2 * TIMED_ROUNDS

    # the overlappable component, measured directly (fresh clients:
    # stack_round consumes the shuffle RNG streams)
    from repro.data.pipeline import stack_round
    prep_clients = _clients()
    t0 = time.perf_counter()
    stack_round([cl.data for cl in prep_clients], _fl().local_epochs)
    us_prep = (time.perf_counter() - t0) * 1e6
    emit("round_engine/host_prep", us_prep,
         f"C={NUM_CLIENTS};overlappable=1")
    stepped = FedPhD(MICRO_UNET, _fl(), _clients(), rng_seed=0,
                     engine="vectorized", prune=False)
    piped = FedPhD(MICRO_UNET, _fl(), _clients(), rng_seed=0,
                   engine="vectorized", prune=False)
    stepped.run_round(1)                   # warmup: jit compile
    piped.run_round(1)

    t0 = time.perf_counter()
    for r in range(2, rounds + 2):
        stepped.run_round(r)
    us_step = (time.perf_counter() - t0) / rounds * 1e6
    t0 = time.perf_counter()
    piped.run(rounds + 1)
    us_pipe = (time.perf_counter() - t0) / rounds * 1e6

    overlap = us_step / max(us_pipe, 1e-9)
    shape = f"C={NUM_CLIENTS};E={NUM_EDGES};B={BATCH};R={rounds}"
    emit("round_engine/run_round_stepped", us_step, shape)
    emit("round_engine/run_pipelined", us_pipe,
         f"{shape};overlap={overlap:.2f}x")
    # both drivers must land on identical trajectories
    for a, b in zip(stepped.history, piped.history):
        assert a.comm_gb == b.comm_gb and abs(a.loss - b.loss) < 1e-6, \
            "pipelined run() diverged from stepped run_round()"


def traced_run() -> None:
    """The obs layer under the bench clock: a fully traced pipelined
    run (phase spans + compile counters through ``repro.obs``) on the
    same micro config.  Three things ride on this row:

    - the per-round latency WITH tracing on, gated at the usual 3x —
      the trace emitters are host-side JSON appends and must stay in
      the noise next to ``run_pipelined``;
    - ``overlap=`` — the overlap ratio *measured from the trace* (vs
      pipelined_ab's stepped/pipelined wall-clock ratio).  ~0.5 on this
      shared-core box: the spans see host prep land inside the
      in-flight window even though the cores are shared.  Informational
      (not gated), like every overlap tag;
    - ``recompiles=`` — unexpected jit-cache growth past each entry
      point's first compile.  Deterministic, pinned at 0 by the gate:
      the "zero steady-state recompiles" ROADMAP invariant.

    The trace lands in ``$BENCH_OUT_DIR/round_engine_trace.jsonl`` so
    CI's fresh-medians artifact carries the raw trace alongside the
    medians (a tempfile when unset).
    """
    import os
    import tempfile

    from repro.obs.metrics import summarize_trace
    from repro.obs.trace import Tracer

    out_dir = os.environ.get("BENCH_OUT_DIR")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir, "round_engine_trace.jsonl")
    else:
        fd, path = tempfile.mkstemp(suffix=".trace.jsonl")
        os.close(fd)
    if os.path.exists(path):               # Tracer appends; start clean
        os.remove(path)

    rounds = TIMED_ROUNDS
    tracer = Tracer(path)
    tr = FedPhD(MICRO_UNET, _fl(), _clients(), rng_seed=0,
                engine="vectorized", prune=False, tracer=tracer)
    tr.run_round(1)                        # warmup: the expected compile
    t0 = time.perf_counter()
    tr.run(rounds + 1)
    us = (time.perf_counter() - t0) / rounds * 1e6
    tracer.close()

    ts = summarize_trace(path)
    ratio = ts["overlap_ratio"]
    shape = f"C={NUM_CLIENTS};E={NUM_EDGES};B={BATCH};R={rounds}"
    emit("round_engine/traced", us,
         f"{shape};overlap={0.0 if ratio is None else ratio:.2f}x"
         f";recompiles={ts['recompiles']}")
    assert ts["recompiles"] == 0, \
        f"steady-state recompiles in traced run: {ts['recompiles']}"
    if not out_dir:
        os.remove(path)


if __name__ == "__main__":
    main()
